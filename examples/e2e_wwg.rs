//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer stack on
//! the paper's real workload — 200 Gridlets of ≥10,000 MI on the simulated
//! WWG testbed (Table 2), DBC cost-optimization with deadline 3100 and
//! budget 22,000 G$ (the paper's §5.3 relaxed-deadline cell), with the
//! schedule advisor running as the AOT-compiled JAX/Pallas artifact through
//! PJRT when artifacts are present (falling back to the native advisor with
//! a warning otherwise). Runs through `GridSession` and reports the paper's
//! headline metrics: Gridlets completed, budget spent, deadline utilization,
//! resource selection.
//!
//!     make artifacts && cargo run --release --example e2e_wwg

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::output::report;
use gridsim::scenario::{AdvisorKind, Scenario};
use gridsim::session::GridSession;
use std::path::Path;

fn main() {
    let artifacts = Path::new("artifacts/advisor.hlo.txt");
    let advisor = if !cfg!(feature = "xla") {
        println!("NOTE: built without the `xla` cargo feature; using native advisor");
        AdvisorKind::Native
    } else if artifacts.exists() {
        println!("advisor engine: XLA artifact ({})", artifacts.display());
        AdvisorKind::Xla
    } else {
        println!("WARNING: {} missing (run `make artifacts`); using native advisor", artifacts.display());
        AdvisorKind::Native
    };

    let deadline = 3_100.0;
    let budget = 22_000.0;
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(200, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(Optimization::Cost),
        )
        .seed(27)
        .advisor(advisor)
        .build();

    let start = std::time::Instant::now();
    let result = GridSession::new(&scenario).run_to_completion();
    let wall = start.elapsed();
    let u = &result.users[0];

    println!();
    println!("== GridSim e2e: 200-Gridlet task farm on the WWG testbed ==");
    println!("policy               : DBC cost-optimization (paper Fig 20)");
    println!("deadline / budget    : {deadline} time units / {budget} G$");
    println!("gridlets completed   : {}/{}", u.gridlets_completed, u.gridlets_total);
    println!("budget spent         : {:.1} G$ ({:.1}% of budget)", u.budget_spent, 100.0 * u.budget_utilization());
    println!("experiment time      : {:.1} ({:.1}% of deadline)", u.finish_time - u.start_time, 100.0 * u.time_utilization());
    println!();
    println!("resource selection (paper Fig 27 expects the cheapest, R8, to absorb everything):");
    println!("{}", report::resource_table(u));
    println!(
        "engine: {} events in {:.3}s wall ({:.0} events/s)",
        result.events,
        wall.as_secs_f64(),
        result.events as f64 / wall.as_secs_f64().max(1e-9)
    );

    // Exit non-zero if the headline result does not hold, so this example
    // doubles as an end-to-end gate.
    let r8 = u.per_resource.iter().find(|r| r.name == "R8").unwrap();
    if !result.all_finished() || u.gridlets_completed != 200 || r8.gridlets_completed < 190 {
        eprintln!("E2E FAILURE: expected all 200 Gridlets on R8");
        std::process::exit(1);
    }
    println!("E2E OK");
}
