//! Quickstart: build a tiny grid, run one deadline-and-budget-constrained
//! experiment, and print the outcome.
//!
//!     cargo run --release --example quickstart

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::AllocPolicy;
use gridsim::output::report;
use gridsim::scenario::{run_scenario, ResourceSpec, Scenario};

fn main() {
    // Two resources: a cheap slow PC and a pricey fast SMP.
    let pc = ResourceSpec {
        name: "CheapPC".into(),
        arch: "Intel".into(),
        os: "Linux".into(),
        machines: 1,
        pes_per_machine: 2,
        mips_per_pe: 380.0,
        policy: AllocPolicy::TimeShared,
        price: 1.0,
        time_zone: 0.0,
        calendar: None,
    };
    let smp = ResourceSpec {
        name: "FastSMP".into(),
        arch: "Alpha".into(),
        os: "OSF1".into(),
        machines: 1,
        pes_per_machine: 8,
        mips_per_pe: 515.0,
        policy: AllocPolicy::TimeShared,
        price: 8.0,
        time_zone: 10.0,
        calendar: None,
    };

    // 50 jobs of ~10,000 MI; finish within 1,500 time units and 4,000 G$,
    // as cheaply as possible.
    let scenario = Scenario::builder()
        .resource(pc)
        .resource(smp)
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .deadline(1_500.0)
                .budget(4_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(42)
        .build();

    let result = run_scenario(&scenario);
    let user = &result.users[0];
    println!("{}", report::experiment_line("user", user));
    println!("\nper-resource breakdown:");
    println!("{}", report::resource_table(user));
    println!(
        "engine: {} events, simulated time {:.1}",
        result.events, result.end_time
    );
}
