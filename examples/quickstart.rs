//! Quickstart: the `GridSession` lifecycle on a tiny grid —
//! **build → step/observe → report**.
//!
//! 1. *Build*: describe resources and users declaratively in a
//!    [`Scenario`]; `GridSession::new` assembles the entity graph (GIS,
//!    statistics, shutdown coordinator, resources, one broker per user).
//! 2. *Step/observe*: drive the simulation in increments with
//!    `run_until(t)` (or one event at a time with `step()`), pulling a
//!    per-broker progress `snapshot()` whenever you want — state, Gridlets
//!    completed, budget spent, per-resource load.
//! 3. *Report*: `report()` harvests per-user outcomes, distinguishing
//!    finished experiments from truncated ones.
//!
//! For fire-and-forget runs, `session.run_to_completion()` does all three
//! stages in one call.
//!
//!     cargo run --release --example quickstart

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::AllocPolicy;
use gridsim::output::report;
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::session::GridSession;

fn main() {
    // Two resources: a cheap slow PC and a pricey fast SMP.
    let pc = ResourceSpec {
        name: "CheapPC".into(),
        arch: "Intel".into(),
        os: "Linux".into(),
        machines: 1,
        pes_per_machine: 2,
        mips_per_pe: 380.0,
        policy: AllocPolicy::TimeShared,
        price: 1.0,
        time_zone: 0.0,
        calendar: None,
    };
    let smp = ResourceSpec {
        name: "FastSMP".into(),
        arch: "Alpha".into(),
        os: "OSF1".into(),
        machines: 1,
        pes_per_machine: 8,
        mips_per_pe: 515.0,
        policy: AllocPolicy::TimeShared,
        price: 8.0,
        time_zone: 10.0,
        calendar: None,
    };

    // 1. BUILD — 50 jobs of ~10,000 MI; finish within 1,500 time units and
    // 4,000 G$, as cheaply as possible.
    let scenario = Scenario::builder()
        .resource(pc)
        .resource(smp)
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .deadline(1_500.0)
                .budget(4_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(42)
        .build();
    let mut session = GridSession::new(&scenario);
    session.init();

    // 2. STEP / OBSERVE — advance the horizon 250 time units at a time,
    // watching the broker work (discovery → trading → scheduling → done).
    // The horizon must grow monotonically: `run_until` leaves the clock on
    // the last dispatched event, so a `clock() + 250` horizon would stall
    // whenever the next event lies further ahead than that.
    println!("{:>8} {:>12} {:>10} {:>12}", "time", "state", "done", "spent(G$)");
    let mut horizon = 0.0;
    while !session.is_idle() {
        horizon += 250.0;
        session.run_until(horizon);
        let snap = session.snapshot();
        let u = &snap.users[0];
        println!(
            "{:>8.1} {:>12} {:>7}/{:<2} {:>12.1}",
            snap.time, u.state, u.gridlets_completed, u.gridlets_total, u.budget_spent
        );
    }

    // 3. REPORT — harvest the outcome.
    let result = session.report().into_scenario_report();
    let user = &result.users[0];
    println!();
    println!("{}", report::experiment_line("user", user));
    println!("\nper-resource breakdown:");
    println!("{}", report::resource_table(user));
    println!(
        "engine: {} events, simulated time {:.1}",
        result.events, result.end_time
    );
}
