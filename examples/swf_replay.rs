//! Real-trace replay: split one 18-column SWF log into per-user workloads.
//!
//! Published supercomputer logs (Standard Workload Format) carry a
//! `user_id` per job. This example loads the committed excerpt
//! (`examples/lanl_cm5_excerpt.swf`), prints what the header directives
//! declare, then picks the two busiest users of the log and replays each
//! one's jobs as a *separate* simulated user with its own economic broker —
//! the paper's multi-user competition (§5.4), but driven by a real trace
//! shape instead of a synthetic farm.
//!
//!     cargo run --release --example swf_replay
//!     cargo run --release --example swf_replay -- --trace examples/lanl_cm5_excerpt.swf

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use gridsim::util::cli::Args;
use gridsim::workload::{parse_swf, SwfLoadOptions, TraceJob, TraceSelector, WorkloadSpec};
use std::collections::BTreeMap;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let path = args.flag("trace").unwrap_or("examples/lanl_cm5_excerpt.swf");

    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("error: cannot read {path}: {e}");
        std::process::exit(1);
    });
    let swf = parse_swf(&text).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    });
    println!(
        "log: {} — {} nodes, {} records, epoch {}",
        swf.header.computer().unwrap_or("?"),
        swf.header.max_nodes().map_or("?".into(), |n| n.to_string()),
        swf.jobs.len(),
        swf.header.unix_start_time().map_or("?".into(), |t| t.to_string()),
    );

    // Convert: completed jobs only, runtime seconds × procs × 100 MIPS.
    // Into an Arc up front: both simulated users (and any sweep built on
    // top) share this one allocation instead of copying the log.
    let options = SwfLoadOptions { mips: 100.0, ..SwfLoadOptions::default() };
    let jobs: Arc<[TraceJob]> = swf
        .to_trace_jobs(&options)
        .unwrap_or_else(|e| {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        })
        .into();

    // Rank the log's users by job count and take the two busiest.
    let mut per_user: BTreeMap<i64, usize> = BTreeMap::new();
    for j in jobs.iter() {
        if let Some(u) = j.user {
            *per_user.entry(u).or_default() += 1;
        }
    }
    let mut ranked: Vec<(i64, usize)> = per_user.into_iter().collect();
    ranked.sort_by_key(|&(u, n)| (std::cmp::Reverse(n), u));
    if ranked.len() < 2 {
        eprintln!("error: the trace has {} user(s); need 2 to compete", ranked.len());
        std::process::exit(1);
    }
    println!("replaying the two busiest users as competing brokers:");
    for &(u, n) in &ranked[..2] {
        println!("  swf user {u:>3}: {n} completed jobs");
    }

    // One simulated user per selected SWF user, each holding an Arc clone
    // of the one loaded log. The slices share the log's rebased clock, so
    // their arrivals stay mutually aligned.
    let mut builder = Scenario::builder().resources(wwg_testbed()).seed(27);
    for &(u, _) in &ranked[..2] {
        builder = builder.user(
            ExperimentSpec::new(WorkloadSpec::trace_selected_shared(
                jobs.clone(),
                TraceSelector::user(u),
            ))
            .deadline(1e6)
            .budget(1e9)
            .optimization(Optimization::Cost),
        );
    }
    let scenario = builder.build();

    let report = GridSession::new(&scenario).run_to_completion();
    println!();
    for (i, res) in report.users.iter().enumerate() {
        let (user, _) = ranked[i];
        println!(
            "U{i} (swf user {user}): {}/{} gridlets, makespan {:.1}, {:.1} G$ ({} resources used)",
            res.gridlets_completed,
            res.gridlets_total,
            res.finish_time - res.start_time,
            res.budget_spent,
            res.per_resource.iter().filter(|r| r.gridlets_completed > 0).count(),
        );
    }
    println!("{} events total", report.events);
    if !report.all_finished() {
        eprintln!("error: a replayed user did not finish");
        std::process::exit(1);
    }
}
