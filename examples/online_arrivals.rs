//! Online application models: jobs that stream into a *running* experiment.
//!
//! The paper's §5 experiments submit a closed batch; Nimrod/G-style
//! parameter-sweep users instead feed jobs in over time. `WorkloadSpec`
//! makes both first-class:
//!
//! 1. A Poisson stream of task-farm jobs (`WorkloadSpec::online`) — the
//!    broker learns the declared totals up front (so Eq 1–2 deadline/budget
//!    factors see the whole workload) but re-plans as each job arrives.
//! 2. The same jobs replayed from an SWF-style trace file
//!    (`examples/trace_wwg.swf`) — submit times come from the file.
//!
//! A mid-run snapshot shows the broker working on a plan that is still
//! growing.
//!
//!     cargo run --release --example online_arrivals
//!     cargo run --release --example online_arrivals -- --trace examples/trace_wwg.swf

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use gridsim::util::cli::Args;
use gridsim::workload::{load_trace_file, ArrivalProcess, WorkloadSpec};

fn main() {
    let args = Args::parse(std::env::args().skip(1));

    // Pick the application model: a trace file if given, else a Poisson
    // stream over the paper's task farm.
    let workload = match args.flag("trace") {
        Some(path) => {
            let jobs = load_trace_file(path).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1);
            });
            println!("workload: {} jobs replayed from {path}", jobs.len());
            WorkloadSpec::trace(jobs)
        }
        None => {
            println!("workload: 100 task-farm jobs, Poisson arrivals (mean gap 20)");
            WorkloadSpec::online(
                WorkloadSpec::task_farm(100, 10_000.0, 0.10),
                ArrivalProcess::Poisson { mean_interarrival: 20.0 },
            )
        }
    };

    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::new(workload)
                .deadline(5_000.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(27)
        .build();

    // Drive in increments and watch the broker's pool grow as jobs arrive:
    // `total` is declared up front, but completions trail the arrivals.
    let mut session = GridSession::new(&scenario);
    session.init();
    println!();
    let cols = ("time", "state", "done", "in flight", "spent(G$)");
    println!("{:>8} {:>12} {:>10} {:>12} {:>11}", cols.0, cols.1, cols.2, cols.3, cols.4);
    let mut horizon = 0.0;
    while !session.is_idle() {
        horizon += 400.0;
        session.run_until(horizon);
        let snap = session.snapshot();
        let u = &snap.users[0];
        println!(
            "{:>8.1} {:>12} {:>7}/{:<3} {:>12} {:>11.1}",
            snap.time, u.state, u.gridlets_completed, u.gridlets_total, u.outstanding,
            u.budget_spent
        );
    }

    let report = session.report().into_scenario_report();
    let u = &report.users[0];
    println!();
    println!(
        "completed {}/{} gridlets in {:.1} time units for {:.1} G$ ({} events)",
        u.gridlets_completed,
        u.gridlets_total,
        u.finish_time - u.start_time,
        u.budget_spent,
        report.events
    );
    println!("per-resource breakdown:");
    for r in &u.per_resource {
        if r.gridlets_completed > 0 {
            let (name, done, spent) = (&r.name, r.gridlets_completed, r.budget_spent);
            println!("  {name:<4} {done:>4} gridlets {spent:>10.1} G$");
        }
    }
    if u.gridlets_completed < u.gridlets_total {
        println!(
            "note: {} job(s) arrived too close to the deadline to finish",
            u.gridlets_total - u.gridlets_completed
        );
    }
}
