//! The paper's §5.4 competition study in miniature: N identical users, each
//! with a private economic broker, compete for the WWG testbed. Mean
//! completions per user decay with competition; termination stretches toward
//! the deadline (Figures 33–35).
//!
//!     cargo run --release --example multi_user_market [-- --users 20]

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::{run_scenario, Scenario};
use gridsim::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_users = args.flag("users").and_then(|s| s.parse().ok()).unwrap_or(20usize);
    let deadline = 3_100.0;
    let budget = 12_000.0;

    println!("WWG testbed, 60 Gridlets/user, deadline {deadline}, budget {budget} G$");
    println!();
    println!(
        "{:>6} {:>12} {:>14} {:>12} {:>10}",
        "users", "done/user", "termination", "spent/user", "events"
    );
    let mut n = 1;
    while n <= max_users {
        let scenario = Scenario::builder()
            .resources(wwg_testbed())
            .users(
                n,
                ExperimentSpec::task_farm(60, 10_000.0, 0.10)
                    .deadline(deadline)
                    .budget(budget)
                    .optimization(Optimization::Cost),
            )
            .seed(17)
            .build();
        let report = run_scenario(&scenario);
        println!(
            "{:>6} {:>12.1} {:>14.1} {:>12.1} {:>10}",
            n,
            report.mean_completed(),
            report.mean_finish_time(),
            report.mean_spent(),
            report.events,
        );
        n *= 2;
    }
    println!();
    println!("Shapes to look for (paper Figs 33–35): per-user completions decay");
    println!("with competition; termination time stretches toward the deadline.");
}
