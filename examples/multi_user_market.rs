//! The paper's §5.4 competition study in miniature: N identical users, each
//! with a private economic broker, compete for the WWG testbed. Mean
//! completions per user decay with competition; termination stretches toward
//! the deadline (Figures 33–35).
//!
//! The largest market is driven through the stepped `GridSession` API with
//! a mid-run snapshot — watching brokers adapt *during* the run instead of
//! only reading post-hoc results.
//!
//!     cargo run --release --example multi_user_market [-- --users 20]

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use gridsim::util::cli::Args;

fn market(n: usize, deadline: f64, budget: f64) -> Scenario {
    Scenario::builder()
        .resources(wwg_testbed())
        .users(
            n,
            ExperimentSpec::task_farm(60, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(Optimization::Cost),
        )
        .seed(17)
        .build()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_users = args.flag("users").and_then(|s| s.parse().ok()).unwrap_or(20usize);
    let deadline = 3_100.0;
    let budget = 12_000.0;

    println!("WWG testbed, 60 Gridlets/user, deadline {deadline}, budget {budget} G$");
    println!();
    println!(
        "{:>6} {:>12} {:>14} {:>12} {:>10}",
        "users", "done/user", "termination", "spent/user", "events"
    );
    let mut n = 1;
    while n <= max_users {
        let report = GridSession::new(&market(n, deadline, budget)).run_to_completion();
        println!(
            "{:>6} {:>12.1} {:>14.1} {:>12.1} {:>10}",
            n,
            report.mean_completed(),
            report.mean_finish_time(),
            report.mean_spent(),
            report.events,
        );
        n *= 2;
    }

    // The same competition, observed mid-flight: pause the largest market
    // halfway to the deadline and probe every broker.
    let n = max_users.max(2);
    let mut session = GridSession::new(&market(n, deadline, budget));
    session.init();
    session.run_until(deadline / 2.0);
    let snap = session.snapshot();
    let done: usize = snap.users.iter().map(|u| u.gridlets_completed).sum();
    let in_flight: usize = snap.users.iter().map(|u| u.outstanding).sum();
    let spent: f64 = snap.users.iter().map(|u| u.budget_spent).sum();
    println!();
    println!(
        "snapshot of the {n}-user market at t={:.0} ({} events): \
         {done} Gridlets done, {in_flight} in flight, {spent:.0} G$ spent",
        snap.time, snap.events
    );
    let report = session.run_to_completion();
    println!(
        "resumed to completion: t={:.1}, mean {:.1} done/user",
        report.end_time,
        report.mean_completed()
    );

    println!();
    println!("Shapes to look for (paper Figs 33–35): per-user completions decay");
    println!("with competition; termination time stretches toward the deadline.");
}
