//! The paper's §5.3 headline experiment in miniature: a single user running
//! DBC *cost-optimization* on the simulated WWG testbed (Table 2), swept
//! over deadline and budget — the data behind Figures 21–24, printed as a
//! small grid. Compare policies with `--policy time|costtime|none`.
//!
//! Then the same market with heterogeneity made first-class: two users with
//! *different* policies and broker tunings compete in one scenario via
//! per-user `UserSpec` overrides.
//!
//!     cargo run --release --example economic_broker [-- --policy cost]

use gridsim::broker::{BrokerConfig, ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::{Scenario, UserSpec};
use gridsim::session::GridSession;
use gridsim::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let policy = Optimization::parse(args.flag("policy").unwrap_or("cost"))
        .expect("--policy cost|time|costtime|none");

    println!("WWG testbed, 100 Gridlets of ≥10,000 MI, policy = {}", policy.label());
    println!();
    println!("{:>9} {:>9} {:>8} {:>10} {:>11}", "deadline", "budget", "done", "time", "spent(G$)");
    for &deadline in &[100.0, 1_100.0, 3_100.0] {
        for &budget in &[6_000.0, 12_000.0, 22_000.0] {
            let scenario = Scenario::builder()
                .resources(wwg_testbed())
                .user(
                    ExperimentSpec::task_farm(100, 10_000.0, 0.10)
                        .deadline(deadline)
                        .budget(budget)
                        .optimization(policy),
                )
                .seed(27)
                .build();
            let report = GridSession::new(&scenario).run_to_completion();
            let u = &report.users[0];
            println!(
                "{:>9} {:>9} {:>5}/100 {:>10.1} {:>11.1}",
                deadline,
                budget,
                u.gridlets_completed,
                u.finish_time - u.start_time,
                u.budget_spent,
            );
        }
    }
    println!();
    println!("Shapes to look for (paper Figs 21–24):");
    println!(" * tight deadline (100): completions rise with budget, budget mostly spent");
    println!(" * relaxed deadline (3100): everything completes cheaply; budget barely matters");

    // Heterogeneous competition: a cost-optimizer with default tuning vs a
    // time-optimizer with a conservative dispatcher (1 Gridlet per PE in
    // flight), in the same market. Per-user overrides; scenario defaults
    // cover everything not overridden.
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(100, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .user(
            UserSpec::new(
                ExperimentSpec::task_farm(100, 10_000.0, 0.10)
                    .deadline(3_100.0)
                    .budget(22_000.0)
                    .optimization(Optimization::Time),
            )
            .broker(BrokerConfig { max_gridlets_per_pe: 1, ..BrokerConfig::default() }),
        )
        .seed(27)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    println!();
    println!("heterogeneous market (one scenario, per-user overrides):");
    for (label, u) in ["cost/default", "time/1-per-PE"].iter().zip(&report.users) {
        println!(
            " * {label:<14} {:>3}/100 done, time {:>7.1}, spent {:>8.1} G$",
            u.gridlets_completed,
            u.finish_time - u.start_time,
            u.budget_spent,
        );
    }
    println!("expect: the time-optimizer finishes sooner and pays more.");
}
