//! The paper's §5.3 headline experiment in miniature: a single user running
//! DBC *cost-optimization* on the simulated WWG testbed (Table 2), swept
//! over deadline and budget — the data behind Figures 21–24, printed as a
//! small grid. Compare policies with `--policy time|costtime|none`.
//!
//!     cargo run --release --example economic_broker [-- --policy cost]

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::{run_scenario, Scenario};
use gridsim::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let policy = Optimization::parse(args.flag("policy").unwrap_or("cost"))
        .expect("--policy cost|time|costtime|none");

    println!("WWG testbed, 100 Gridlets of ≥10,000 MI, policy = {}", policy.label());
    println!();
    println!("{:>9} {:>9} {:>8} {:>10} {:>11}", "deadline", "budget", "done", "time", "spent(G$)");
    for &deadline in &[100.0, 1_100.0, 3_100.0] {
        for &budget in &[6_000.0, 12_000.0, 22_000.0] {
            let scenario = Scenario::builder()
                .resources(wwg_testbed())
                .user(
                    ExperimentSpec::task_farm(100, 10_000.0, 0.10)
                        .deadline(deadline)
                        .budget(budget)
                        .optimization(policy),
                )
                .seed(27)
                .build();
            let report = run_scenario(&scenario);
            let u = &report.users[0];
            println!(
                "{:>9} {:>9} {:>5}/100 {:>10.1} {:>11.1}",
                deadline,
                budget,
                u.gridlets_completed,
                u.finish_time - u.start_time,
                u.budget_spent,
            );
        }
    }
    println!();
    println!("Shapes to look for (paper Figs 21–24):");
    println!(" * tight deadline (100): completions rise with budget, budget mostly spent");
    println!(" * relaxed deadline (3100): everything completes cheaply; budget barely matters");
}
