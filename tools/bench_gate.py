#!/usr/bin/env python3
"""Gate a fresh bench snapshot against the committed perf trajectory.

Usage: bench_gate.py NEW_SNAPSHOT.json [REPO_ROOT]

Compares every throughput metric (name containing "events_per_sec") in the
new snapshot against the latest committed ``BENCH_*.json`` under REPO_ROOT
(default: the repository root containing this script). Fails (exit 1) on a
gross regression — a new value below half the committed one. Metrics that
are null/missing on either side are skipped, so the gate passes cleanly
while the committed trajectory still holds the honest-null placeholder.

Stdlib only; understands both the merged snapshot shape
(``{"benches": {name: {"metrics": [...]}}}``) and the legacy flat one
(``{"bench": name, "metrics": [...]}``).
"""

import glob
import json
import os
import sys

REGRESSION_FACTOR = 2.0


def load_metrics(path):
    """Snapshot file -> {(bench, metric): value-or-None}."""
    with open(path, encoding="utf-8") as f:
        snap = json.load(f)
    out = {}
    benches = snap.get("benches")
    if isinstance(benches, dict):
        for bench, entry in benches.items():
            for m in entry.get("metrics", []):
                out[(bench, m.get("name"))] = m.get("value")
    elif "bench" in snap:
        for m in snap.get("metrics", []):
            out[(snap["bench"], m.get("name"))] = m.get("value")
    return out


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    new_path = argv[1]
    root = argv[2] if len(argv) > 2 else os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    committed_files = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not committed_files:
        print(f"bench gate: no committed BENCH_*.json under {root}; nothing to gate against")
        return 0
    committed_path = committed_files[-1]

    new = load_metrics(new_path)
    committed = load_metrics(committed_path)
    print(f"bench gate: {new_path} vs committed {committed_path}")

    failures = []
    compared = skipped = 0
    for key, old_value in sorted(committed.items()):
        bench, name = key
        if "events_per_sec" not in (name or ""):
            continue
        new_value = new.get(key)
        if old_value is None or new_value is None:
            skipped += 1
            print(f"  skip {bench}/{name}: committed={old_value} new={new_value}")
            continue
        compared += 1
        ratio = new_value / old_value if old_value else float("inf")
        status = "ok"
        if new_value < old_value / REGRESSION_FACTOR:
            status = "REGRESSION"
            failures.append(
                f"{bench}/{name}: {new_value:.1f} < committed {old_value:.1f} / {REGRESSION_FACTOR}"
            )
        print(f"  {status:>10} {bench}/{name}: new={new_value:.1f} committed={old_value:.1f} ({ratio:.2f}x)")

    print(f"bench gate: {compared} compared, {skipped} skipped, {len(failures)} regression(s)")
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
