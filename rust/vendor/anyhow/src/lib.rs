//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored crate provides
//! the small surface the workspace actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait
//! for `Result<T, Error>` and `Option<T>`. Semantics mirror the real crate
//! for that surface (message-first `Display`, `Caused by` chain in `Debug`,
//! blanket `From<E: std::error::Error>`).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Prefix the message with higher-level context (what `Context` does).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause, if a source error was captured.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Attach context to errors, turning `Result<T, Error>` / `Option<T>` into
/// `Result<T, Error>` with a prefixed message.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let who = "io";
        let e = anyhow!("inline {who}");
        assert_eq!(e.to_string(), "inline io");
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_prefixes() {
        let r: Result<u32> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing k");
    }

    #[test]
    fn from_std_error_keeps_source() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        let e = io_fail().unwrap_err();
        assert!(e.source().is_some());
        assert!(format!("{e:?}").contains("Caused by"));
    }
}
