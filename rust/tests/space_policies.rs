//! Space-shared `SpacePolicy` queue-ordering coverage at the `SpaceShared`
//! unit level (paper §3.5): the same arrival sequence driven through FCFS,
//! SJF and EASY backfilling, asserting the *order* in which jobs start and
//! complete — not just e2e totals.

use gridsim::gridsim::gridlet::Gridlet;
use gridsim::gridsim::res_gridlet::ResGridlet;
use gridsim::gridsim::resource::LocalScheduler;
use gridsim::gridsim::space_shared::SpaceShared;
use gridsim::gridsim::SpacePolicy;

fn rg(id: usize, mi: f64, pes: usize) -> ResGridlet {
    ResGridlet::new(Gridlet::new(id, mi, 0, 0).with_pes(pes), 0.0, id as u64)
}

/// Drive a scheduler until idle, returning gridlet ids in completion order
/// (ties broken by collection order — deterministic for a deterministic
/// scheduler).
fn completion_order(ss: &mut SpaceShared, mut submissions: Vec<(f64, ResGridlet)>) -> Vec<usize> {
    submissions.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut done = Vec::new();
    let mut now = 0.0;
    let mut pending = submissions.into_iter().peekable();
    loop {
        let next_arrival = pending.peek().map(|(t, _)| *t).unwrap_or(f64::INFINITY);
        let next_completion = ss.next_completion(now).unwrap_or(f64::INFINITY);
        if next_arrival.is_infinite() && next_completion.is_infinite() {
            break;
        }
        if next_arrival <= next_completion {
            now = next_arrival;
            let (t, job) = pending.next().unwrap();
            ss.submit(job, t);
        } else {
            now = next_completion;
            for finished in ss.collect(now) {
                done.push(finished.gridlet.id);
            }
        }
    }
    done
}

/// One uniprocessor, four queued jobs of decreasing length. FCFS keeps
/// submission order; SJF sorts by remaining work.
#[test]
fn fcfs_and_sjf_order_the_same_queue_differently() {
    let jobs = || {
        vec![
            (0.0, rg(0, 10.0, 1)), // running first either way
            (0.0, rg(1, 40.0, 1)),
            (0.0, rg(2, 20.0, 1)),
            (0.0, rg(3, 5.0, 1)),
        ]
    };
    let mut fcfs = SpaceShared::new(&[1], 1.0, SpacePolicy::Fcfs);
    assert_eq!(completion_order(&mut fcfs, jobs()), vec![0, 1, 2, 3]);

    let mut sjf = SpaceShared::new(&[1], 1.0, SpacePolicy::Sjf);
    // Job 0 occupies the PE at t=0; the queue {1,2,3} then drains
    // shortest-first: 3 (5 MI), 2 (20 MI), 1 (40 MI).
    assert_eq!(completion_order(&mut sjf, jobs()), vec![0, 3, 2, 1]);
}

/// SJF ties (equal remaining MI) fall back to queue order — determinism at
/// the ordering boundary.
#[test]
fn sjf_breaks_ties_by_queue_order() {
    let mut sjf = SpaceShared::new(&[1], 1.0, SpacePolicy::Sjf);
    let jobs = vec![
        (0.0, rg(0, 10.0, 1)),
        (0.0, rg(1, 7.0, 1)),
        (0.0, rg(2, 7.0, 1)),
        (0.0, rg(3, 7.0, 1)),
    ];
    assert_eq!(completion_order(&mut sjf, jobs), vec![0, 1, 2, 3]);
}

/// EASY backfilling lets a short narrow job jump a wide queue head iff it
/// cannot delay the head's reserved start (the shadow time).
#[test]
fn easy_backfill_respects_the_shadow_time() {
    // 2 PEs. J0 (1 PE) runs until t=10. Head J1 needs both PEs → shadow 10.
    // J2 (1 PE, 5 MI) finishes by t=5 ≤ 10 → backfills ahead of J1.
    let mut easy = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
    let jobs = vec![(0.0, rg(0, 10.0, 1)), (0.0, rg(1, 10.0, 2)), (0.0, rg(2, 5.0, 1))];
    assert_eq!(completion_order(&mut easy, jobs), vec![2, 0, 1]);

    // Same shape, but J2 is long (20 MI): starting it would push the head's
    // start past the shadow time, so it must wait its turn behind J1.
    let mut easy = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
    let jobs = vec![(0.0, rg(0, 10.0, 1)), (0.0, rg(1, 10.0, 2)), (0.0, rg(2, 20.0, 1))];
    assert_eq!(completion_order(&mut easy, jobs), vec![0, 1, 2]);

    // FCFS on the first workload never reorders: the wide head blocks the
    // short job even though a PE sits idle until t=10.
    let mut fcfs = SpaceShared::new(&[2], 1.0, SpacePolicy::Fcfs);
    let jobs = vec![(0.0, rg(0, 10.0, 1)), (0.0, rg(1, 10.0, 2)), (0.0, rg(2, 5.0, 1))];
    assert_eq!(completion_order(&mut fcfs, jobs), vec![0, 1, 2]);
}

/// Backfilled work must not starve the head: after the head finally starts,
/// later arrivals queue behind it again.
#[test]
fn backfill_does_not_starve_the_head() {
    let mut easy = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
    // J0 holds 1 PE to t=10; head J1 (2 PEs) waits; J2..J4 are 1-PE jobs of
    // 5 MI arriving over time — the first backfills (finishes at shadow),
    // later ones would keep the second PE busy past the shadow and must not
    // start before the head.
    let jobs = vec![
        (0.0, rg(0, 10.0, 1)),
        (0.0, rg(1, 10.0, 2)),
        (0.0, rg(2, 5.0, 1)),
        (6.0, rg(3, 5.0, 1)),
        (7.0, rg(4, 5.0, 1)),
    ];
    let order = completion_order(&mut easy, jobs);
    // J2 backfills (done t=5); J0 done t=10; head J1 runs 10→20; J3/J4 only
    // after the head, in queue order.
    assert_eq!(order, vec![2, 0, 1, 3, 4]);
    assert_eq!(easy.queue_ids(), Vec::<usize>::new());
    assert_eq!(easy.exec_ids(), Vec::<usize>::new());
}

/// The three policies agree on totals for a queue they all can drain — the
/// ordering differs, conservation does not.
#[test]
fn policies_conserve_work() {
    for policy in [SpacePolicy::Fcfs, SpacePolicy::Sjf, SpacePolicy::BackfillEasy] {
        let mut ss = SpaceShared::new(&[2], 2.0, policy);
        let jobs: Vec<(f64, ResGridlet)> =
            (0..6).map(|i| (i as f64, rg(i, 10.0 + i as f64, 1))).collect();
        let order = completion_order(&mut ss, jobs);
        assert_eq!(order.len(), 6, "{policy:?} completed everything");
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5], "{policy:?} completed each job once");
    }
}
