//! Real-trace workloads end to end: the committed 18-column SWF excerpt
//! through the parser, the strict JSON loader, per-user `TraceSelector`
//! splits, composition (`concat`/`mix`), modulated arrivals, the sweep
//! axes — and the regression pinning that the legacy 4-column format still
//! loads byte-identically.

use gridsim::broker::ExperimentSpec;
use gridsim::config::scenario_file::{parse_scenario_at, parse_sweep_at};
use gridsim::gridsim::random::GridSimRandom;
use gridsim::output::sweep::long_csv;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use gridsim::sweep::run_sweep;
use gridsim::util::prop::{check, forall};
use gridsim::workload::{
    load_trace_file, parse_swf, parse_trace, ArrivalProcess, RateEnvelope, SwfLoadOptions,
    TraceSelector, WorkloadSpec,
};
use std::path::{Path, PathBuf};

/// The committed example directory, independent of the test CWD.
fn examples_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples")
}

fn excerpt() -> String {
    std::fs::read_to_string(examples_dir().join("lanl_cm5_excerpt.swf")).unwrap()
}

#[test]
fn excerpt_header_and_filtering_are_as_documented() {
    let swf = parse_swf(&excerpt()).unwrap();
    // Header directives parse, including repeated Note: lines.
    assert_eq!(swf.header.computer(), Some("Thinking Machines CM-5"));
    assert_eq!(swf.header.max_nodes(), Some(1024));
    assert_eq!(swf.header.max_jobs(), Some(24));
    assert_eq!(swf.header.unix_start_time(), Some(760_917_602));
    assert!(swf.header.directives.iter().filter(|(k, _)| k == "Note").count() >= 3);
    assert_eq!(swf.jobs.len(), 24);

    // Default conversion: statuses {1, -1} kept, failed (0) and cancelled
    // (5) dropped, the job with no usable runtime skipped → 20 jobs.
    let jobs = swf.to_trace_jobs(&SwfLoadOptions::default()).unwrap();
    assert_eq!(jobs.len(), 20);
    // Earliest kept job submits at 0, so the rebase is the identity here.
    assert_eq!(jobs[0].submit_time, 0.0);
    // -1 semantics: job 4 falls back to requested_time (600 s × 32 procs),
    // job 5 to requested_procs (90 s × 64).
    let job4 = jobs.iter().find(|j| j.submit_time == 190.0).unwrap();
    assert_eq!(job4.length_mi, 600.0 * 32.0);
    let job5 = jobs.iter().find(|j| j.submit_time == 260.0).unwrap();
    assert_eq!(job5.length_mi, 90.0 * 64.0);
    // The per-user split the docs promise: 7 + 8 + 5.
    assert_eq!(TraceSelector::user(3).count(&jobs), 7);
    assert_eq!(TraceSelector::user(7).count(&jobs), 8);
    assert_eq!(TraceSelector::user(12).count(&jobs), 5);
    // Status-filter override: keeping failed jobs only finds the two 0s.
    let failed = SwfLoadOptions { statuses: Some(vec![0]), ..SwfLoadOptions::default() };
    assert_eq!(swf.to_trace_jobs(&failed).unwrap().len(), 2);
}

#[test]
fn out_of_order_submits_sort_in_materialization() {
    // Records 8 (submit 950) and 9 (submit 900) are out of order in the
    // file — as in real logs. File order sets ids; release order sorts.
    let jobs = parse_swf(&excerpt()).unwrap().to_trace_jobs(&SwfLoadOptions::default()).unwrap();
    let spec = WorkloadSpec::trace(jobs);
    let releases = spec.materialize(&mut GridSimRandom::new(1));
    assert!(releases.windows(2).all(|w| w[0].offset <= w[1].offset), "sorted by offset");
    let i900 = releases.iter().position(|r| r.offset == 900.0).unwrap();
    let i950 = releases.iter().position(|r| r.offset == 950.0).unwrap();
    assert!(i900 < i950);
    assert!(
        releases[i900].gridlet.id > releases[i950].gridlet.id,
        "ids keep file order, so the out-of-order pair has inverted ids"
    );
}

#[test]
fn legacy_four_column_format_loads_byte_identically() {
    // The pre-SWF behavior, pinned: auto-detection must route 4-column
    // files through the original parser with identical results.
    let path = examples_dir().join("trace_wwg.swf");
    let text = std::fs::read_to_string(&path).unwrap();
    let via_file = load_trace_file(&path).unwrap();
    let via_parse = parse_trace(&text).unwrap();
    assert_eq!(via_file, via_parse);
    assert_eq!(via_file.len(), 20);
    // First and last rows exactly as committed.
    assert_eq!(via_file[0].submit_time.to_bits(), 0f64.to_bits());
    assert_eq!(via_file[0].length_mi.to_bits(), 10_000f64.to_bits());
    assert_eq!((via_file[0].input_bytes, via_file[0].output_bytes), (1000, 500));
    assert_eq!(via_file[19].submit_time.to_bits(), 1_500f64.to_bits());
    assert_eq!(via_file[19].length_mi.to_bits(), 10_000f64.to_bits());
    // No SWF metadata is fabricated for legacy jobs.
    assert!(via_file.iter().all(|j| j.user.is_none() && j.partition.is_none()));
}

/// The acceptance property: an SWF excerpt loaded through the JSON loader,
/// split per user, mixed with a heavy-tailed farm — byte-identical releases
/// under equal seeds, for many seeds.
#[test]
fn mix_of_trace_and_heavy_tail_materializes_deterministically() {
    let jobs = parse_swf(&excerpt()).unwrap().to_trace_jobs(&SwfLoadOptions::default()).unwrap();
    let spec = WorkloadSpec::mix_weighted(
        vec![
            WorkloadSpec::heavy_tailed(30, 5_000.0, 0.2, 15.0),
            WorkloadSpec::trace_selected(jobs, TraceSelector::user(7)),
        ],
        vec![2.0, 1.0],
    );
    spec.validate().unwrap();
    assert_eq!(spec.declared_jobs(), 38);
    forall(
        11,
        25,
        |rng| rng.next_u64(),
        |&seed| {
            let a = spec.materialize(&mut GridSimRandom::new(seed));
            let b = spec.materialize(&mut GridSimRandom::new(seed));
            check(a.len() == 38, "all parts drain")?;
            for (x, y) in a.iter().zip(&b) {
                check(
                    x.offset.to_bits() == y.offset.to_bits()
                        && x.gridlet.length_mi.to_bits() == y.gridlet.length_mi.to_bits()
                        && x.gridlet.id == y.gridlet.id,
                    "same seed ⇒ byte-identical releases",
                )?;
            }
            let mut ids: Vec<usize> = a.iter().map(|r| r.gridlet.id).collect();
            ids.sort_unstable();
            check(ids == (0..38).collect::<Vec<_>>(), "ids are a permutation")
        },
    );
}

#[test]
fn swf_scenario_splits_users_and_completes_through_the_broker() {
    // The full acceptance path: {"workload": {"type": "trace", ...}} with a
    // per-user "select", run to completion on a live economic broker.
    let text = r#"{
        "seed": 7,
        "resources": [
            {"name": "Cheap", "pes": 8, "mips": 500, "price": 1.0},
            {"name": "Fast", "pes": 8, "mips": 900, "price": 3.0}
        ],
        "users": [
            {"workload": {"type": "trace", "path": "lanl_cm5_excerpt.swf",
                          "select": {"users": [3]}},
             "deadline": 1e7, "budget": 1e9},
            {"workload": {"type": "trace", "path": "lanl_cm5_excerpt.swf",
                          "select": {"users": [12], "max_jobs": 4}},
             "deadline": 1e7, "budget": 1e9}
        ]
    }"#;
    let scenario = parse_scenario_at(text, Some(examples_dir().as_path())).unwrap();
    assert_eq!(scenario.users[0].experiment.num_gridlets(), 7);
    assert_eq!(scenario.users[1].experiment.num_gridlets(), 4, "max_jobs truncates");
    assert!(scenario.users[0].experiment.workload.is_online());

    let report = GridSession::new(&scenario).run_to_completion();
    assert!(report.all_finished(), "unfinished: {:?}", report.unfinished);
    for (i, expect) in [(0usize, 7usize), (1, 4)] {
        let u = &report.users[i];
        assert_eq!(u.gridlets_completed, expect);
        let per_res: usize = u.per_resource.iter().map(|r| r.gridlets_completed).sum();
        assert_eq!(per_res, expect, "real per-resource accounting");
        assert!(u.budget_spent > 0.0);
    }
}

#[test]
fn modulated_arrivals_run_and_respect_the_envelope_end_to_end() {
    let scenario = Scenario::builder()
        .resource(gridsim::scenario::ResourceSpec {
            name: "R0".into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: 4,
            mips_per_pe: 200.0,
            policy: gridsim::gridsim::AllocPolicy::TimeShared,
            price: 1.0,
            time_zone: 0.0,
            calendar: None,
        })
        .user(
            ExperimentSpec::new(WorkloadSpec::online(
                WorkloadSpec::task_farm(40, 500.0, 0.0),
                ArrivalProcess::Modulated {
                    mean_interarrival: 3.0,
                    envelope: RateEnvelope::Piecewise {
                        period: 200.0,
                        rates: vec![1.0, 0.0],
                    },
                },
            ))
            .deadline(1e6)
            .budget(1e9),
        )
        .seed(13)
        .build();
    // The user's own arrival schedule (session seed derivation) stays in
    // the day windows.
    let user_seed = 13u64.wrapping_mul(997).wrapping_add(1);
    let releases = scenario.users[0]
        .experiment
        .workload
        .materialize(&mut GridSimRandom::new(user_seed));
    for r in &releases {
        assert!(
            r.offset.rem_euclid(200.0) < 100.0,
            "arrival at {} fell in the zero-rate night window",
            r.offset
        );
    }
    let report = GridSession::new(&scenario).run_to_completion();
    assert!(report.all_finished());
    assert_eq!(report.users[0].gridlets_completed, 40);
    let span = report.users[0].finish_time - report.users[0].start_time;
    assert!(span >= releases.last().unwrap().offset, "run covers the last arrival");
}

#[test]
fn composite_sweep_file_is_jobs_invariant() {
    // The committed sweep file: trace_selectors × mix_weights over a mix of
    // heavy-tailed + SWF trace. Byte-identical CSV at any worker count.
    let path = examples_dir().join("composite_sweep.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let spec = parse_sweep_at(&text, Some(examples_dir().as_path())).unwrap();
    assert_eq!(spec.cell_count(), 4);
    let serial = run_sweep(&spec, 1).unwrap();
    let parallel = run_sweep(&spec, 3).unwrap();
    let a = long_csv(&spec, &serial).to_string();
    let b = long_csv(&spec, &parallel).to_string();
    assert_eq!(a, b, "sweep output depends only on the spec");
    // The axis columns carry the selector and weight labels.
    assert!(a.contains(",u3,"), "{a}");
    assert!(a.contains(",u7,"), "{a}");
    assert!(a.contains(",3+1,"), "{a}");
    // Different selectors genuinely change the workload: cells for user 3
    // and user 7 declare different job totals (40 farm + 7 vs 8 trace).
    let totals: Vec<&str> = a
        .lines()
        .skip(1)
        .map(|l| l.split(',').nth(14).unwrap())
        .collect();
    assert!(totals.contains(&"47") && totals.contains(&"48"), "{totals:?}");
}

#[test]
fn concat_of_farm_and_trace_runs_to_completion() {
    let jobs = parse_swf(&excerpt()).unwrap().to_trace_jobs(&SwfLoadOptions::default()).unwrap();
    let spec = WorkloadSpec::concat(vec![
        WorkloadSpec::task_farm(10, 2_000.0, 0.10),
        WorkloadSpec::trace_selected(jobs, TraceSelector::user(12).with_max_jobs(3)),
    ]);
    assert_eq!(spec.declared_jobs(), 13);
    let scenario = Scenario::builder()
        .resource(gridsim::scenario::ResourceSpec {
            name: "R0".into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: 8,
            mips_per_pe: 400.0,
            policy: gridsim::gridsim::AllocPolicy::TimeShared,
            price: 2.0,
            time_zone: 0.0,
            calendar: None,
        })
        .user(ExperimentSpec::new(spec).deadline(1e7).budget(1e9))
        .seed(3)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    assert!(report.all_finished());
    assert_eq!(report.users[0].gridlets_total, 13);
    assert_eq!(report.users[0].gridlets_completed, 13);
}
