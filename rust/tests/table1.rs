//! Entity-level reproduction of the paper's Table 1 (and Figs 9/12): three
//! Gridlets (10, 8.5, 9.5 MI) arriving at t = 0, 4, 7 on a 2-PE, 1-MIPS
//! resource, under time-shared and space-shared management — exercised
//! through the full event protocol (submission events, internal completion
//! interrupts, return events), not by poking the scheduler directly.

use gridsim::des::{Ctx, Entity, EntityId, Event, Simulation};
use gridsim::gridsim::{
    tags, AllocPolicy, Gridlet, GridResource, GridInformationService, MachineList, Msg,
    ResourceCalendar, ResourceCharacteristics, SpacePolicy,
};

/// Drives the Table 1 arrival schedule and records returned Gridlets.
struct Driver {
    resource: EntityId,
    submissions: Vec<(f64, Gridlet)>,
    pub returned: Vec<(f64, Gridlet)>,
}

impl Entity<Msg> for Driver {
    fn name(&self) -> &str {
        "driver"
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        for (at, g) in self.submissions.drain(..) {
            let mut g = g;
            g.owner = ctx.me();
            ctx.send_delayed(self.resource, at, tags::GRIDLET_SUBMIT, Some(Msg::Gridlet(Box::new(g))));
        }
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        if ev.tag == tags::GRIDLET_RETURN {
            let Msg::Gridlet(g) = ev.take_data() else { panic!("expected gridlet") };
            self.returned.push((ctx.now(), *g));
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

fn run_table1(policy: AllocPolicy) -> Vec<(f64, Gridlet)> {
    let mut sim: Simulation<Msg> = Simulation::new();
    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let machines = match policy {
        AllocPolicy::TimeShared => MachineList::cluster(1, 2, 1.0),
        AllocPolicy::SpaceShared(_) => MachineList::cluster(2, 1, 1.0),
    };
    let chars = ResourceCharacteristics::new("test", "linux", machines, policy, 1.0, 0.0);
    let resource = sim.add(Box::new(GridResource::new(
        "R",
        chars,
        ResourceCalendar::no_load(),
        gis,
    )));
    let submissions = vec![
        (0.0, Gridlet::new(1, 10.0, 0, 0)),
        (4.0, Gridlet::new(2, 8.5, 0, 0)),
        (7.0, Gridlet::new(3, 9.5, 0, 0)),
    ];
    let driver = sim.add(Box::new(Driver { resource, submissions, returned: vec![] }));
    sim.run();
    sim.get::<Driver>(driver).unwrap().returned.clone()
}

#[test]
fn table1_time_shared_column() {
    let returned = run_table1(AllocPolicy::TimeShared);
    assert_eq!(returned.len(), 3);
    // Table 1: G1 f=10 (elapsed 10), G2 f=14 (10), G3 f=18 (11).
    let by_id = |id: usize| returned.iter().find(|(_, g)| g.id == id).unwrap();
    let (t1, g1) = by_id(1);
    assert_eq!(*t1, 10.0);
    assert_eq!(g1.finish_time, 10.0);
    assert_eq!(g1.elapsed(), 10.0);
    let (t2, g2) = by_id(2);
    assert_eq!(*t2, 14.0);
    assert_eq!(g2.elapsed(), 10.0);
    let (t3, g3) = by_id(3);
    assert_eq!(*t3, 18.0);
    assert_eq!(g3.elapsed(), 11.0);
}

#[test]
fn table1_space_shared_column() {
    let returned = run_table1(AllocPolicy::SpaceShared(SpacePolicy::Fcfs));
    assert_eq!(returned.len(), 3);
    // Table 1: G1 f=10 (10), G2 f=12.5 (8.5), G3 s=10 f=19.5 (12.5).
    let by_id = |id: usize| returned.iter().find(|(_, g)| g.id == id).unwrap();
    assert_eq!(by_id(1).1.finish_time, 10.0);
    assert_eq!(by_id(1).1.elapsed(), 10.0);
    assert_eq!(by_id(2).1.finish_time, 12.5);
    assert_eq!(by_id(2).1.elapsed(), 8.5);
    let (_, g3) = by_id(3);
    assert_eq!(g3.start_time, 0.0); // start_time is set by ResGridlet on queue entry
    assert_eq!(g3.finish_time, 19.5);
    assert_eq!(g3.elapsed(), 12.5);
}

#[test]
fn return_order_is_completion_order() {
    let returned = run_table1(AllocPolicy::TimeShared);
    let times: Vec<f64> = returned.iter().map(|(t, _)| *t).collect();
    assert!(times.windows(2).all(|w| w[0] <= w[1]));
}

#[test]
fn stale_interrupt_rule_under_bursty_arrivals() {
    // Many same-length jobs arriving in a burst: each arrival invalidates
    // the previous forecast interrupt; every job must still come back
    // exactly once with consistent accounting.
    let mut sim: Simulation<Msg> = Simulation::new();
    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let chars = ResourceCharacteristics::new(
        "t",
        "l",
        MachineList::cluster(1, 3, 10.0),
        AllocPolicy::TimeShared,
        1.0,
        0.0,
    );
    let resource = sim.add(Box::new(GridResource::new(
        "R",
        chars,
        ResourceCalendar::no_load(),
        gis,
    )));
    let submissions: Vec<(f64, Gridlet)> = (0..30)
        .map(|i| ((i as f64) * 0.1, Gridlet::new(i, 50.0 + i as f64, 0, 0)))
        .collect();
    let driver = sim.add(Box::new(Driver { resource, submissions, returned: vec![] }));
    sim.run();
    let returned = &sim.get::<Driver>(driver).unwrap().returned;
    assert_eq!(returned.len(), 30, "every gridlet returns exactly once");
    let mut ids: Vec<usize> = returned.iter().map(|(_, g)| g.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 30, "no duplicates");
    for (t, g) in returned {
        assert_eq!(g.finish_time, *t);
        assert!(g.elapsed() > 0.0);
        // cpu_time for time-shared = length / mips.
        assert!((g.cpu_time - g.length_mi / 10.0).abs() < 1e-9);
        // Conservation: wall-clock at least the dedicated-PE runtime.
        assert!(g.elapsed() + 1e-9 >= g.cpu_time);
    }
}

#[test]
fn space_shared_queue_drains_in_fcfs_order() {
    let mut sim: Simulation<Msg> = Simulation::new();
    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let chars = ResourceCharacteristics::new(
        "t",
        "l",
        MachineList::cluster(1, 1, 10.0),
        AllocPolicy::SpaceShared(SpacePolicy::Fcfs),
        1.0,
        0.0,
    );
    let resource = sim.add(Box::new(GridResource::new(
        "R",
        chars,
        ResourceCalendar::no_load(),
        gis,
    )));
    let submissions: Vec<(f64, Gridlet)> =
        (0..10).map(|i| (0.0, Gridlet::new(i, 100.0, 0, 0))).collect();
    let driver = sim.add(Box::new(Driver { resource, submissions, returned: vec![] }));
    sim.run();
    let returned = &sim.get::<Driver>(driver).unwrap().returned;
    assert_eq!(returned.len(), 10);
    let ids: Vec<usize> = returned.iter().map(|(_, g)| g.id).collect();
    assert_eq!(ids, (0..10).collect::<Vec<_>>(), "FCFS completion order");
    // Sequential on one PE: finishes at 10, 20, ..., 100.
    for (i, (t, _)) in returned.iter().enumerate() {
        assert!((t - 10.0 * (i + 1) as f64).abs() < 1e-9);
    }
}
