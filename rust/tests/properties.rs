//! Property-based tests over the whole stack: conservation laws and
//! invariants that must hold for *any* scenario, via the in-tree
//! property-test runner (`util::prop`).

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::reservation::ReservationBook;
use gridsim::gridsim::{AllocPolicy, SpacePolicy};
use gridsim::runtime::{Advisor, AdvisorInput, NativeAdvisor, ResourceSnapshot};
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::session::GridSession;
use gridsim::util::prop::{check, forall};
use gridsim::util::rng::Rng;

/// Generate a random small scenario.
fn gen_scenario(rng: &mut Rng) -> Scenario {
    let n_resources = 1 + rng.below(4) as usize;
    let mut builder = Scenario::builder();
    for i in 0..n_resources {
        let time_shared = rng.next_f64() < 0.7;
        let pes = 1 + rng.below(4) as usize;
        builder = builder.resource(ResourceSpec {
            name: format!("R{i}"),
            arch: "gen".into(),
            os: "linux".into(),
            machines: if time_shared { 1 } else { pes },
            pes_per_machine: if time_shared { pes } else { 1 },
            mips_per_pe: 50.0 + rng.below(500) as f64,
            policy: if time_shared {
                AllocPolicy::TimeShared
            } else {
                AllocPolicy::SpaceShared(match rng.below(3) {
                    0 => SpacePolicy::Fcfs,
                    1 => SpacePolicy::Sjf,
                    _ => SpacePolicy::BackfillEasy,
                })
            },
            price: 1.0 + rng.below(8) as f64,
            time_zone: 0.0,
            calendar: None,
        });
    }
    let optimization = match rng.below(4) {
        0 => Optimization::Cost,
        1 => Optimization::Time,
        2 => Optimization::CostTime,
        _ => Optimization::NoOpt,
    };
    let n_jobs = 1 + rng.below(30) as usize;
    builder
        .user(
            ExperimentSpec::task_farm(n_jobs, 500.0 + rng.below(5_000) as f64, 0.10)
                .deadline(10.0 + rng.below(5_000) as f64)
                .budget(rng.below(50_000) as f64)
                .optimization(optimization),
        )
        .seed(rng.next_u64())
        .max_time(1e7)
        .build()
}

#[test]
fn prop_budget_never_exceeded() {
    forall(101, 40, gen_scenario, |s| {
        let report = GridSession::new(s).run_to_completion();
        let u = &report.users[0];
        check(
            u.budget_spent <= u.budget + 1e-6,
            format!("spent {} > budget {}", u.budget_spent, u.budget),
        )
    });
}

#[test]
fn prop_completions_bounded_by_total() {
    forall(102, 40, gen_scenario, |s| {
        let report = GridSession::new(s).run_to_completion();
        let u = &report.users[0];
        check(
            u.gridlets_completed <= u.gridlets_total,
            format!("{}/{}", u.gridlets_completed, u.gridlets_total),
        )
    });
}

#[test]
fn prop_experiment_always_terminates() {
    forall(103, 40, gen_scenario, |s| {
        let report = GridSession::new(s).run_to_completion();
        // The shutdown entity must have fired: end time is finite and below
        // the kernel's hard cap.
        check(
            report.end_time < 1e7,
            format!("simulation ran to the hard cap: {}", report.end_time),
        )
    });
}

#[test]
fn prop_ample_budget_and_deadline_completes_all() {
    forall(
        104,
        25,
        |rng| {
            let mut s = gen_scenario(rng);
            s.users[0] = s.users[0].clone().d_factor(1.0).b_factor(1.0);
            s
        },
        |s| {
            let report = GridSession::new(s).run_to_completion();
            let u = &report.users[0];
            check(
                u.gridlets_completed == u.gridlets_total,
                format!(
                    "D=B=1 must complete everything: {}/{} (deadline {}, budget {}, spent {})",
                    u.gridlets_completed, u.gridlets_total, u.deadline, u.budget, u.budget_spent
                ),
            )
        },
    );
}

#[test]
fn prop_trace_monotone() {
    forall(105, 20, gen_scenario, |s| {
        let report = GridSession::new(s).run_to_completion();
        let mut last: std::collections::HashMap<String, (usize, f64)> = Default::default();
        for p in &report.users[0].trace {
            let e = last.entry(p.resource.clone()).or_insert((0, 0.0));
            if p.completed < e.0 || p.spent < e.1 - 1e-9 {
                return Err(format!("trace not monotone at {}", p.time));
            }
            *e = (p.completed, p.spent);
        }
        Ok(())
    });
}

#[test]
fn prop_advisor_respects_budget_and_jobs() {
    forall(
        106,
        300,
        |rng| {
            let n = 1 + rng.below(16) as usize;
            let mut costs: Vec<f64> = (0..n).map(|_| rng.uniform(0.001, 0.5)).collect();
            costs.sort_by(|a, b| a.total_cmp(b));
            AdvisorInput {
                resources: costs
                    .into_iter()
                    .map(|c| ResourceSnapshot { rate_mi: rng.uniform(0.0, 4000.0), cost_per_mi: c })
                    .collect(),
                time_left: rng.uniform(0.0, 4000.0),
                budget_left: rng.uniform(0.0, 30_000.0),
                avg_job_mi: rng.uniform(100.0, 20_000.0),
                jobs: rng.below(400) as usize,
            }
        },
        |input| {
            let alloc = NativeAdvisor::new().advise(input);
            let total: usize = alloc.iter().sum();
            check(total <= input.jobs, format!("allocated {total} > pool {}", input.jobs))?;
            let cost: f64 = alloc
                .iter()
                .zip(&input.resources)
                .map(|(&n, s)| n as f64 * s.cost_per_mi * input.avg_job_mi)
                .sum();
            check(
                cost <= input.budget_left + 1e-6,
                format!("planned cost {cost} > budget {}", input.budget_left),
            )?;
            // Deadline capacity per lane.
            for (i, (&n, s)) in alloc.iter().zip(&input.resources).enumerate() {
                let cap = (s.rate_mi * input.time_left / input.avg_job_mi).floor() as usize;
                check(n <= cap, format!("lane {i}: {n} > capacity {cap}"))?;
            }
            Ok(())
        },
    );
}

/// Random reservation-request stream for the [`ReservationBook`]
/// properties: capacity 1–6, up to 24 requests with windows in [0, 70) and
/// PE counts that sometimes exceed capacity (exercising rejection).
fn gen_reservation_ops(rng: &mut Rng) -> (usize, Vec<(f64, f64, usize)>) {
    let capacity = 1 + rng.below(6) as usize;
    let n = 1 + rng.below(24) as usize;
    let ops = (0..n)
        .map(|_| {
            (
                rng.below(50) as f64,
                1.0 + rng.below(20) as f64,
                1 + rng.below(capacity as u64 + 1) as usize,
            )
        })
        .collect();
    (capacity, ops)
}

fn filled_book(capacity: usize, ops: &[(f64, f64, usize)]) -> ReservationBook {
    let mut book = ReservationBook::new(capacity);
    for (i, &(start, duration, num_pe)) in ops.iter().enumerate() {
        book.try_reserve(i, start, duration, num_pe);
    }
    book
}

#[test]
fn prop_reservations_never_overcommit() {
    forall(108, 300, gen_reservation_ops, |(capacity, ops)| {
        let book = filled_book(*capacity, ops);
        // Reservations are piecewise constant, so the peak occurs at some
        // accepted window's start.
        for r in book.accepted() {
            let active = book.active_pes(r.start);
            check(
                active <= *capacity,
                format!("overcommitted: {active} PEs at t={} > {capacity}", r.start),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_reservation_exact_fit_admitted() {
    // Whatever the book holds, a request for exactly the residual capacity
    // over a probe window must be admitted, and residual + 1 rejected.
    forall(109, 300, gen_reservation_ops, |(capacity, ops)| {
        let mut book = filled_book(*capacity, ops);
        let (start, end) = (0.0, 100.0); // covers every generated window
        let peak = std::iter::once(start)
            .chain(book.accepted().iter().map(|r| r.start).filter(|&s| s > start && s < end))
            .map(|t| book.active_pes(t))
            .max()
            .unwrap_or(0);
        let residual = capacity - peak;
        check(
            !book.try_reserve(1_001, start, end - start, residual + 1),
            format!("one PE over the residual {residual} must be rejected"),
        )?;
        if residual > 0 {
            check(
                book.try_reserve(1_000, start, end - start, residual),
                format!("exact residual fit ({residual} PEs) must be admitted"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_reservation_cancel_then_readmit() {
    // Cancelling any accepted reservation must free enough capacity to
    // readmit the identical window — admission is monotone in the book's
    // contents, so removing one reservation can only lower every peak.
    forall(110, 300, gen_reservation_ops, |(capacity, ops)| {
        let mut book = filled_book(*capacity, ops);
        for r in book.accepted().to_vec() {
            check(book.cancel(r.id), format!("accepted id {} must cancel", r.id))?;
            check(
                book.try_reserve(r.id, r.start, r.end - r.start, r.num_pe),
                format!("freed window must readmit id {}", r.id),
            )?;
        }
        Ok(())
    });
}

/// Generate a random valid pricing model (all three variants, envelopes
/// sometimes unbounded above).
fn gen_price_model(rng: &mut Rng) -> gridsim::market::PriceModel {
    use gridsim::market::PriceModel;
    let envelope = |rng: &mut Rng| {
        let floor = rng.uniform(0.0, 5.0);
        let cap =
            if rng.next_f64() < 0.25 { f64::INFINITY } else { floor + rng.uniform(0.0, 10.0) };
        (floor, cap)
    };
    match rng.below(3) {
        0 => PriceModel::Static { price: rng.uniform(0.0, 20.0) },
        1 => {
            let (floor, cap) = envelope(&mut *rng);
            PriceModel::UtilizationLinear {
                base: rng.uniform(0.0, 10.0),
                slope: rng.uniform(0.0, 10.0),
                floor,
                cap,
            }
        }
        _ => {
            let (floor, cap) = envelope(&mut *rng);
            let mut steps = Vec::new();
            let mut threshold = 0.0;
            for _ in 0..rng.below(5) {
                threshold += rng.uniform(0.01, 0.3);
                if threshold > 1.0 {
                    break;
                }
                steps.push((threshold, rng.uniform(0.0, 15.0)));
            }
            PriceModel::UtilizationStep { base: rng.uniform(0.0, 10.0), steps, floor, cap }
        }
    }
}

#[test]
fn prop_price_models_respect_envelope_and_are_deterministic() {
    use gridsim::market::{PriceModel, PricingModel};
    forall(111, 300, gen_price_model, |m| {
        check(m.validate().is_ok(), format!("generated model must validate: {m:?}"))?;
        // Static's envelope is the price itself (returned exactly); the
        // utilization models clamp into [floor, cap].
        let (floor, cap) = match m {
            PriceModel::Static { price } => (*price, *price),
            PriceModel::UtilizationLinear { floor, cap, .. }
            | PriceModel::UtilizationStep { floor, cap, .. } => (*floor, *cap),
        };
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let t = 137.0 * i as f64;
            let p = m.price_at(u, t);
            check(
                p >= floor && p <= cap,
                format!("{m:?}: price {p} escapes [{floor}, {cap}] at u={u}"),
            )?;
            check(
                p.to_bits() == m.price_at(u, t).to_bits(),
                format!("{m:?}: equal inputs must price identically at u={u}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_utilization_linear_monotone_nondecreasing() {
    use gridsim::market::{PriceModel, PricingModel};
    forall(
        112,
        300,
        |rng| {
            let floor = rng.uniform(0.0, 5.0);
            PriceModel::UtilizationLinear {
                base: rng.uniform(0.0, 10.0),
                slope: rng.uniform(0.0, 10.0),
                floor,
                cap: floor + rng.uniform(0.0, 10.0),
            }
        },
        |m| {
            let mut last = f64::NEG_INFINITY;
            for i in 0..=40 {
                let u = i as f64 / 40.0;
                let p = m.price_at(u, 0.0);
                check(p >= last, format!("{m:?}: price fell from {last} to {p} at u={u}"))?;
                last = p;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_static_model_reproduces_configured_price_exactly() {
    use gridsim::market::{PriceModel, PricingModel};
    forall(113, 300, |rng| rng.uniform(0.0, 50.0), |price| {
        let m = PriceModel::Static { price: *price };
        for i in 0..=10 {
            let u = i as f64 / 10.0;
            let p = m.price_at(u, 999.0 * u);
            check(
                p.to_bits() == price.to_bits(),
                format!("Static must reproduce {price} bit-for-bit, got {p} at u={u}"),
            )?;
        }
        Ok(())
    });
}

/// Random DAG workload: up to 8 nodes, edges only from lower to higher
/// declaration index (guaranteed acyclic), random lengths.
fn gen_dag(rng: &mut Rng) -> (Vec<gridsim::workload::DagNode>, Vec<(String, String)>) {
    use gridsim::workload::DagNode;
    let n = 1 + rng.below(8) as usize;
    let nodes: Vec<DagNode> =
        (0..n).map(|i| DagNode::new(format!("n{i}"), 100.0 + rng.below(5_000) as f64)).collect();
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < 0.3 {
                edges.push((format!("n{i}"), format!("n{j}")));
            }
        }
    }
    (nodes, edges)
}

#[test]
fn prop_dag_materialization_is_topological() {
    use gridsim::gridsim::random::GridSimRandom;
    use gridsim::workload::WorkloadSpec;
    forall(114, 60, gen_dag, |(nodes, edges)| {
        let spec = WorkloadSpec::dag(nodes.clone(), edges.clone());
        check(spec.validate().is_ok(), format!("generated dag must validate: {nodes:?}"))?;
        let releases = spec.materialize(&mut GridSimRandom::new(9));
        check(
            releases.len() == nodes.len(),
            format!("{} releases for {} nodes", releases.len(), nodes.len()),
        )?;
        for (pos, r) in releases.iter().enumerate() {
            // Ids are contiguous rank positions; all offsets are 0 (DAG
            // releases are precedence-timed, never clock-timed).
            check(r.gridlet.id == pos, format!("id {} at position {pos}", r.gridlet.id))?;
            check(r.offset == 0.0, format!("offset {} on a dag release", r.offset))?;
            // Positive lengths make a parent's upward rank strictly exceed
            // its children's, so the id order is a topological order.
            for &p in &r.parents {
                check(
                    p < r.gridlet.id,
                    format!("parent {p} does not precede child {}", r.gridlet.id),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dag_materialization_is_bit_identical() {
    use gridsim::gridsim::random::GridSimRandom;
    use gridsim::workload::WorkloadSpec;
    forall(115, 60, gen_dag, |(nodes, edges)| {
        let spec = WorkloadSpec::dag(nodes.clone(), edges.clone());
        let a = spec.materialize(&mut GridSimRandom::new(31));
        let b = spec.materialize(&mut GridSimRandom::new(31));
        for (x, y) in a.iter().zip(&b) {
            check(x.gridlet.id == y.gridlet.id, format!("ids {} vs {}", x.gridlet.id, y.gridlet.id))?;
            check(
                x.gridlet.length_mi.to_bits() == y.gridlet.length_mi.to_bits(),
                format!("lengths {} vs {}", x.gridlet.length_mi, y.gridlet.length_mi),
            )?;
            check(x.parents == y.parents, format!("parents {:?} vs {:?}", x.parents, y.parents))?;
        }
        Ok(())
    });
}

#[test]
fn prop_broken_dags_are_rejected_never_panic() {
    use gridsim::workload::{DagNode, WorkloadSpec};
    forall(
        116,
        60,
        |rng| {
            let (mut nodes, mut edges) = gen_dag(rng);
            match rng.below(4) {
                0 => edges.push(("n0".into(), "no_such_node".into())), // dangling
                1 => {
                    // Cycle (2-cycle, or a self-loop on a 1-node graph).
                    if nodes.len() >= 2 {
                        edges.push(("n0".into(), "n1".into()));
                        edges.push(("n1".into(), "n0".into()));
                    } else {
                        edges.push(("n0".into(), "n0".into()));
                    }
                }
                2 => nodes.push(DagNode::new("n0", 50.0)), // duplicate id
                _ => nodes[0].length_mi = 0.0,             // non-positive length
            }
            (nodes, edges)
        },
        |(nodes, edges)| {
            let spec = WorkloadSpec::dag(nodes.clone(), edges.clone());
            check(
                spec.validate().is_err(),
                format!("corrupted dag must be rejected: {nodes:?} {edges:?}"),
            )
        },
    );
}

#[test]
fn prop_advisor_prefix_exactness() {
    // The documented exactness property behind the XLA two-pass advisor:
    // once a lane takes less than its capacity for *budget* reasons while
    // jobs remain, every costlier lane takes zero.
    forall(
        107,
        300,
        |rng| {
            let n = 2 + rng.below(15) as usize;
            let mut costs: Vec<f64> = (0..n).map(|_| rng.uniform(0.01, 0.5)).collect();
            costs.sort_by(|a, b| a.total_cmp(b));
            AdvisorInput {
                resources: costs
                    .into_iter()
                    .map(|c| ResourceSnapshot { rate_mi: rng.uniform(1.0, 2000.0), cost_per_mi: c })
                    .collect(),
                time_left: rng.uniform(1.0, 2000.0),
                budget_left: rng.uniform(0.0, 10_000.0),
                avg_job_mi: rng.uniform(100.0, 10_000.0),
                jobs: 1 + rng.below(300) as usize,
            }
        },
        |input| {
            let alloc = NativeAdvisor::new().advise(input);
            let allocated: usize = alloc.iter().sum();
            if allocated == input.jobs {
                return Ok(()); // pool exhausted — nothing to check
            }
            for (i, (&n, s)) in alloc.iter().zip(&input.resources).enumerate() {
                let cap = (s.rate_mi * input.time_left / input.avg_job_mi).floor() as usize;
                if n < cap {
                    // Short of capacity with jobs left → budget bound; all
                    // costlier lanes must be zero.
                    let rest: usize = alloc[i + 1..].iter().sum();
                    check(
                        rest == 0,
                        format!("lane {i} budget-truncated but later lanes got {rest}"),
                    )?;
                    return Ok(());
                }
            }
            Ok(())
        },
    );
}
