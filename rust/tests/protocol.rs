//! Protocol-level tests for the remaining GridSimTags services: Gridlet
//! status queries (tag 8), cancellation (tags 12/13), dynamics queries
//! (tag 5) and advance reservations (tags 14/15) — all through real events
//! against a live resource entity.

use gridsim::des::{Ctx, Entity, EntityId, Event, Simulation};
use gridsim::gridsim::messages::ReservationRequest;
use gridsim::gridsim::{
    tags, AllocPolicy, GridInformationService, GridResource, Gridlet, MachineList, Msg,
    ResourceCalendar, ResourceCharacteristics, SpacePolicy,
};

/// Scriptable probe entity: sends a list of (time, tag, msg) to a resource
/// and logs everything it receives.
struct Probe {
    resource: EntityId,
    script: Vec<(f64, i64, Option<Msg>)>,
    pub log: Vec<(f64, i64, Option<Msg>)>,
}

impl Entity<Msg> for Probe {
    fn name(&self) -> &str {
        "probe"
    }
    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        for (at, tag, msg) in self.script.drain(..) {
            ctx.send_delayed(self.resource, at, tag, msg);
        }
    }
    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        let data = ev.data.take();
        self.log.push((ctx.now(), ev.tag, data));
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

fn build(policy: AllocPolicy, pes: usize, script: Vec<(f64, i64, Option<Msg>)>) -> Vec<(f64, i64, Option<Msg>)> {
    let mut sim: Simulation<Msg> = Simulation::new();
    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let machines = match policy {
        AllocPolicy::TimeShared => MachineList::cluster(1, pes, 1.0),
        AllocPolicy::SpaceShared(_) => MachineList::cluster(pes, 1, 1.0),
    };
    let chars = ResourceCharacteristics::new("t", "l", machines, policy, 1.0, 0.0);
    let resource =
        sim.add(Box::new(GridResource::new("R", chars, ResourceCalendar::no_load(), gis)));
    // Patch the probe's script destinations.
    let script = script
        .into_iter()
        .map(|(at, tag, msg)| {
            let msg = msg.map(|m| match m {
                Msg::Gridlet(mut g) => {
                    g.owner = resource + 1; // probe id (added next)
                    Msg::Gridlet(g)
                }
                other => other,
            });
            (at, tag, msg)
        })
        .collect();
    let probe = sim.add(Box::new(Probe { resource, script, log: vec![] }));
    sim.run();
    sim.get::<Probe>(probe).unwrap().log.clone()
}

fn gridlet(id: usize, mi: f64) -> Option<Msg> {
    Some(Msg::Gridlet(Box::new(Gridlet::new(id, mi, 0, 0))))
}

#[test]
fn status_query_reports_exec_queue_and_unknown() {
    // Space-shared 1 PE: G0 runs, G1 queues.
    let log = build(
        AllocPolicy::SpaceShared(SpacePolicy::Fcfs),
        1,
        vec![
            (0.0, tags::GRIDLET_SUBMIT, gridlet(0, 100.0)),
            (0.0, tags::GRIDLET_SUBMIT, gridlet(1, 100.0)),
            (1.0, tags::GRIDLET_STATUS, Some(Msg::GridletId(0))),
            (1.0, tags::GRIDLET_STATUS, Some(Msg::GridletId(1))),
            (1.0, tags::GRIDLET_STATUS, Some(Msg::GridletId(99))),
        ],
    );
    let statuses: Vec<u64> = log
        .iter()
        .filter(|(_, tag, _)| *tag == tags::GRIDLET_STATUS)
        .map(|(_, _, msg)| match msg {
            Some(Msg::Control(c)) => *c,
            other => panic!("unexpected status payload {other:?}"),
        })
        .collect();
    assert_eq!(statuses, vec![2, 1, u64::MAX], "InExec, Queued, unknown");
}

#[test]
fn cancel_returns_gridlet_and_frees_capacity() {
    // Time-shared 1 PE: two jobs sharing; cancel one at t=10.
    let log = build(
        AllocPolicy::TimeShared,
        1,
        vec![
            (0.0, tags::GRIDLET_SUBMIT, gridlet(0, 100.0)),
            (0.0, tags::GRIDLET_SUBMIT, gridlet(1, 100.0)),
            (10.0, tags::GRIDLET_CANCEL, Some(Msg::GridletId(0))),
        ],
    );
    // The cancel reply carries the half-processed gridlet.
    let cancel_reply = log
        .iter()
        .find(|(_, tag, _)| *tag == tags::GRIDLET_CANCEL_REPLY)
        .expect("cancel reply");
    match &cancel_reply.2 {
        Some(Msg::Gridlet(g)) => {
            assert_eq!(g.id, 0);
            assert_eq!(g.status, gridsim::gridsim::GridletStatus::Canceled);
            // Ran 10 units at half share = 5 MI consumed → cpu_time 5.
            assert!((g.cpu_time - 5.0).abs() < 1e-9, "cpu {}", g.cpu_time);
        }
        other => panic!("unexpected cancel payload {other:?}"),
    }
    // The survivor then runs at full rate: 95 MI left at t=10 → done at 105.
    let ret = log
        .iter()
        .find(|(_, tag, _)| *tag == tags::GRIDLET_RETURN)
        .expect("survivor returns");
    assert!((ret.0 - 105.0).abs() < 1e-9, "finish at {}", ret.0);
    // Cancelling an unknown id replies with the bare id.
    let log2 = build(
        AllocPolicy::TimeShared,
        1,
        vec![(0.0, tags::GRIDLET_CANCEL, Some(Msg::GridletId(5)))],
    );
    assert!(matches!(
        log2.iter().find(|(_, t, _)| *t == tags::GRIDLET_CANCEL_REPLY),
        Some((_, _, Some(Msg::GridletId(5))))
    ));
}

#[test]
fn dynamics_query_reports_load() {
    let log = build(
        AllocPolicy::SpaceShared(SpacePolicy::Fcfs),
        1,
        vec![
            (0.0, tags::GRIDLET_SUBMIT, gridlet(0, 100.0)),
            (0.0, tags::GRIDLET_SUBMIT, gridlet(1, 100.0)),
            (1.0, tags::RESOURCE_DYNAMICS, None),
        ],
    );
    let dynamics = log
        .iter()
        .find_map(|(_, tag, msg)| {
            if *tag == tags::RESOURCE_DYNAMICS {
                if let Some(Msg::Dynamics(d)) = msg {
                    return Some(d.clone());
                }
            }
            None
        })
        .expect("dynamics reply");
    assert_eq!(dynamics.in_exec, 1);
    assert_eq!(dynamics.queued, 1);
    assert!(dynamics.available);
    assert_eq!(dynamics.local_load, 0.0);
}

#[test]
fn reservations_accepted_until_capacity_then_withheld() {
    // 2-PE time-shared resource; reserve both PEs over [5, 15).
    let reserve = |id, start, dur, pes| {
        Some(Msg::Reserve(ReservationRequest {
            reservation_id: id,
            start,
            duration: dur,
            num_pe: pes,
        }))
    };
    let log = build(
        AllocPolicy::TimeShared,
        2,
        vec![
            (0.0, tags::RESERVATION_REQUEST, reserve(1, 5.0, 10.0, 1)),
            (0.0, tags::RESERVATION_REQUEST, reserve(2, 5.0, 10.0, 1)),
            // Third overlapping reservation must be rejected (capacity 2).
            (0.0, tags::RESERVATION_REQUEST, reserve(3, 8.0, 2.0, 1)),
            // Non-overlapping is fine.
            (0.0, tags::RESERVATION_REQUEST, reserve(4, 20.0, 5.0, 2)),
            // Work submitted during the reserved window runs on withheld
            // capacity: 10 MI on (2−2→min 1 effective) PE... submit at t=6.
            (6.0, tags::GRIDLET_SUBMIT, gridlet(0, 9.0)),
        ],
    );
    let replies: Vec<(usize, bool)> = log
        .iter()
        .filter_map(|(_, tag, msg)| {
            if *tag == tags::RESERVATION_REPLY {
                if let Some(Msg::ReserveReply(r)) = msg {
                    return Some((r.reservation_id, r.accepted));
                }
            }
            None
        })
        .collect();
    assert_eq!(replies, vec![(1, true), (2, true), (3, false), (4, true)]);
    // The gridlet still completes (withholding clamps to capacity-1), and
    // it must have been slowed by the reservation window (the effective PE
    // count during [6,15) is 1, shared with nobody → full 1-MIPS rate; so
    // here it finishes at 15: 9 MI at rate 1).
    let ret = log.iter().find(|(_, t, _)| *t == tags::GRIDLET_RETURN).expect("return");
    assert!((ret.0 - 15.0).abs() < 1e-6, "finish at {}", ret.0);
}
