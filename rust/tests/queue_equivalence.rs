//! Differential pins for the event-queue swap (PR 7).
//!
//! The kernel's determinism contract says pop order is exactly `(time, seq)`
//! lexicographic. The flat 4-ary key-heap in `des::queue` replaced the
//! original `BinaryHeap<HeapEntry>`; this suite drives the new queue and a
//! reference implementation of the old one through thousands of randomized
//! interleaved push/pop sequences (ties included) and requires identical pop
//! order, then pins a 100k-event ping-storm at the kernel level: stepped and
//! whole runs must produce bit-identical entity logs and event streams.
//!
//! Known, documented edge divergence: the new queue canonicalizes a `-0.0`
//! timestamp to `+0.0` on push (the reference `total_cmp` ordered `-0.0`
//! strictly before `0.0`). No simulation code can observe this — event times
//! are sums of non-negative clocks and delays — so the differential driver
//! sticks to ordinary non-negative times.

use gridsim::des::{Ctx, Entity, Event, EventKind, EventQueue, SimConfig, Simulation};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Reference future-event queue: the pre-swap `BinaryHeap` implementation,
/// ordering by `(total_cmp(time), seq)` reversed into a min-heap.
struct RefQueue {
    heap: BinaryHeap<RefEntry>,
    next_seq: u64,
}

struct RefEntry {
    time: f64,
    seq: u64,
    tag: i64,
}

impl PartialEq for RefEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for RefEntry {}
impl PartialOrd for RefEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest (time, seq).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl RefQueue {
    fn new() -> RefQueue {
        RefQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }
    fn push(&mut self, time: f64, tag: i64) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(RefEntry { time, seq, tag });
        seq
    }
    fn pop(&mut self) -> Option<(f64, u64, i64)> {
        self.heap.pop().map(|e| (e.time, e.seq, e.tag))
    }
    fn pop_before(&mut self, horizon: f64) -> Option<(f64, u64, i64)> {
        match self.heap.peek() {
            Some(e) if e.time <= horizon => self.pop(),
            _ => None,
        }
    }
}

fn ev(time: f64, tag: i64) -> Event<u32> {
    Event { time, seq: 0, src: 0, dst: 0, tag, kind: EventKind::External, data: None }
}

/// Deterministic 64-bit LCG (same constants as `rand`'s Lehmer examples).
struct Lcg(u64);
impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 16
    }
    /// A time from a coarse grid so ties are frequent.
    fn time(&mut self) -> f64 {
        (self.next() % 199) as f64 * 0.5
    }
}

#[test]
fn randomized_interleaved_push_pop_matches_reference() {
    for seed in [3u64, 17, 0xDEAD_BEEF, 0x9E37_79B9_7F4A_7C15] {
        let mut rng = Lcg(seed);
        let mut new_q: EventQueue<u32> = EventQueue::new();
        let mut ref_q = RefQueue::new();
        let mut tag = 0i64;
        for _ in 0..5_000 {
            match rng.next() % 4 {
                // Bias toward pushes so the heaps stay deep.
                0 | 1 => {
                    let t = rng.time();
                    tag += 1;
                    let a = new_q.push(ev(t, tag));
                    let b = ref_q.push(t, tag);
                    assert_eq!(a, b, "seq assignment must match");
                }
                2 => {
                    let got = new_q.pop().map(|e| (e.time, e.seq, e.tag));
                    assert_eq!(got, ref_q.pop(), "pop order diverged (seed {seed})");
                }
                _ => {
                    let h = rng.time();
                    let got = new_q.pop_before(h).map(|e| (e.time, e.seq, e.tag));
                    assert_eq!(got, ref_q.pop_before(h), "pop_before diverged (seed {seed})");
                }
            }
        }
        // Drain: every remaining event must come out in identical order.
        loop {
            let got = new_q.pop().map(|e| (e.time, e.seq, e.tag));
            let want = ref_q.pop();
            assert_eq!(got, want, "drain diverged (seed {seed})");
            if want.is_none() {
                break;
            }
        }
    }
}

#[test]
fn all_ties_drain_fifo_like_reference() {
    let mut new_q: EventQueue<u32> = EventQueue::new();
    let mut ref_q = RefQueue::new();
    for tag in 0..2_000 {
        new_q.push(ev(7.0, tag));
        ref_q.push(7.0, tag);
    }
    for _ in 0..2_000 {
        let got = new_q.pop().map(|e| (e.time, e.seq, e.tag));
        assert_eq!(got, ref_q.pop());
    }
    assert!(new_q.is_empty());
}

// ---------------------------------------------------------------------------
// Kernel-level pin: a 100k-event ping-storm must produce bit-identical
// entity logs and observer streams whether run whole, stepped one event at
// a time, or stepped through bounded run_until windows (the three dispatch
// paths over the new queue).
// ---------------------------------------------------------------------------

/// Storm node: keeps events bouncing to the next ring entity forever and
/// logs every delivery as raw time bits (bit-identity, not approximate).
struct Storm {
    name: String,
    next: usize,
    log: Vec<(u64, u64)>,
}

impl Entity<u32> for Storm {
    fn name(&self) -> &str {
        &self.name
    }
    fn on_start(&mut self, ctx: &mut Ctx<u32>) {
        for k in 0..4u64 {
            ctx.send_delayed(self.next, 0.5 + k as f64 * 0.25, 0, None);
        }
    }
    fn on_event(&mut self, ctx: &mut Ctx<u32>, ev: Event<u32>) {
        self.log.push((ctx.now().to_bits(), ev.seq));
        ctx.send_delayed(self.next, 1.0, 0, None);
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

const STORM_EVENTS: u64 = 100_000;
const STORM_ENTITIES: usize = 16;

fn storm_sim() -> Simulation<u32> {
    let mut sim =
        Simulation::with_config(SimConfig { max_time: f64::INFINITY, max_events: STORM_EVENTS });
    for i in 0..STORM_ENTITIES {
        sim.add(Box::new(Storm {
            name: format!("S{i}"),
            next: (i + 1) % STORM_ENTITIES,
            log: vec![],
        }));
    }
    sim.set_observer(Box::new(|_| {}));
    sim
}

fn storm_logs(sim: &Simulation<u32>) -> Vec<Vec<(u64, u64)>> {
    (0..STORM_ENTITIES)
        .map(|i| sim.get::<Storm>(i).unwrap().log.clone())
        .collect()
}

#[test]
fn pingstorm_100k_bit_identical_across_dispatch_paths() {
    // Whole run.
    let mut whole = storm_sim();
    let end_whole = whole.run();
    assert_eq!(whole.events_processed(), STORM_EVENTS);

    // Stepped one event at a time.
    let mut stepped = storm_sim();
    stepped.init();
    while stepped.step().is_some() {}
    let end_stepped = stepped.finalize();

    // Bounded run_until windows (exercises pop_before's horizon path).
    let mut windowed = storm_sim();
    let mut horizon = 0.0;
    while !windowed.is_idle() {
        horizon += 97.0;
        windowed.run_until(horizon);
    }
    let end_windowed = windowed.finalize();

    assert_eq!(end_whole.to_bits(), end_stepped.to_bits());
    assert_eq!(end_whole.to_bits(), end_windowed.to_bits());
    assert_eq!(whole.events_processed(), stepped.events_processed());
    assert_eq!(whole.events_processed(), windowed.events_processed());
    let logs = storm_logs(&whole);
    assert_eq!(logs, storm_logs(&stepped), "stepped logs must be bit-identical");
    assert_eq!(logs, storm_logs(&windowed), "windowed logs must be bit-identical");
    assert_eq!(
        logs.iter().map(Vec::len).sum::<usize>() as u64,
        STORM_EVENTS,
        "every dispatched event must be logged exactly once"
    );
}

#[test]
fn pingstorm_event_stream_matches_reference_order() {
    // Replay the observer's (time, seq) stream against the reference queue
    // discipline: times never decrease, and seqs are unique.
    use std::sync::{Arc, Mutex};
    let seen: Arc<Mutex<Vec<(u64, u64)>>> = Arc::new(Mutex::new(vec![]));
    let sink = seen.clone();
    let mut sim = storm_sim();
    sim.set_observer(Box::new(move |e: &Event<u32>| {
        sink.lock().unwrap().push((e.time.to_bits(), e.seq));
    }));
    sim.run();
    let seen = seen.lock().unwrap();
    assert_eq!(seen.len() as u64, STORM_EVENTS);
    for w in seen.windows(2) {
        let (t0, s0) = w[0];
        let (t1, s1) = w[1];
        assert!(
            f64::from_bits(t0) < f64::from_bits(t1) || (t0 == t1 && s0 < s1),
            "dispatch order must be strictly increasing in (time, seq)"
        );
    }
}
