//! Market layer through the full stack: utilization-driven pricing,
//! spot-tier preemption, charge-at-execution accounting.
//!
//! The scenarios use trace workloads with explicit release offsets so the
//! demand trajectory (and hence the price trajectory) is exact: a second
//! job arriving mid-run pushes utilization across a step threshold, the
//! price spikes, and a spot bid placed between the idle and spiked
//! discounted prices is crossed deterministically.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::AllocPolicy;
use gridsim::market::{MarketSpec, PriceModel};
use gridsim::scenario::{ResourceSpec, Scenario, ScenarioReport, UserSpec};
use gridsim::session::GridSession;
use gridsim::workload::{TraceJob, WorkloadSpec};

fn resource(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "t".into(),
        os: "l".into(),
        machines: 1,
        pes_per_machine: pes,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

fn run(scenario: &Scenario) -> ScenarioReport {
    GridSession::new(scenario).run_to_completion()
}

/// Two 2000-MI jobs released 5 time units apart — the second arrival is
/// what crosses the utilization step.
fn staggered_pair() -> WorkloadSpec {
    WorkloadSpec::trace(vec![
        TraceJob::new(0.0, 2_000.0, 1, 1),
        TraceJob::new(5.0, 2_000.0, 1, 1),
    ])
}

/// Step tariff on a 2-PE resource: 2 G$ idle, 10 G$ once both PEs are
/// taken (utilization 1.0 ≥ 0.75).
fn step_model() -> PriceModel {
    PriceModel::UtilizationStep {
        base: 2.0,
        steps: vec![(0.75, 10.0)],
        floor: 0.0,
        cap: f64::INFINITY,
    }
}

/// The spot e2e: a bidding user rents the discounted spot tier, the second
/// arrival spikes the price past the bid, both jobs come back `Preempted`
/// (not `Lost`), partial work is charged at the rate actually paid, and
/// the resubmitted jobs finish on the on-demand resource.
#[test]
fn price_spike_preempts_spot_jobs_which_finish_on_demand() {
    let build = || {
        Scenario::builder()
            .resource(resource("SPOT", 2, 100.0, 2.0))
            .resource(resource("DEMAND", 2, 100.0, 4.0))
            .user(
                UserSpec::new(
                    ExperimentSpec::new(staggered_pair())
                        .deadline(1_000.0)
                        .budget(10_000.0)
                        .optimization(Optimization::Cost),
                )
                .max_spot_price(2.5),
            )
            .market(
                MarketSpec::new()
                    .pricing_for("SPOT", step_model())
                    .spot_for("SPOT", 0.5),
            )
            .seed(11)
            .build()
    };
    let report = run(&build());
    assert!(report.all_finished());
    let u = &report.users[0];
    assert_eq!(u.gridlets_total, 2);
    assert_eq!(u.gridlets_completed, 2, "preempted jobs must be rescued on demand");

    // Preemption is its own ledger: nothing was lost to failures, nothing
    // abandoned, and both evictions flowed through the resubmission policy.
    assert_eq!(u.gridlets_preempted, 2, "both resident spot jobs outbid");
    assert_eq!(u.gridlets_lost, 0);
    assert_eq!(u.gridlets_abandoned, 0);
    assert_eq!(u.gridlets_resubmitted, 2);

    // Spot-banned jobs retry on demand only: the spot tier completes
    // nothing, the on-demand resource completes everything.
    let spot = u.per_resource.iter().find(|r| r.name == "SPOT").unwrap();
    let demand = u.per_resource.iter().find(|r| r.name == "DEMAND").unwrap();
    assert_eq!(spot.gridlets_completed, 0);
    assert_eq!(demand.gridlets_completed, 2);

    // Partial spot work IS charged (unlike `Lost`), at the discounted rate
    // actually paid: the first job ran ~5 time units at 0.5 × 2 G$ before
    // the spike, so the spot bill is positive but far below one full job
    // at the undiscounted base price (20 PE-time × 2 G$).
    assert!(spot.budget_spent > 0.0, "preempted partial work must be charged");
    assert!(
        spot.budget_spent < 40.0,
        "partial discounted charge, got {}",
        spot.budget_spent
    );
    assert!(spot.budget_spent < demand.budget_spent);

    // Total cost equals the sum of the per-resource ledgers.
    let ledger: f64 = u.per_resource.iter().map(|r| r.budget_spent).sum();
    assert!(
        (u.budget_spent - ledger).abs() < 1e-9,
        "budget_spent {} != per-resource sum {ledger}",
        u.budget_spent
    );

    // And the whole episode is deterministic.
    let again = run(&build());
    assert_eq!(report.events, again.events);
    assert_eq!(
        report.users[0].budget_spent.to_bits(),
        again.users[0].budget_spent.to_bits()
    );
}

/// A user with no bid on the same market is never preempted — it pays the
/// full dynamic price instead, so congestion makes the same workload cost
/// more than the static tariff would.
#[test]
fn no_bid_user_pays_dynamic_price_and_is_never_preempted() {
    let scenario = Scenario::builder()
        .resource(resource("R0", 2, 100.0, 2.0))
        .user(
            ExperimentSpec::new(staggered_pair())
                .deadline(1_000.0)
                .budget(10_000.0)
                .optimization(Optimization::Cost),
        )
        .market(MarketSpec::new().pricing_for("R0", step_model()))
        .seed(11)
        .build();
    let report = run(&scenario);
    assert!(report.all_finished());
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 2);
    assert_eq!(u.gridlets_preempted, 0, "no bid, no preemption");
    assert_eq!(u.gridlets_lost, 0);
    // 2 × 2000 MI at 100 MIPS is exactly 40 PE-time: the static tariff
    // would bill 80 G$; the overlapping window at the 10 G$ step must push
    // the execution-time-averaged bill well past that.
    assert!(
        u.budget_spent > 100.0,
        "dynamic congestion price must exceed the 80 G$ static bill, got {}",
        u.budget_spent
    );
}

/// An affordable bid on a flat (never-crossing) spot tier is a pure
/// discount: everything completes on spot, nothing is preempted, and the
/// bill is exactly the discounted static price.
#[test]
fn uncontested_spot_tier_is_a_pure_discount() {
    let scenario = Scenario::builder()
        .resource(resource("SPOT", 2, 100.0, 2.0))
        .user(
            UserSpec::new(
                ExperimentSpec::new(staggered_pair())
                    .deadline(1_000.0)
                    .budget(10_000.0)
                    .optimization(Optimization::Cost),
            )
            .max_spot_price(2.5),
        )
        // Static pricing: the spot price never moves, the bid is never
        // crossed.
        .market(MarketSpec::new().spot_for("SPOT", 0.5))
        .seed(11)
        .build();
    let report = run(&scenario);
    assert!(report.all_finished());
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 2);
    assert_eq!(u.gridlets_preempted, 0);
    // 40 PE-time at 0.5 × 2 G$ = 40 G$ exactly (Static prices settle with
    // no averaging arithmetic).
    assert!(
        (u.budget_spent - 40.0).abs() < 1e-9,
        "discounted static bill must be exact, got {}",
        u.budget_spent
    );
}
