//! Edge-case coverage across the stack: degenerate grids, extreme
//! parameters, and comparative scheduler behaviour.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::{AllocPolicy, SpacePolicy};
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::session::GridSession;

fn spec(name: &str, pes: usize, mips: f64, price: f64, policy: AllocPolicy) -> ResourceSpec {
    let (machines, per) = match policy {
        AllocPolicy::TimeShared => (1, pes),
        AllocPolicy::SpaceShared(_) => (pes, 1),
    };
    ResourceSpec {
        name: name.into(),
        arch: "t".into(),
        os: "l".into(),
        machines,
        pes_per_machine: per,
        mips_per_pe: mips,
        policy,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

#[test]
fn single_gridlet_single_pe() {
    let scenario = Scenario::builder()
        .resource(spec("R", 1, 100.0, 1.0, AllocPolicy::TimeShared))
        .user(ExperimentSpec::task_farm(1, 1_000.0, 0.0).deadline(100.0).budget(100.0))
        .seed(1)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 1);
    // 1000 MI / 100 MIPS = 10 time units, 10 G$ at 1 G$/PE-time.
    assert!((r.users[0].budget_spent - 10.0).abs() < 1e-9);
    assert!((r.users[0].finish_time - 10.0).abs() < 1e-9);
}

#[test]
fn enormous_gridlet_blows_deadline_not_the_simulator() {
    let scenario = Scenario::builder()
        .resource(spec("R", 1, 1.0, 1.0, AllocPolicy::TimeShared))
        .user(ExperimentSpec::task_farm(1, 1e9, 0.0).deadline(10.0).budget(1e12))
        .seed(1)
        .max_time(1e8)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    // Either it was never dispatched (capacity 0 by deadline) or it came
    // back long after the deadline; both are acceptable terminations.
    assert!(r.users[0].gridlets_completed <= 1);
    assert!(r.end_time < 1e8, "must terminate before the hard cap");
}

#[test]
fn many_tiny_gridlets() {
    let scenario = Scenario::builder()
        .resource(spec("R", 4, 1_000.0, 1.0, AllocPolicy::TimeShared))
        .user(ExperimentSpec::task_farm(500, 10.0, 0.0).deadline(1_000.0).budget(1e6))
        .seed(2)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 500);
}

#[test]
fn identical_resources_tie_breaking_is_deterministic() {
    let build = || {
        Scenario::builder()
            .resource(spec("A", 2, 100.0, 1.0, AllocPolicy::TimeShared))
            .resource(spec("B", 2, 100.0, 1.0, AllocPolicy::TimeShared))
            .resource(spec("C", 2, 100.0, 1.0, AllocPolicy::TimeShared))
            .user(ExperimentSpec::task_farm(30, 1_000.0, 0.1).deadline(1_000.0).budget(1e6))
            .seed(3)
            .build()
    };
    let a = GridSession::new(&build()).run_to_completion();
    let b = GridSession::new(&build()).run_to_completion();
    for (x, y) in a.users[0].per_resource.iter().zip(&b.users[0].per_resource) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.gridlets_completed, y.gridlets_completed);
    }
}

#[test]
fn space_shared_grid_completes_experiment() {
    // A grid made only of clusters (queueing systems) works end to end.
    let scenario = Scenario::builder()
        .resource(spec("C1", 8, 400.0, 2.0, AllocPolicy::SpaceShared(SpacePolicy::Fcfs)))
        .resource(spec("C2", 4, 400.0, 1.0, AllocPolicy::SpaceShared(SpacePolicy::Sjf)))
        .resource(spec("C3", 4, 400.0, 3.0, AllocPolicy::SpaceShared(SpacePolicy::BackfillEasy)))
        .user(
            ExperimentSpec::task_farm(60, 5_000.0, 0.10)
                .deadline(2_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(4)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 60);
    // Cost-opt prefers the cheapest cluster (C2).
    let c2 = r.users[0].per_resource.iter().find(|p| p.name == "C2").unwrap();
    assert!(c2.gridlets_completed >= 30, "cheapest cluster dominates: {}", c2.gridlets_completed);
}

#[test]
fn mixed_time_and_space_shared_grid() {
    let scenario = Scenario::builder()
        .resource(spec("SMP", 8, 500.0, 4.0, AllocPolicy::TimeShared))
        .resource(spec("Cluster", 16, 400.0, 2.0, AllocPolicy::SpaceShared(SpacePolicy::Fcfs)))
        .user(
            ExperimentSpec::task_farm(100, 8_000.0, 0.10)
                .deadline(500.0)
                .budget(1e6)
                .optimization(Optimization::Time),
        )
        .seed(5)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 100);
    // Time-opt should use both.
    assert!(r.users[0].per_resource.iter().all(|p| p.gridlets_completed > 0));
}

#[test]
fn policy_ablation_orderings_hold() {
    // The §4.2.2 trade-off, asserted (not just printed by bench_policies):
    // with slack, time-opt is no slower than cost-opt and cost-opt is no
    // more expensive than time-opt.
    let run = |opt| {
        let scenario = Scenario::builder()
            .resources(gridsim::config::testbed::wwg_testbed())
            .user(
                ExperimentSpec::task_farm(80, 10_000.0, 0.10)
                    .deadline(3_100.0)
                    .budget(60_000.0)
                    .optimization(opt),
            )
            .seed(6)
            .build();
        let r = GridSession::new(&scenario).run_to_completion();
        let u = &r.users[0];
        assert_eq!(u.gridlets_completed, 80, "{opt:?} must finish with slack");
        (u.finish_time - u.start_time, u.budget_spent)
    };
    let (t_cost, s_cost) = run(Optimization::Cost);
    let (t_time, s_time) = run(Optimization::Time);
    let (t_ct, s_ct) = run(Optimization::CostTime);
    assert!(t_time <= t_cost, "time-opt no slower ({t_time} vs {t_cost})");
    assert!(s_cost <= s_time, "cost-opt no dearer ({s_cost} vs {s_time})");
    // Cost-time: at most cost-opt's time, at most time-opt's... cost lies
    // between (inclusive, with small numeric slack).
    assert!(t_ct <= t_cost * 1.05, "cost-time not slower than cost ({t_ct} vs {t_cost})");
    assert!(s_ct <= s_time * 1.05, "cost-time not dearer than time ({s_ct} vs {s_time})");
}

#[test]
fn hundred_resources_scale() {
    let mut builder = Scenario::builder();
    for i in 0..100 {
        builder = builder.resource(spec(
            &format!("R{i}"),
            2,
            100.0 + i as f64,
            1.0 + (i % 7) as f64,
            AllocPolicy::TimeShared,
        ));
    }
    let scenario = builder
        .user(ExperimentSpec::task_farm(200, 2_000.0, 0.1).deadline(2_000.0).budget(1e6))
        .seed(7)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 200);
}

#[test]
fn single_node_dag_equals_one_job_explicit() {
    // A workflow with no edges has no parented releases, so the gating
    // machinery must stay completely dormant: same events, same clock,
    // same bill as the equivalent explicit one-job workload.
    use gridsim::workload::{DagNode, JobSpec, WorkloadSpec};
    let build = |w: WorkloadSpec| {
        Scenario::builder()
            .resource(spec("R", 2, 100.0, 1.0, AllocPolicy::TimeShared))
            .user(ExperimentSpec::new(w).deadline(1_000.0).budget(1e6))
            .seed(9)
            .build()
    };
    let dag = build(WorkloadSpec::dag(vec![DagNode::new("only", 1_000.0)], vec![]));
    let explicit = build(WorkloadSpec::explicit(vec![JobSpec {
        length_mi: 1_000.0,
        input_bytes: 1000,
        output_bytes: 500,
    }]));
    let a = GridSession::new(&dag).run_to_completion();
    let b = GridSession::new(&explicit).run_to_completion();
    assert_eq!(a.users[0].gridlets_completed, 1);
    assert_eq!(a.events, b.events, "no extra notices for an edgeless workflow");
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits());
    assert_eq!(a.users[0].finish_time.to_bits(), b.users[0].finish_time.to_bits());
    assert_eq!(a.users[0].budget_spent.to_bits(), b.users[0].budget_spent.to_bits());
}

#[test]
fn empty_dag_is_rejected() {
    use gridsim::workload::WorkloadSpec;
    let err = WorkloadSpec::dag(vec![], vec![]).validate().unwrap_err().to_string();
    assert!(err.contains("at least one node"), "{err}");
}

#[test]
fn dag_inside_concat_and_mix_runs_end_to_end() {
    // Composition remaps workflow parent ids into the combined numbering,
    // so a chain buried in a concat or a mix still gates correctly and the
    // whole combined workload completes.
    use gridsim::workload::{DagNode, WorkloadSpec};
    let chain = || {
        WorkloadSpec::dag(
            vec![DagNode::new("first", 1_000.0), DagNode::new("second", 2_000.0)],
            vec![("first".into(), "second".into())],
        )
    };
    let run = |w: WorkloadSpec, total: usize| {
        let scenario = Scenario::builder()
            .resource(spec("R", 4, 200.0, 1.0, AllocPolicy::TimeShared))
            .user(ExperimentSpec::new(w).deadline(1e5).budget(1e6))
            .seed(10)
            .build();
        let r = GridSession::new(&scenario).run_to_completion();
        assert_eq!(r.users[0].gridlets_total, total);
        assert_eq!(r.users[0].gridlets_completed, total);
    };
    run(
        WorkloadSpec::concat(vec![chain(), WorkloadSpec::task_farm(3, 500.0, 0.0)]),
        5,
    );
    run(WorkloadSpec::mix(vec![chain(), WorkloadSpec::task_farm(3, 500.0, 0.0)]), 5);
}

#[test]
#[should_panic(expected = "online_arrivals cannot wrap a dag")]
fn online_arrivals_cannot_wrap_a_dag() {
    // Precedence, not an arrival process, times a workflow's releases —
    // the constructor rejects the combination just like the JSON loader.
    use gridsim::workload::{ArrivalProcess, DagNode, WorkloadSpec};
    let dag = WorkloadSpec::dag(vec![DagNode::new("a", 1_000.0)], vec![]);
    let _ = WorkloadSpec::online(dag, ArrivalProcess::Fixed { interval: 5.0 });
}

#[test]
fn online_arrivals_validation_rejects_nested_dag() {
    // The same rule holds when the wrapper is assembled without the
    // constructor (e.g. by hand or through deserialization) and the dag
    // hides inside a concat part.
    use gridsim::workload::{ArrivalProcess, DagNode, WorkloadSpec};
    let dag = WorkloadSpec::dag(vec![DagNode::new("a", 1_000.0)], vec![]);
    let wrapped = WorkloadSpec::OnlineArrivals {
        workload: Box::new(WorkloadSpec::concat(vec![
            WorkloadSpec::task_farm(2, 500.0, 0.0),
            dag,
        ])),
        arrivals: ArrivalProcess::Fixed { interval: 5.0 },
    };
    let err = wrapped.validate().unwrap_err().to_string();
    assert!(err.contains("cannot wrap a dag"), "{err}");
}

#[test]
fn zero_variation_workload_is_uniform() {
    let scenario = Scenario::builder()
        .resource(spec("R", 2, 100.0, 1.0, AllocPolicy::TimeShared))
        .user(ExperimentSpec::task_farm(10, 1_000.0, 0.0).deadline(1_000.0).budget(1e6))
        .seed(8)
        .build();
    let r = GridSession::new(&scenario).run_to_completion();
    assert_eq!(r.users[0].gridlets_completed, 10);
    // All jobs identical → total spend is exactly 10 × (1000/100) × 1 G$.
    assert!((r.users[0].budget_spent - 100.0).abs() < 1e-9);
}
