//! Network-delay semantics and failure injection through the full stack.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::des::{Ctx, Entity, EntityId, Event, Simulation};
use gridsim::gridsim::{
    tags, AllocPolicy, Gridlet, GridInformationService, GridResource, MachineList, Msg,
    ResourceCalendar, ResourceCharacteristics,
};
use gridsim::scenario::{NetworkSpec, ResourceSpec, Scenario};
use gridsim::session::GridSession;

fn spec(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "t".into(),
        os: "l".into(),
        machines: 1,
        pes_per_machine: pes,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

#[test]
fn baud_rate_network_slows_completion() {
    let build = |network: NetworkSpec| {
        Scenario::builder()
            .resource(spec("R0", 2, 100.0, 1.0))
            .user(
                ExperimentSpec::task_farm(10, 1_000.0, 0.0)
                    .deadline(10_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            .seed(3)
            .network(network)
            .build()
    };
    let fast = GridSession::new(&build(NetworkSpec::Instantaneous)).run_to_completion();
    let slow = GridSession::new(&build(NetworkSpec::Baud { default_rate: 9600.0, latency: 0.1 }))
        .run_to_completion();
    assert_eq!(fast.users[0].gridlets_completed, 10);
    assert_eq!(slow.users[0].gridlets_completed, 10);
    let t_fast = fast.users[0].finish_time - fast.users[0].start_time;
    let t_slow = slow.users[0].finish_time - slow.users[0].start_time;
    assert!(
        t_slow > t_fast,
        "staging at 9600 baud must cost time: {t_slow} vs {t_fast}"
    );
}

#[test]
fn staging_delay_scales_with_file_size() {
    let build = |input_bytes: u64| {
        let e = ExperimentSpec::task_farm(5, 1_000.0, 0.0)
            .deadline(100_000.0)
            .budget(1e6)
            .staging(input_bytes, 500);
        Scenario::builder()
            .resource(spec("R0", 1, 100.0, 1.0))
            .user(e)
            .seed(3)
            .network(NetworkSpec::Baud { default_rate: 9600.0, latency: 0.0 })
            .build()
    };
    let small = GridSession::new(&build(100)).run_to_completion();
    let large = GridSession::new(&build(100_000)).run_to_completion();
    let t_small = small.users[0].finish_time;
    let t_large = large.users[0].finish_time;
    assert!(
        t_large > t_small + 50.0,
        "100 KB inputs at 9600 baud are slow: {t_large} vs {t_small}"
    );
}

/// Hand-driven failure pulse for the low-level resource test below — the
/// scenario-level path goes through [`gridsim::faults::FaultInjector`]
/// instead (see `broker_reroutes_lost_gridlets_via_scenario_faults`).
struct FaultPulse {
    target: EntityId,
    fail_at: f64,
    recover_at: Option<f64>,
}

impl Entity<Msg> for FaultPulse {
    fn name(&self) -> &str {
        "fault-injector"
    }
    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        ctx.send_delayed(self.target, self.fail_at, tags::RESOURCE_FAIL, None);
        if let Some(t) = self.recover_at {
            ctx.send_delayed(self.target, t, tags::RESOURCE_RECOVER, None);
        }
    }
    fn on_event(&mut self, _ctx: &mut Ctx<Msg>, _ev: Event<Msg>) {}
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Driver that submits jobs directly and counts outcomes.
struct Submitter {
    resource: EntityId,
    n: usize,
    pub success: usize,
    pub failed: usize,
    pub lost: usize,
}

impl Entity<Msg> for Submitter {
    fn name(&self) -> &str {
        "submitter"
    }
    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        for i in 0..self.n {
            let mut g = Gridlet::new(i, 100.0, 0, 0);
            g.owner = ctx.me();
            ctx.send_delayed(
                self.resource,
                i as f64,
                tags::GRIDLET_SUBMIT,
                Some(Msg::Gridlet(Box::new(g))),
            );
        }
    }
    fn on_event(&mut self, _ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        if ev.tag == tags::GRIDLET_RETURN {
            let Msg::Gridlet(g) = ev.take_data() else { panic!() };
            match g.status {
                gridsim::gridsim::GridletStatus::Success => self.success += 1,
                gridsim::gridsim::GridletStatus::Failed => self.failed += 1,
                gridsim::gridsim::GridletStatus::Lost => self.lost += 1,
                other => panic!("unexpected status {other:?}"),
            }
        }
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[test]
fn resource_failure_bounces_jobs_and_recovery_restores() {
    let mut sim: Simulation<Msg> = Simulation::new();
    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let chars = ResourceCharacteristics::new(
        "t",
        "l",
        MachineList::cluster(1, 1, 10.0),
        AllocPolicy::TimeShared,
        1.0,
        0.0,
    );
    let resource = sim.add(Box::new(GridResource::new(
        "R",
        chars,
        ResourceCalendar::no_load(),
        gis,
    )));
    // 20 jobs at t=0..19; fail at t=5.5, recover at t=12.5. Jobs in flight
    // at 5.5 drain as Lost; submissions in [5.5, 12.5) bounce as Failed;
    // later ones succeed — three distinct statuses for three fates.
    sim.add(Box::new(FaultPulse { target: resource, fail_at: 5.5, recover_at: Some(12.5) }));
    let submitter = sim.add(Box::new(Submitter { resource, n: 20, success: 0, failed: 0, lost: 0 }));
    sim.run();
    let s = sim.get::<Submitter>(submitter).unwrap();
    assert_eq!(s.success + s.failed + s.lost, 20, "every job gets an answer");
    assert!(s.lost >= 5, "jobs in flight at the crash drain as Lost: {}", s.lost);
    assert!(s.failed >= 6, "submissions during the outage bounce as Failed: {}", s.failed);
    assert!(s.success >= 6, "jobs after recovery succeed: {}", s.success);
}

#[test]
fn broker_reroutes_lost_gridlets_via_scenario_faults() {
    // Two resources; the cheap one goes down at t=3 and never comes back
    // (a trace process with one long downtime window). Entirely
    // scenario-driven: the session builds the fault injector from the
    // `faults` spec, the broker re-routes the drained Gridlets to the
    // survivor under its default retry policy, and everything finishes.
    use gridsim::faults::{FaultProcess, FaultsSpec};
    let scenario = Scenario::builder()
        .resource(spec("Fragile", 2, 200.0, 1.0)) // cheap → preferred
        .resource(spec("Stable", 2, 200.0, 2.0))
        .user(
            ExperimentSpec::task_farm(20, 1_000.0, 0.0)
                .deadline(10_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(5)
        .faults(FaultsSpec::default().override_for(
            "Fragile",
            FaultProcess::Trace { intervals: vec![(3.0, 1e8)] },
        ))
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 20, "all Gridlets complete despite the failure");
    assert!(u.gridlets_lost >= 1, "jobs in flight at t=3 drain as Lost");
    assert_eq!(
        u.gridlets_resubmitted, u.gridlets_lost,
        "the default retry policy resubmits every loss"
    );
    assert_eq!(u.gridlets_abandoned, 0, "nothing abandoned under retry");
    let stable = u.per_resource.iter().find(|r| r.name == "Stable").unwrap();
    assert!(stable.gridlets_completed >= 16, "survivor does the work: {}", stable.gridlets_completed);
}

#[test]
fn local_load_calendar_slows_processing() {
    let mut with_load = spec("R0", 1, 100.0, 1.0);
    with_load.calendar = Some(ResourceCalendar::business(9.0, 0.8, 0.8, 0.8));
    let build = |r: ResourceSpec| {
        Scenario::builder()
            .resource(r)
            .user(ExperimentSpec::task_farm(5, 1_000.0, 0.0).deadline(1e6).budget(1e9))
            .seed(4)
            .build()
    };
    let loaded = GridSession::new(&build(with_load)).run_to_completion();
    let free = GridSession::new(&build(spec("R0", 1, 100.0, 1.0))).run_to_completion();
    let t_loaded = loaded.users[0].finish_time;
    let t_free = free.users[0].finish_time;
    assert!(
        t_loaded > t_free * 2.0,
        "80% background load must slow things ~5x: {t_loaded} vs {t_free}"
    );
}
