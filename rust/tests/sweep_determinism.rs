//! Determinism regression: the same `SweepSpec` run with `--jobs 1` and
//! `--jobs 8` (and twice at the same jobs count) produces byte-identical
//! CSV output — the sweep engine's core contract. Thread count and
//! completion order must leak into nothing: not cell order, not seeds, not
//! a single formatted float.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::{AllocPolicy, SpacePolicy};
use gridsim::output::sweep::{aggregate_csv, long_csv};
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::sweep::{run_sweep, SweepSpec};

fn resource(name: &str, policy: AllocPolicy, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    let (machines, per) = match policy {
        AllocPolicy::TimeShared => (1, pes),
        AllocPolicy::SpaceShared(_) => (pes, 1),
    };
    ResourceSpec {
        name: name.into(),
        arch: "test".into(),
        os: "linux".into(),
        machines,
        pes_per_machine: per,
        mips_per_pe: mips,
        policy,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// A grid that exercises every axis: mixed resource kinds, a policy axis,
/// a user-count axis, replications — 2·2·2·2·2·2 = 64 cells of small runs.
fn spec() -> SweepSpec {
    let base = Scenario::builder()
        .resource(resource("T0", AllocPolicy::TimeShared, 2, 100.0, 1.0))
        .resource(resource("T1", AllocPolicy::TimeShared, 2, 120.0, 3.0))
        .resource(resource("S0", AllocPolicy::SpaceShared(SpacePolicy::Fcfs), 3, 80.0, 2.0))
        .user(
            ExperimentSpec::task_farm(8, 600.0, 0.10)
                .deadline(5_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(41)
        .build();
    SweepSpec::over(base)
        .deadlines(vec![40.0, 5_000.0])
        .budgets(vec![2.0, 1e6])
        .user_counts(vec![1, 3])
        .policies(vec![Optimization::Cost, Optimization::Time])
        .resource_subsets(vec![
            vec!["T0".into(), "T1".into(), "S0".into()],
            vec!["T0".into(), "S0".into()],
        ])
        .replications(2)
}

#[test]
fn csv_output_is_byte_identical_across_jobs_counts() {
    let spec = spec();
    assert_eq!(spec.cell_count(), 64);

    let jobs1 = run_sweep(&spec, 1).expect("jobs=1");
    let jobs8 = run_sweep(&spec, 8).expect("jobs=8");
    let jobs8_again = run_sweep(&spec, 8).expect("jobs=8 rerun");

    let long1 = long_csv(&spec, &jobs1).to_string();
    let long8 = long_csv(&spec, &jobs8).to_string();
    let long8b = long_csv(&spec, &jobs8_again).to_string();
    assert_eq!(long1, long8, "long CSV differs between --jobs 1 and --jobs 8");
    assert_eq!(long8, long8b, "long CSV differs between identical --jobs 8 runs");

    let agg1 = aggregate_csv(&spec, &jobs1).to_string();
    let agg8 = aggregate_csv(&spec, &jobs8).to_string();
    let agg8b = aggregate_csv(&spec, &jobs8_again).to_string();
    assert_eq!(agg1, agg8, "aggregate CSV differs between --jobs 1 and --jobs 8");
    assert_eq!(agg8, agg8b, "aggregate CSV differs between identical --jobs 8 runs");

    // Sanity on the content itself: starved cells complete less than funded
    // ones, so the grid is not trivially constant.
    assert!(long1.lines().count() > 64, "one row per (cell, user) plus header");
    let funded = jobs1
        .outcomes
        .iter()
        .filter(|o| o.cell.budget == Some(1e6) && o.cell.deadline == Some(5_000.0))
        .map(|o| o.report.mean_completed())
        .sum::<f64>();
    let starved = jobs1
        .outcomes
        .iter()
        .filter(|o| o.cell.budget == Some(2.0))
        .map(|o| o.report.mean_completed())
        .sum::<f64>();
    assert!(funded > starved, "funded {funded} vs starved {starved}");
}

#[test]
fn faulted_sweep_is_byte_identical_across_jobs_counts() {
    // Stochastic failure–repair on every resource plus an MTBF-scaling
    // axis: the fault schedules come from per-resource RNG streams seeded
    // off the scenario seed, so they must be exactly as jobs-invariant as
    // everything else in the cell.
    use gridsim::broker::{BrokerConfig, ResubmissionPolicy};
    use gridsim::faults::{FaultProcess, FaultsSpec};
    let base = Scenario::builder()
        .resource(resource("T0", AllocPolicy::TimeShared, 2, 100.0, 1.0))
        .resource(resource("T1", AllocPolicy::TimeShared, 2, 120.0, 3.0))
        .resource(resource("S0", AllocPolicy::SpaceShared(SpacePolicy::Fcfs), 3, 80.0, 2.0))
        .user(
            // Long enough jobs that every run spans several mean uptimes —
            // the loss assertions below need failures to actually land.
            ExperimentSpec::task_farm(10, 3_000.0, 0.10)
                .deadline(5_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(41)
        .faults(
            FaultsSpec::all(FaultProcess::Exponential { mtbf: 300.0, mttr: 40.0 }).override_for(
                "S0",
                FaultProcess::Weibull { mtbf: 250.0, mttr: 30.0, shape: 1.5 },
            ),
        )
        .broker_config(BrokerConfig {
            resubmission: ResubmissionPolicy::RetryWithBackoff { max_attempts: 3, backoff: 5.0 },
            ..BrokerConfig::default()
        })
        .build();
    let spec = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time])
        .mtbf_scalings(vec![0.5, 1.0, 2.0])
        .replications(2);
    assert_eq!(spec.cell_count(), 12);

    let jobs1 = run_sweep(&spec, 1).expect("jobs=1");
    let jobs4 = run_sweep(&spec, 4).expect("jobs=4");
    let long1 = long_csv(&spec, &jobs1).to_string();
    let long4 = long_csv(&spec, &jobs4).to_string();
    assert_eq!(long1, long4, "faulted long CSV differs between --jobs 1 and --jobs 4");
    assert_eq!(
        aggregate_csv(&spec, &jobs1).to_string(),
        aggregate_csv(&spec, &jobs4).to_string(),
        "faulted aggregate CSV differs between --jobs 1 and --jobs 4"
    );

    // The faults actually bite, and CRN keeps severity ordered: the harsh
    // scaling loses at least as much work as the gentle one.
    let lost_at = |s: f64| {
        jobs1
            .outcomes
            .iter()
            .filter(|o| o.cell.mtbf_scaling == Some(s))
            .map(|o| o.report.total_lost())
            .sum::<usize>()
    };
    assert!(lost_at(0.5) > 0, "harsh cells must lose Gridlets");
    assert!(lost_at(0.5) >= lost_at(2.0), "more losses at smaller MTBF scaling");
    assert!(long1.lines().next().unwrap().contains("mtbf_scaling"), "{long1}");
}

#[test]
fn market_sweep_is_byte_identical_and_crn_ordered() {
    // A `spot_discounts` axis over a dynamically priced spot tier: pricing
    // consumes no RNG, so every discount cell sees the same demand
    // trajectory (common random numbers) and the bill scales with the
    // discount alone.
    use gridsim::market::{MarketSpec, PriceModel};
    use gridsim::scenario::UserSpec;
    let base = Scenario::builder()
        .resource(resource("T0", AllocPolicy::TimeShared, 2, 100.0, 2.0))
        // Expensive enough that cost policy keeps the whole farm on the
        // spot tier at every discount in (0, 1].
        .resource(resource("T1", AllocPolicy::TimeShared, 2, 120.0, 50.0))
        .user(
            UserSpec::new(
                ExperimentSpec::task_farm(8, 600.0, 0.10)
                    .deadline(5_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            // A bid the capped price can never cross: the tier is a pure
            // discount and no cell preempts.
            .max_spot_price(1e6),
        )
        .seed(41)
        .market(
            MarketSpec::new()
                .pricing_for(
                    "T0",
                    PriceModel::UtilizationLinear { base: 2.0, slope: 2.0, floor: 2.0, cap: 6.0 },
                )
                .spot_for("T0", 0.9),
        )
        .build();
    let spec = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time])
        .spot_discounts(vec![0.25, 0.5, 1.0])
        .replications(2);
    assert_eq!(spec.cell_count(), 12);

    let jobs1 = run_sweep(&spec, 1).expect("jobs=1");
    let jobs4 = run_sweep(&spec, 4).expect("jobs=4");
    let long1 = long_csv(&spec, &jobs1).to_string();
    let long4 = long_csv(&spec, &jobs4).to_string();
    assert_eq!(long1, long4, "market long CSV differs between --jobs 1 and --jobs 4");
    assert_eq!(
        aggregate_csv(&spec, &jobs1).to_string(),
        aggregate_csv(&spec, &jobs4).to_string(),
        "market aggregate CSV differs between --jobs 1 and --jobs 4"
    );
    assert!(long1.lines().next().unwrap().contains("spot_discount"), "{long1}");

    // CRN across the discount axis: no cell preempts (the bid is never
    // crossed), every cell completes the full farm, and the cost-policy
    // bill rises strictly with the discount factor.
    assert!(jobs1.outcomes.iter().all(|o| o.report.total_preempted() == 0));
    let spent_at = |d: f64| {
        jobs1
            .outcomes
            .iter()
            .filter(|o| {
                o.cell.spot_discount == Some(d) && o.cell.policy == Some(Optimization::Cost)
            })
            .map(|o| {
                assert!(o.report.all_finished());
                assert_eq!(o.report.users[0].gridlets_completed, 8);
                o.report.mean_spent()
            })
            .sum::<f64>()
    };
    let (lo, mid, hi) = (spent_at(0.25), spent_at(0.5), spent_at(1.0));
    assert!(lo < mid && mid < hi, "price paid must rise with discount: {lo} {mid} {hi}");
}

#[test]
fn engine_reports_match_direct_session_runs() {
    // A sweep cell must equal the same scenario run directly — the engine
    // adds orchestration, never simulation semantics.
    use gridsim::session::GridSession;
    let spec = spec();
    let results = run_sweep(&spec, 4).expect("sweep");
    for outcome in results.outcomes.iter().step_by(13) {
        let scenario = spec.scenario_for(&outcome.cell);
        let direct = GridSession::new(&scenario).run_to_completion();
        assert_eq!(direct.events, outcome.report.events);
        assert_eq!(direct.end_time.to_bits(), outcome.report.end_time.to_bits());
        for (a, b) in direct.users.iter().zip(&outcome.report.users) {
            assert_eq!(a.gridlets_completed, b.gridlets_completed);
            assert_eq!(a.budget_spent.to_bits(), b.budget_spent.to_bits());
            assert_eq!(a.finish_time.to_bits(), b.finish_time.to_bits());
        }
    }
}
