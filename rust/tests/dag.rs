//! End-to-end DAG workflow battery (workflow layer, PR 10): precedence
//! gating observed on the live event stream, the pinned HEFT priority
//! list, makespan ordering against cost-minimization on a heterogeneous
//! two-resource grid, sweep jobs-invariance, and workflow behaviour under
//! resource failures (retry vs abandonment cascade).

use std::sync::{Arc, Mutex};

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::des::Event;
use gridsim::gridsim::random::GridSimRandom;
use gridsim::gridsim::{tags, AllocPolicy, Msg};
use gridsim::output::sweep::{aggregate_csv, long_csv};
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::session::GridSession;
use gridsim::sweep::{run_sweep, SweepSpec};
use gridsim::workload::{DagNode, WorkloadSpec};

fn spec(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "t".into(),
        os: "l".into(),
        machines: 1,
        pes_per_machine: pes,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// Diamond workflow. Rank order (see `workload::dag`) assigns the ids
/// a=0, c=1, b=2, d=3; d's parents are therefore `[1, 2]`.
fn diamond() -> WorkloadSpec {
    WorkloadSpec::dag(
        vec![
            DagNode::new("a", 1_000.0),
            DagNode::new("b", 2_000.0),
            DagNode::new("c", 3_000.0),
            DagNode::new("d", 4_000.0),
        ],
        vec![
            ("a".into(), "b".into()),
            ("a".into(), "c".into()),
            ("b".into(), "d".into()),
            ("c".into(), "d".into()),
        ],
    )
}

/// Fork–join workflow whose upward ranks are hand-computed below
/// (`five_node_fan_out_pins_the_heft_priority_list`).
fn five_node() -> WorkloadSpec {
    WorkloadSpec::dag(
        vec![
            DagNode::new("prep", 1_000.0),
            DagNode::new("simA", 16_000.0),
            DagNode::new("simB", 8_000.0),
            DagNode::new("simC", 4_000.0),
            DagNode::new("post", 1_000.0),
        ],
        vec![
            ("prep".into(), "simA".into()),
            ("prep".into(), "simB".into()),
            ("prep".into(), "simC".into()),
            ("simA".into(), "post".into()),
            ("simB".into(), "post".into()),
            ("simC".into(), "post".into()),
        ],
    )
}

/// Record every workflow-relevant event as a `(tag, gridlet id)` pair, in
/// dispatch order. The kernel calls the observer *before* delivering the
/// event, so payloads are still intact here.
fn observe(session: &mut GridSession) -> Arc<Mutex<Vec<(i64, usize)>>> {
    let log = Arc::new(Mutex::new(Vec::new()));
    let sink = log.clone();
    session.set_observer(Box::new(move |ev: &Event<Msg>| {
        let id = match (ev.tag, &ev.data) {
            (tags::GRIDLET_ARRIVAL | tags::GRIDLET_SUBMIT, Some(Msg::Gridlet(g))) => g.id,
            (tags::GRIDLET_COMPLETED | tags::GRIDLET_ABANDONED, Some(Msg::GridletId(id))) => *id,
            _ => return,
        };
        sink.lock().unwrap().push((ev.tag, id));
    }));
    log
}

fn count(log: &[(i64, usize)], tag: i64, id: usize) -> usize {
    log.iter().filter(|&&e| e == (tag, id)).count()
}

fn first_pos(log: &[(i64, usize)], tag: i64, id: usize) -> usize {
    log.iter()
        .position(|&e| e == (tag, id))
        .unwrap_or_else(|| panic!("no (tag {tag}, gridlet {id}) event in {log:?}"))
}

#[test]
fn diamond_children_never_start_before_their_parents_complete() {
    let scenario = Scenario::builder()
        .resource(spec("R0", 2, 200.0, 1.0))
        .resource(spec("R1", 2, 200.0, 2.0))
        .user(
            ExperimentSpec::new(diamond())
                .deadline(1e5)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(11)
        .build();
    let mut session = GridSession::new(&scenario);
    let log = observe(&mut session);
    session.init();
    while session.step().is_some() {}
    let report = session.report().into_scenario_report();
    assert_eq!(report.users[0].gridlets_completed, 4);

    let log = log.lock().unwrap();
    for id in 0..4 {
        assert_eq!(count(&log, tags::GRIDLET_COMPLETED, id), 1, "gridlet {id} completes once");
    }
    // The root ships with the experiment; every child is precedence-released
    // exactly once, never more (no double-release on the diamond join).
    assert_eq!(count(&log, tags::GRIDLET_ARRIVAL, 0), 0, "the root is never withheld");
    for id in 1..4 {
        assert_eq!(count(&log, tags::GRIDLET_ARRIVAL, id), 1, "child {id} released exactly once");
    }
    assert!(log.iter().all(|&(t, _)| t != tags::GRIDLET_ABANDONED), "nothing abandoned");

    // Precedence, on the live event stream: a child's release and its
    // dispatch to a resource both strictly follow *every* parent's
    // completion notice — the join child 3 waits for both 1 and 2.
    let done = |id| first_pos(&log, tags::GRIDLET_COMPLETED, id);
    let arrival = |id| first_pos(&log, tags::GRIDLET_ARRIVAL, id);
    let submit = |id| first_pos(&log, tags::GRIDLET_SUBMIT, id);
    for (child, parents) in [(1, vec![0]), (2, vec![0]), (3, vec![1, 2])] {
        for p in parents {
            assert!(
                done(p) < arrival(child),
                "child {child} released before parent {p} completed"
            );
            assert!(
                done(p) < submit(child),
                "child {child} dispatched before parent {p} completed"
            );
        }
    }
}

#[test]
fn five_node_fan_out_pins_the_heft_priority_list() {
    // Hand-computed upward ranks (MIPS̄ = 400, BW̄ = 9600, default staging
    // 1000/500 B → comm term (500 + 1000)/9600 = 0.15625 per edge):
    //   post = 1000/400                      =  2.5
    //   simA = 16000/400 + 0.15625 + post    = 42.65625
    //   simB =  8000/400 + 0.15625 + post    = 22.65625
    //   simC =  4000/400 + 0.15625 + post    = 12.65625
    //   prep =  1000/400 + 0.15625 + simA    = 45.3125
    // Descending rank ⇒ ids prep=0, simA=1, simB=2, simC=3, post=4.
    let spec5 = five_node();
    spec5.validate().unwrap();
    let releases = spec5.materialize(&mut GridSimRandom::new(1));
    let view: Vec<(usize, f64, Vec<usize>)> = releases
        .iter()
        .map(|r| (r.gridlet.id, r.gridlet.length_mi, r.parents.clone()))
        .collect();
    assert_eq!(
        view,
        vec![
            (0, 1_000.0, vec![]),
            (1, 16_000.0, vec![0]),
            (2, 8_000.0, vec![0]),
            (3, 4_000.0, vec![0]),
            (4, 1_000.0, vec![1, 2, 3]),
        ],
        "HEFT priority list: prep, simA, simB, simC, post"
    );
    assert!(releases.iter().all(|r| r.offset == 0.0));
}

#[test]
fn heft_beats_cost_minimization_on_heterogeneous_makespan() {
    // Cheap: 100 MIPS at 1 G$/PE-time = 0.0100 G$/MI — the cost pick.
    // Fast: 400 MIPS at 8 G$/PE-time = 0.0200 G$/MI — 4× the speed.
    // Cost-min serializes the whole 30 000 MI workflow onto Cheap
    // (makespan ≈ 300); HEFT's EFT placement spreads the fork stage across
    // both machines and must finish strictly earlier, paying more for it.
    let run = |opt: Optimization| {
        let scenario = Scenario::builder()
            .resource(spec("Cheap", 1, 100.0, 1.0))
            .resource(spec("Fast", 1, 400.0, 8.0))
            .user(ExperimentSpec::new(five_node()).deadline(1e5).budget(1e6).optimization(opt))
            .seed(13)
            .build();
        let r = GridSession::new(&scenario).run_to_completion();
        let u = &r.users[0];
        assert_eq!(u.gridlets_completed, 5, "{opt:?} must complete the workflow");
        (u.finish_time - u.start_time, u.budget_spent)
    };
    let (t_cost, s_cost) = run(Optimization::Cost);
    let (t_heft, s_heft) = run(Optimization::Heft);
    assert!(
        t_heft < t_cost,
        "HEFT makespan {t_heft} must beat cost-min makespan {t_cost}"
    );
    assert!(
        s_cost <= s_heft,
        "cost-min stays the cheaper schedule: {s_cost} vs {s_heft}"
    );
}

#[test]
fn dag_sweep_is_byte_identical_across_jobs_counts() {
    let base = Scenario::builder()
        .resource(spec("T0", 2, 100.0, 1.0))
        .resource(spec("T1", 2, 200.0, 3.0))
        .resource(spec("T2", 4, 400.0, 8.0))
        .user(
            ExperimentSpec::new(five_node())
                .deadline(5_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(41)
        .build();
    let sweep = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time, Optimization::Heft])
        .user_counts(vec![1, 2])
        .replications(2);
    assert_eq!(sweep.cell_count(), 12);

    let jobs1 = run_sweep(&sweep, 1).expect("jobs=1");
    let jobs4 = run_sweep(&sweep, 4).expect("jobs=4");
    let long1 = long_csv(&sweep, &jobs1).to_string();
    let long4 = long_csv(&sweep, &jobs4).to_string();
    assert_eq!(long1, long4, "DAG long CSV differs between --jobs 1 and --jobs 4");
    assert_eq!(
        aggregate_csv(&sweep, &jobs1).to_string(),
        aggregate_csv(&sweep, &jobs4).to_string(),
        "DAG aggregate CSV differs between --jobs 1 and --jobs 4"
    );
    assert!(long1.contains("heft"), "the heft policy axis must reach the CSV:\n{long1}");

    // Ample deadline and budget: every cell finishes every user's workflow,
    // whichever policy placed it.
    for outcome in &jobs1.outcomes {
        assert!(outcome.report.all_finished());
        for u in &outcome.report.users {
            assert_eq!(u.gridlets_completed, 5, "cell {:?}", outcome.cell);
        }
    }
}

#[test]
fn faulted_parent_is_resubmitted_and_children_release_exactly_once() {
    // The cheap resource crashes at t=3 with the 5-time-unit root in
    // flight and never comes back; the default retry policy reroutes the
    // root to the survivor. The join gating must fire exactly once per
    // child — losing a parent must not double-release (or never release)
    // its children.
    use gridsim::faults::{FaultProcess, FaultsSpec};
    let scenario = Scenario::builder()
        .resource(spec("Fragile", 2, 200.0, 1.0)) // cheap → preferred
        .resource(spec("Stable", 2, 200.0, 2.0))
        .user(
            ExperimentSpec::new(diamond())
                .deadline(1e5)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(5)
        .faults(FaultsSpec::default().override_for(
            "Fragile",
            FaultProcess::Trace { intervals: vec![(3.0, 1e8)] },
        ))
        .build();
    let mut session = GridSession::new(&scenario);
    let log = observe(&mut session);
    session.init();
    while session.step().is_some() {}
    let report = session.report().into_scenario_report();
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 4, "retry completes the workflow despite the crash");
    assert!(u.gridlets_lost >= 1, "the root is in flight at t=3");
    assert_eq!(u.gridlets_resubmitted, u.gridlets_lost, "retry resubmits every loss");
    assert_eq!(u.gridlets_abandoned, 0);

    let log = log.lock().unwrap();
    assert_eq!(count(&log, tags::GRIDLET_ARRIVAL, 0), 0, "the root ships with the experiment");
    for id in 1..4 {
        assert_eq!(
            count(&log, tags::GRIDLET_ARRIVAL, id),
            1,
            "child {id} released exactly once across the resubmission"
        );
    }
    assert!(
        count(&log, tags::GRIDLET_SUBMIT, 0) >= 2,
        "the lost root is dispatched again after the crash"
    );
}

#[test]
fn abandoned_parent_prunes_every_descendant_and_terminates() {
    // Same crash, but the broker abandons instead of retrying: the root's
    // abandonment notice must cascade through the withheld diamond — no
    // child ever becomes eligible — and the DAG_CASCADE count keeps the
    // broker's termination accounting exact (the run ends instead of
    // waiting forever for jobs that can never arrive).
    use gridsim::broker::{BrokerConfig, ResubmissionPolicy};
    use gridsim::faults::{FaultProcess, FaultsSpec};
    let scenario = Scenario::builder()
        .resource(spec("Fragile", 2, 200.0, 1.0))
        .resource(spec("Stable", 2, 200.0, 2.0))
        .user(
            ExperimentSpec::new(diamond())
                .deadline(1e5)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(5)
        .broker_config(BrokerConfig {
            resubmission: ResubmissionPolicy::Abandon,
            ..BrokerConfig::default()
        })
        .faults(FaultsSpec::default().override_for(
            "Fragile",
            FaultProcess::Trace { intervals: vec![(3.0, 1e8)] },
        ))
        .build();
    let mut session = GridSession::new(&scenario);
    let log = observe(&mut session);
    session.init();
    while session.step().is_some() {}
    let report = session.report().into_scenario_report();
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 0, "the root dies before anything completes");
    assert_eq!(
        u.gridlets_abandoned, 4,
        "the lost root plus its three pruned descendants"
    );
    assert_eq!(u.gridlets_completed + u.gridlets_abandoned, u.gridlets_total);
    assert_eq!(u.gridlets_resubmitted, 0, "abandon never resubmits");
    assert!(report.end_time < 1e6, "accounting terminates the run well before the hard cap");

    let log = log.lock().unwrap();
    assert_eq!(count(&log, tags::GRIDLET_ABANDONED, 0), 1, "one notice for the root");
    for id in 0..4 {
        assert_eq!(
            count(&log, tags::GRIDLET_ARRIVAL, id),
            0,
            "gridlet {id} must never be precedence-released"
        );
    }
}
