//! Resume determinism: a sweep interrupted after N cells and resumed from
//! its `sweep_cells.jsonl` checkpoint must produce `sweep_long.csv` /
//! `sweep_agg.csv` byte-identical to an uninterrupted run — at any worker
//! count, with the resumed cells taken verbatim from the checkpoint.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::AllocPolicy;
use gridsim::output::sweep::{aggregate_csv, long_csv, CHECKPOINT_FILE};
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::sweep::{run_sweep, run_sweep_checkpointed, SweepSpec};
use std::path::PathBuf;

fn resource(name: &str, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "test".into(),
        os: "linux".into(),
        machines: 1,
        pes_per_machine: 2,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// 2 deadlines × 2 budgets × 2 replications = 8 cells; variation > 0 so
/// replications draw distinct workloads and the CSVs are not trivially
/// constant.
fn spec() -> SweepSpec {
    let base = Scenario::builder()
        .resource(resource("R0", 100.0, 1.0))
        .resource(resource("R1", 120.0, 3.0))
        .user(
            ExperimentSpec::task_farm(8, 600.0, 0.10)
                .deadline(5_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .seed(43)
        .build();
    SweepSpec::over(base)
        .deadlines(vec![40.0, 5_000.0])
        .budgets(vec![2.0, 1e6])
        .replications(2)
}

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gridsim_resume_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn resumed_sweep_is_byte_identical_to_one_shot() {
    let spec = spec();

    // Reference: the plain (non-checkpointed) engine.
    let reference = run_sweep(&spec, 2).unwrap();
    let ref_long = long_csv(&spec, &reference).to_string();
    let ref_agg = aggregate_csv(&spec, &reference).to_string();

    // Checkpointing an uninterrupted run must not perturb a byte.
    let full_dir = test_dir("full");
    let full = run_sweep_checkpointed(&spec, 2, &full_dir, false).unwrap();
    assert_eq!(full.cells_reused, 0);
    assert_eq!(long_csv(&spec, &full).to_string(), ref_long);
    assert_eq!(aggregate_csv(&spec, &full).to_string(), ref_agg);
    let checkpoint = std::fs::read_to_string(full_dir.join(CHECKPOINT_FILE)).unwrap();
    assert_eq!(checkpoint.lines().count(), 8, "one fsync'd line per cell");

    // Emulate a kill after 3 completed cells: a checkpoint holding only the
    // first 3 lines, then resume with a *different* worker count.
    let half_dir = test_dir("half");
    let head: String =
        checkpoint.lines().take(3).map(|l| format!("{l}\n")).collect();
    std::fs::write(half_dir.join(CHECKPOINT_FILE), &head).unwrap();
    let resumed = run_sweep_checkpointed(&spec, 3, &half_dir, true).unwrap();
    assert_eq!(resumed.cells_reused, 3, "completed cells are skipped");
    assert_eq!(resumed.outcomes.len(), 8, "missing cells were appended");
    assert_eq!(long_csv(&spec, &resumed).to_string(), ref_long, "long CSV byte-identical");
    assert_eq!(aggregate_csv(&spec, &resumed).to_string(), ref_agg, "agg CSV byte-identical");
    // The resumed run appended the 5 missing cells to the same file, so a
    // second resume reuses everything and executes nothing.
    let again = run_sweep_checkpointed(&spec, 2, &half_dir, true).unwrap();
    assert_eq!(again.cells_reused, 8);
    assert_eq!(long_csv(&spec, &again).to_string(), ref_long);

    // Bit-exactness underneath the CSVs: resumed reports equal executed
    // ones field for field.
    for (a, b) in reference.outcomes.iter().zip(&again.outcomes) {
        assert_eq!(a.cell.index, b.cell.index);
        assert_eq!(a.report.events, b.report.events);
        assert_eq!(a.report.end_time.to_bits(), b.report.end_time.to_bits());
        assert_eq!(a.report.unfinished, b.report.unfinished);
        for (u, v) in a.report.users.iter().zip(&b.report.users) {
            assert_eq!(u.gridlets_completed, v.gridlets_completed);
            assert_eq!(u.budget_spent.to_bits(), v.budget_spent.to_bits());
            assert_eq!(u.finish_time.to_bits(), v.finish_time.to_bits());
        }
    }

    let _ = std::fs::remove_dir_all(&full_dir);
    let _ = std::fs::remove_dir_all(&half_dir);
}

#[test]
fn resume_repairs_a_torn_checkpoint_tail_before_appending() {
    let spec = spec();
    let reference = run_sweep(&spec, 2).unwrap();
    let ref_long = long_csv(&spec, &reference).to_string();

    let full_dir = test_dir("torn_src");
    run_sweep_checkpointed(&spec, 2, &full_dir, false).unwrap();
    let checkpoint = std::fs::read_to_string(full_dir.join(CHECKPOINT_FILE)).unwrap();
    let lines: Vec<&str> = checkpoint.lines().collect();

    // Case 1: a torn final fragment with no newline (kill mid-append).
    // Resume must drop the fragment and must NOT let the first new record
    // merge with it — the file stays line-parseable for the *next* resume.
    let dir = test_dir("torn");
    std::fs::write(
        dir.join(CHECKPOINT_FILE),
        format!("{}\n{}\n{{\"digest\":\"00ab", lines[0], lines[1]),
    )
    .unwrap();
    let resumed = run_sweep_checkpointed(&spec, 2, &dir, true).unwrap();
    assert_eq!(resumed.cells_reused, 2, "the torn fragment's cell reruns");
    assert_eq!(long_csv(&spec, &resumed).to_string(), ref_long);
    let repaired = std::fs::read_to_string(dir.join(CHECKPOINT_FILE)).unwrap();
    assert_eq!(repaired.lines().count(), 8, "2 repaired + 6 appended, no merged line");
    let again = run_sweep_checkpointed(&spec, 2, &dir, true).unwrap();
    assert_eq!(again.cells_reused, 8, "the repaired file resumes cleanly again");

    // Case 2: a complete final line that lost only its trailing newline
    // (kill between the two write_all calls). The record is valid and must
    // be kept — and still must not merge with the first appended record.
    let dir2 = test_dir("no_newline");
    std::fs::write(dir2.join(CHECKPOINT_FILE), format!("{}\n{}", lines[0], lines[1]))
        .unwrap();
    let resumed = run_sweep_checkpointed(&spec, 2, &dir2, true).unwrap();
    assert_eq!(resumed.cells_reused, 2, "the newline-less record survives");
    assert_eq!(long_csv(&spec, &resumed).to_string(), ref_long);
    let repaired = std::fs::read_to_string(dir2.join(CHECKPOINT_FILE)).unwrap();
    assert_eq!(repaired.lines().count(), 8);
    let again = run_sweep_checkpointed(&spec, 2, &dir2, true).unwrap();
    assert_eq!(again.cells_reused, 8);

    for d in [&full_dir, &dir, &dir2] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn fresh_run_overwrites_a_stale_checkpoint() {
    let spec = spec();
    let dir = test_dir("fresh");
    run_sweep_checkpointed(&spec, 2, &dir, false).unwrap();
    // Without --resume the old checkpoint is truncated, every cell reruns.
    let rerun = run_sweep_checkpointed(&spec, 2, &dir, false).unwrap();
    assert_eq!(rerun.cells_reused, 0);
    let text = std::fs::read_to_string(dir.join(CHECKPOINT_FILE)).unwrap();
    assert_eq!(text.lines().count(), 8, "rewritten, not appended");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_sweep() {
    let spec = spec();
    let dir = test_dir("foreign");
    run_sweep_checkpointed(&spec, 2, &dir, false).unwrap();
    // Same base, different axis values: the digest must not match.
    let other = SweepSpec::over(spec.base.clone())
        .deadlines(vec![41.0, 5_000.0])
        .budgets(vec![2.0, 1e6])
        .replications(2);
    let err = run_sweep_checkpointed(&other, 2, &dir, true).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different sweep"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resuming_an_empty_directory_runs_everything() {
    let spec = spec();
    let dir = test_dir("empty");
    // --resume against a directory with no checkpoint is a fresh start,
    // not an error (nothing to reuse).
    let results = run_sweep_checkpointed(&spec, 2, &dir, true).unwrap();
    assert_eq!(results.cells_reused, 0);
    assert_eq!(results.outcomes.len(), 8);
    let _ = std::fs::remove_dir_all(&dir);
}
