//! The `WorkloadSpec` application-model API, end to end: deterministic
//! materialization across every variant (property test), trace-file
//! round-trips, online arrivals completing through a live broker with real
//! per-resource accounting, and the backward-compatibility regression — a
//! scenario omitting `"workload"` (or spelling out `task_farm`) is
//! byte-identical to the historical flat task-farm shape.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::scenario_file::parse_scenario;
use gridsim::gridsim::random::GridSimRandom;
use gridsim::gridsim::AllocPolicy;
use gridsim::scenario::{ResourceSpec, Scenario, ScenarioReport};
use gridsim::session::GridSession;
use gridsim::util::prop::{check, forall};
use gridsim::workload::{
    format_trace, parse_trace, ArrivalProcess, JobSpec, RateEnvelope, TraceJob, WorkloadSpec,
};

fn resource(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "test".into(),
        os: "linux".into(),
        machines: 1,
        pes_per_machine: pes,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// Every variant, driven by a generated seed: two materializations under
/// the same seed must agree bit-for-bit, offsets must be sorted, and ids
/// must be a permutation of 0..n.
#[test]
fn every_variant_materializes_deterministically() {
    let variants: Vec<WorkloadSpec> = vec![
        WorkloadSpec::task_farm(40, 10_000.0, 0.10),
        WorkloadSpec::heavy_tailed(40, 1_000.0, 0.2, 25.0),
        WorkloadSpec::explicit(
            (1..=10)
                .map(|i| JobSpec {
                    length_mi: 100.0 * i as f64,
                    input_bytes: i,
                    output_bytes: i,
                })
                .collect(),
        ),
        WorkloadSpec::trace(
            (0..10)
                .map(|i| TraceJob::new((10 - i) as f64, 50.0 + i as f64, 1, 1))
                .collect(),
        ),
        WorkloadSpec::online(
            WorkloadSpec::task_farm(40, 1_000.0, 0.10),
            ArrivalProcess::Poisson { mean_interarrival: 3.0 },
        ),
        WorkloadSpec::online(
            WorkloadSpec::heavy_tailed(40, 1_000.0, 0.3, 10.0),
            ArrivalProcess::Fixed { interval: 2.5 },
        ),
        WorkloadSpec::online(
            WorkloadSpec::task_farm(40, 1_000.0, 0.10),
            ArrivalProcess::Modulated {
                mean_interarrival: 3.0,
                envelope: RateEnvelope::Piecewise { period: 50.0, rates: vec![1.0, 0.2] },
            },
        ),
        WorkloadSpec::online(
            WorkloadSpec::task_farm(40, 1_000.0, 0.10),
            ArrivalProcess::Modulated {
                mean_interarrival: 3.0,
                envelope: RateEnvelope::Sinusoid { period: 80.0, amplitude: 0.9 },
            },
        ),
        WorkloadSpec::concat(vec![
            WorkloadSpec::task_farm(15, 1_000.0, 0.10),
            WorkloadSpec::trace(
                (0..5).map(|i| TraceJob::new(i as f64 * 4.0, 100.0, 1, 1)).collect(),
            ),
        ]),
        WorkloadSpec::mix_weighted(
            vec![
                WorkloadSpec::heavy_tailed(20, 1_000.0, 0.2, 10.0),
                WorkloadSpec::online(
                    WorkloadSpec::task_farm(10, 500.0, 0.0),
                    ArrivalProcess::Poisson { mean_interarrival: 2.0 },
                ),
            ],
            vec![3.0, 1.0],
        ),
    ];
    for spec in &variants {
        forall(
            7,
            25,
            |rng| rng.next_u64(),
            |&seed| {
                let a = spec.materialize(&mut GridSimRandom::new(seed));
                let b = spec.materialize(&mut GridSimRandom::new(seed));
                check(a.len() == b.len(), "same length")?;
                check(a.len() == spec.declared_jobs(), "declared_jobs matches")?;
                for (x, y) in a.iter().zip(&b) {
                    check(
                        x.offset.to_bits() == y.offset.to_bits()
                            && x.gridlet.length_mi.to_bits() == y.gridlet.length_mi.to_bits()
                            && x.gridlet.id == y.gridlet.id,
                        format!("{}: bit-identical releases", spec.label()),
                    )?;
                }
                check(
                    a.windows(2).all(|w| w[0].offset <= w[1].offset),
                    "offsets sorted",
                )?;
                let mut ids: Vec<usize> = a.iter().map(|r| r.gridlet.id).collect();
                ids.sort_unstable();
                check(
                    ids == (0..a.len()).collect::<Vec<_>>(),
                    "ids are a permutation of 0..n",
                )
            },
        );
    }
}

#[test]
fn trace_round_trips_through_file_and_scenario() {
    // Generated jobs with awkward floats round-trip exactly.
    let jobs: Vec<TraceJob> = (0..25)
        .map(|i| TraceJob::new(i as f64 * 1.1, 10_000.0 / 3.0 + i as f64, 100 + i, 50))
        .collect();
    let text = format_trace(&jobs);
    assert_eq!(parse_trace(&text).unwrap(), jobs, "write -> load -> identical jobs");

    // And the workload built from the re-loaded jobs materializes identical
    // gridlets.
    let a = WorkloadSpec::trace(jobs.clone()).materialize(&mut GridSimRandom::new(1));
    let b = WorkloadSpec::trace(parse_trace(&text).unwrap())
        .materialize(&mut GridSimRandom::new(1));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.offset.to_bits(), y.offset.to_bits());
        assert_eq!(x.gridlet.length_mi.to_bits(), y.gridlet.length_mi.to_bits());
        assert_eq!(x.gridlet.input_bytes, y.gridlet.input_bytes);
    }
}

/// Online arrivals complete through a live broker: jobs submitted after the
/// experiment started are scheduled, executed and accounted per resource.
#[test]
fn online_arrivals_complete_late_jobs_with_real_accounting() {
    let n = 30;
    let mean_gap = 4.0;
    let scenario = Scenario::builder()
        .resource(resource("Cheap", 2, 100.0, 1.0))
        .resource(resource("Fast", 4, 200.0, 3.0))
        .user(
            ExperimentSpec::new(WorkloadSpec::online(
                WorkloadSpec::task_farm(n, 500.0, 0.10),
                ArrivalProcess::Poisson { mean_interarrival: mean_gap },
            ))
            .deadline(100_000.0)
            .budget(1e9)
            .optimization(Optimization::Cost),
        )
        .seed(11)
        .build();

    // The arrival schedule the user will follow (same seed derivation as
    // the session: seed·997·(1+0)+1).
    let user_seed = 11u64.wrapping_mul(997).wrapping_add(1);
    let releases = scenario.users[0]
        .experiment
        .workload
        .materialize(&mut GridSimRandom::new(user_seed));
    let last_arrival = releases.last().unwrap().offset;
    assert!(last_arrival > 0.0, "workload is genuinely online");

    let mut session = GridSession::new(&scenario);
    // Pause mid-stream: the broker already knows the declared totals but
    // has only seen the jobs released so far.
    session.init();
    session.run_until(last_arrival / 2.0);
    let mid = session.snapshot();
    assert_eq!(mid.users[0].gridlets_total, n, "declared total known up front");
    assert!(
        mid.users[0].gridlets_completed < n,
        "jobs are still arriving at t={}",
        mid.time
    );

    let report = session.run_to_completion();
    assert!(report.all_finished());
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, n, "late-arriving gridlets completed");
    assert!(
        u.finish_time - u.start_time >= last_arrival,
        "experiment cannot end before its last arrival ({} < {last_arrival})",
        u.finish_time - u.start_time
    );
    // Real per-resource accounting: completions and spend add up.
    let per_res_done: usize = u.per_resource.iter().map(|r| r.gridlets_completed).sum();
    let per_res_spent: f64 = u.per_resource.iter().map(|r| r.budget_spent).sum();
    assert_eq!(per_res_done, n);
    assert!(u.budget_spent > 0.0);
    assert!((per_res_spent - u.budget_spent).abs() < 1e-9);
}

/// A tight deadline under online arrivals: the broker drains at the
/// deadline and late jobs count as unfinished — not as phantom completions.
#[test]
fn online_arrivals_respect_deadline_for_unarrived_jobs() {
    let scenario = Scenario::builder()
        .resource(resource("R0", 2, 100.0, 1.0))
        .user(
            ExperimentSpec::new(WorkloadSpec::online(
                WorkloadSpec::task_farm(50, 500.0, 0.0),
                ArrivalProcess::Fixed { interval: 10.0 },
            ))
            .deadline(100.0)
            .budget(1e9),
        )
        .seed(5)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    let u = &report.users[0];
    assert_eq!(u.gridlets_total, 50);
    assert!(
        u.gridlets_completed < 50,
        "jobs arriving past the deadline cannot complete ({}/50)",
        u.gridlets_completed
    );
    assert!(u.gridlets_completed > 0, "early arrivals do complete");
}

fn run_report(scenario: &Scenario) -> ScenarioReport {
    GridSession::new(scenario).run_to_completion()
}

/// Digest of everything the report/CSV layer prints for a run.
fn digest(report: &ScenarioReport) -> String {
    let mut out = format!("end={} events={}\n", report.end_time.to_bits(), report.events);
    for u in &report.users {
        out.push_str(&format!(
            "done={}/{} spent={} finish={} deadline={} budget={}\n",
            u.gridlets_completed,
            u.gridlets_total,
            u.budget_spent.to_bits(),
            u.finish_time.to_bits(),
            u.deadline.to_bits(),
            u.budget.to_bits(),
        ));
        for r in &u.per_resource {
            out.push_str(&format!(
                "  {} {} {}\n",
                r.name,
                r.gridlets_completed,
                r.budget_spent.to_bits()
            ));
        }
    }
    out
}

/// The acceptance regression: a scenario JSON omitting `"workload"`, one
/// spelling it as a `task_farm` object, and the builder API all produce
/// byte-identical results for the same seed — and the flat-JSON run matches
/// the pre-refactor materialization formula exactly.
#[test]
fn flat_json_workload_json_and_builder_are_byte_identical() {
    let flat = r#"{
        "seed": 27,
        "resources": [
            {"name": "R0", "pes": 2, "mips": 100, "price": 1.0},
            {"name": "R1", "pes": 2, "mips": 200, "price": 4.0}
        ],
        "users": [{"gridlets": 40, "length_mi": 1000, "variation": 0.1,
                   "deadline": 2000, "budget": 100000, "optimization": "cost"}]
    }"#;
    let spelled = r#"{
        "seed": 27,
        "resources": [
            {"name": "R0", "pes": 2, "mips": 100, "price": 1.0},
            {"name": "R1", "pes": 2, "mips": 200, "price": 4.0}
        ],
        "users": [{"workload": {"type": "task_farm", "gridlets": 40,
                                "length_mi": 1000, "variation": 0.1},
                   "deadline": 2000, "budget": 100000, "optimization": "cost"}]
    }"#;
    let built = Scenario::builder()
        .resource(resource("R0", 2, 100.0, 1.0))
        .resource(resource("R1", 2, 200.0, 4.0))
        .user(
            ExperimentSpec::task_farm(40, 1_000.0, 0.10)
                .deadline(2_000.0)
                .budget(100_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(27)
        .build();

    let d_flat = digest(&run_report(&parse_scenario(flat).unwrap()));
    let d_spelled = digest(&run_report(&parse_scenario(spelled).unwrap()));
    let d_built = digest(&run_report(&built));
    assert_eq!(d_flat, d_spelled, "flat keys == explicit task_farm object");
    assert_eq!(d_flat, d_built, "JSON == builder API");

    // And the workload the user materializes is the pre-refactor stream:
    // GridSimRandom::new(user_seed).real(base, 0, variation) per job.
    let user_seed = 27u64.wrapping_mul(997).wrapping_add(1);
    let mut legacy = GridSimRandom::new(user_seed);
    let expected: Vec<f64> = (0..40).map(|_| legacy.real(1_000.0, 0.0, 0.10)).collect();
    let releases = parse_scenario(flat).unwrap().users[0]
        .experiment
        .workload
        .materialize(&mut GridSimRandom::new(user_seed));
    for (r, e) in releases.iter().zip(&expected) {
        assert_eq!(r.gridlet.length_mi.to_bits(), e.to_bits(), "legacy §5.2 stream");
    }
}

/// The market regression pin: a scenario with no `"pricing"` block is
/// byte-identical to the same scenario with an explicit `Static` model at
/// every resource's configured price — on a small mixed grid and on the
/// full Table 2 testbed. `Static` settles with no averaging arithmetic and
/// never publishes a `PRICE_UPDATE`, so the market layer's default must be
/// invisible in every reported bit.
#[test]
fn explicit_static_pricing_is_byte_identical_to_no_market() {
    use gridsim::config::testbed::wwg_testbed;
    use gridsim::market::{MarketSpec, PriceModel};

    let static_market = |resources: &[ResourceSpec]| {
        let mut market = MarketSpec::new();
        for r in resources {
            market = market.pricing_for(r.name.clone(), PriceModel::Static { price: r.price });
        }
        market
    };

    let small = |market: bool| {
        let resources =
            vec![resource("R0", 2, 100.0, 1.0), resource("R1", 2, 200.0, 4.0)];
        let mut b = Scenario::builder().resources(resources.clone()).seed(27);
        if market {
            b = b.market(static_market(&resources));
        }
        b.user(
            ExperimentSpec::task_farm(40, 1_000.0, 0.10)
                .deadline(2_000.0)
                .budget(100_000.0)
                .optimization(Optimization::Cost),
        )
        .user(
            ExperimentSpec::task_farm(10, 1_000.0, 0.10)
                .deadline(2_000.0)
                .budget(100_000.0)
                .optimization(Optimization::Time),
        )
        .build()
    };
    assert_eq!(
        digest(&run_report(&small(false))),
        digest(&run_report(&small(true))),
        "Static pricing must be invisible on the small grid"
    );

    let testbed = |market: bool| {
        let resources = wwg_testbed();
        let mut b = Scenario::builder().resources(resources.clone()).seed(31);
        if market {
            b = b.market(static_market(&resources));
        }
        b.user(
            ExperimentSpec::task_farm(20, 10_000.0, 0.10)
                .deadline(5_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .build()
    };
    assert_eq!(
        digest(&run_report(&testbed(false))),
        digest(&run_report(&testbed(true))),
        "Static pricing must be invisible on the Table 2 testbed"
    );
}

/// Closed-batch runs carry no arrival machinery: the broker still receives
/// one experiment whose declared totals equal the batch.
#[test]
fn closed_batch_declares_batch_totals() {
    let scenario = Scenario::builder()
        .resource(resource("R0", 2, 100.0, 1.0))
        .user(ExperimentSpec::task_farm(12, 1_000.0, 0.10).deadline(1e4).budget(1e6))
        .seed(3)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    assert!(report.all_finished());
    assert_eq!(report.users[0].gridlets_total, 12);
    assert_eq!(report.users[0].gridlets_completed, 12);
}
