//! Differential tests: the AOT JAX/Pallas advisor artifact (via PJRT) must
//! produce the same allocations as the pure-Rust `NativeAdvisor`, and the
//! forecast artifact must match the paper's Fig 8/Table 1 numbers.
//!
//! Requires `artifacts/*.hlo.txt` (built by `make artifacts`); tests skip
//! with a loud message when artifacts are missing so `cargo test` stays
//! usable before the first python build.

use gridsim::runtime::{
    Advisor, AdvisorInput, ForecastInput, NativeAdvisor, ResourceSnapshot, XlaAdvisor,
    XlaForecaster,
};
use gridsim::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` cargo feature");
        return None;
    }
    let dir = Path::new("artifacts");
    if dir.join("advisor.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn input(
    resources: Vec<ResourceSnapshot>,
    time: f64,
    budget: f64,
    avg: f64,
    jobs: usize,
) -> AdvisorInput {
    AdvisorInput { resources, time_left: time, budget_left: budget, avg_job_mi: avg, jobs }
}

#[test]
fn xla_advisor_matches_native_on_fixed_cases() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaAdvisor::load_dir(dir).expect("load advisor artifact");
    let mut native = NativeAdvisor::new();
    let cases = vec![
        // (rates, costs, time, budget, avg, jobs)
        (vec![(50.0, 0.01), (1000.0, 0.05)], 10.0, 1e9, 100.0, 8),
        (vec![(20.0, 0.01), (1000.0, 0.10)], 10.0, 25.0, 100.0, 50),
        (vec![(100.0, 0.01)], 0.0, 1e9, 100.0, 10),
        (vec![(100.0, 0.01)], 10.0, 0.0, 100.0, 10),
        // Paper-scale: the WWG testbed's cost-sorted rates/prices.
        (
            vec![
                (760.0, 1.0 / 380.0),
                (760.0, 2.0 / 380.0),
                (1508.0, 3.0 / 377.0),
                (754.0, 3.0 / 377.0),
                (3016.0, 3.0 / 377.0),
                (6560.0, 4.0 / 410.0),
                (1508.0, 4.0 / 377.0),
                (2460.0, 5.0 / 410.0),
                (6560.0, 5.0 / 410.0),
                (1640.0, 6.0 / 410.0),
                (2060.0, 8.0 / 515.0),
            ],
            3100.0,
            22000.0,
            10500.0,
            200,
        ),
    ];
    for (specs, time, budget, avg, jobs) in cases {
        let snaps: Vec<ResourceSnapshot> = specs
            .iter()
            .map(|&(r, c)| ResourceSnapshot { rate_mi: r, cost_per_mi: c })
            .collect();
        let mut snaps_sorted = snaps.clone();
        snaps_sorted.sort_by(|a, b| a.cost_per_mi.total_cmp(&b.cost_per_mi));
        let inp = input(snaps_sorted, time, budget, avg, jobs);
        let a = native.advise(&inp);
        let b = xla.advise(&inp);
        assert_eq!(a, b, "native vs xla mismatch on {inp:?}");
    }
}

#[test]
fn xla_advisor_matches_native_randomized() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaAdvisor::load_dir(dir).expect("load advisor artifact");
    let mut native = NativeAdvisor::new();
    let mut rng = Rng::new(0xDBC);
    let mut mismatches = 0;
    for case in 0..300 {
        let n = 1 + (rng.below(16) as usize);
        let mut costs: Vec<f64> =
            (0..n).map(|_| (1 + rng.below(500)) as f64 / 1000.0).collect();
        costs.sort_by(|a, b| a.total_cmp(b));
        let snaps: Vec<ResourceSnapshot> = costs
            .into_iter()
            .map(|c| ResourceSnapshot { rate_mi: rng.below(4000) as f64, cost_per_mi: c })
            .collect();
        let inp = input(
            snaps,
            rng.below(4000) as f64,
            rng.below(30000) as f64,
            (50 + rng.below(20000)) as f64,
            rng.below(300) as usize,
        );
        let a = native.advise(&inp);
        let b = xla.advise(&inp);
        // f32 vs f64 may differ by one job at exact floor() boundaries;
        // tolerate per-lane |Δ| ≤ 1 but require near-total agreement.
        for (x, y) in a.iter().zip(&b) {
            let d = (*x as i64 - *y as i64).abs();
            assert!(d <= 1, "case {case}: native={a:?} xla={b:?} for {inp:?}");
            if d > 0 {
                mismatches += 1;
            }
        }
    }
    assert!(mismatches <= 6, "too many off-by-one boundary cases: {mismatches}");
}

#[test]
fn xla_forecaster_reproduces_fig9_moment() {
    let Some(dir) = artifacts_dir() else { return };
    let mut fc = XlaForecaster::load_dir(dir).expect("load forecast artifact");
    // Table 1 at t=7: G1 has 3 MI left (full PE), G2 5.5 and G3 9.5 share.
    let input = ForecastInput {
        remaining_mi: vec![vec![3.0, 5.5, 9.5]],
        mips_per_pe: vec![1.0],
        num_pe: vec![2],
        availability: vec![1.0],
    };
    let out = fc.forecast(&input).expect("forecast");
    let row = &out[0];
    assert!((row[0] - 3.0).abs() < 1e-4, "G1 completes 3 units later, got {}", row[0]);
    assert!((row[1] - 11.0).abs() < 1e-3, "G2 at half share: {}", row[1]);
    assert!((row[2] - 19.0).abs() < 1e-3, "G3 at half share: {}", row[2]);
}

#[test]
fn xla_forecaster_masks_inactive() {
    let Some(dir) = artifacts_dir() else { return };
    let mut fc = XlaForecaster::load_dir(dir).expect("load forecast artifact");
    let input = ForecastInput {
        remaining_mi: vec![vec![10.0, 0.0, 5.0]],
        mips_per_pe: vec![10.0],
        num_pe: vec![4],
        availability: vec![1.0],
    };
    let out = fc.forecast(&input).expect("forecast");
    assert!((out[0][0] - 1.0).abs() < 1e-5);
    assert!(out[0][1].is_infinite(), "zero-MI slot is inactive");
    assert!((out[0][2] - 0.5).abs() < 1e-5);
}

#[test]
fn scenario_runs_with_xla_advisor_end_to_end() {
    let Some(_) = artifacts_dir() else { return };
    use gridsim::broker::{ExperimentSpec, Optimization};
    use gridsim::gridsim::AllocPolicy;
    use gridsim::scenario::{AdvisorKind, ResourceSpec, Scenario};
    use gridsim::session::GridSession;
    let resource = ResourceSpec {
        name: "R0".into(),
        arch: "test".into(),
        os: "linux".into(),
        machines: 1,
        pes_per_machine: 2,
        mips_per_pe: 100.0,
        policy: AllocPolicy::TimeShared,
        price: 1.0,
        time_zone: 0.0,
        calendar: None,
    };
    let build = |advisor: AdvisorKind| {
        Scenario::builder()
            .resource(resource.clone())
            .user(
                ExperimentSpec::task_farm(12, 1_000.0, 0.10)
                    .deadline(500.0)
                    .budget(10_000.0)
                    .optimization(Optimization::Cost),
            )
            .seed(11)
            .advisor(advisor)
            .build()
    };
    let native = GridSession::new(&build(AdvisorKind::Native)).run_to_completion();
    let xla = GridSession::new(&build(AdvisorKind::Xla)).run_to_completion();
    assert_eq!(native.users[0].gridlets_completed, 12);
    assert_eq!(
        native.users[0].gridlets_completed,
        xla.users[0].gridlets_completed,
        "same outcome under either advisor engine"
    );
    assert!((native.users[0].budget_spent - xla.users[0].budget_spent).abs() < 1e-6);
}
