//! Stepped-execution contract: driving a `GridSession` through
//! `run_until`/`step` in arbitrary increments must be *bit-identical* to one
//! `run_to_completion()` — same end time, same event count, same per-user
//! results. Plus end-to-end coverage of per-user heterogeneity (different
//! policies, broker configs, advisors in one scenario) through both the
//! builder API and the JSON loader.

use gridsim::broker::{BrokerConfig, ExperimentSpec, Optimization};
use gridsim::config::scenario_file::parse_scenario;
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::{Scenario, ScenarioReport, UserSpec};
use gridsim::session::GridSession;
use gridsim::util::prop::{check, forall};
use gridsim::util::rng::Rng;

/// A two-user WWG scenario with heterogeneous policies and broker tunings.
fn wwg_two_user(seed: u64, gridlets: usize) -> Scenario {
    Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(gridlets, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .user(
            UserSpec::new(
                ExperimentSpec::task_farm(gridlets, 10_000.0, 0.10)
                    .deadline(3_100.0)
                    .budget(22_000.0)
                    .optimization(Optimization::Time),
            )
            .broker(BrokerConfig { max_gridlets_per_pe: 1, ..BrokerConfig::default() }),
        )
        .seed(seed)
        .build()
}

fn assert_bit_identical(a: &ScenarioReport, b: &ScenarioReport) -> Result<(), String> {
    check(a.end_time.to_bits() == b.end_time.to_bits(), "end_time differs")?;
    check(a.events == b.events, format!("events {} != {}", a.events, b.events))?;
    check(a.users.len() == b.users.len(), "user count differs")?;
    check(a.unfinished == b.unfinished, "unfinished set differs")?;
    for (i, (ua, ub)) in a.users.iter().zip(&b.users).enumerate() {
        check(
            ua.gridlets_completed == ub.gridlets_completed,
            format!("user {i} completed {} != {}", ua.gridlets_completed, ub.gridlets_completed),
        )?;
        check(
            ua.budget_spent.to_bits() == ub.budget_spent.to_bits(),
            format!("user {i} spent {} != {}", ua.budget_spent, ub.budget_spent),
        )?;
        check(
            ua.finish_time.to_bits() == ub.finish_time.to_bits(),
            format!("user {i} finish {} != {}", ua.finish_time, ub.finish_time),
        )?;
        check(ua.start_time.to_bits() == ub.start_time.to_bits(), "start_time differs")?;
        check(ua.trace.len() == ub.trace.len(), "trace length differs")?;
    }
    Ok(())
}

#[test]
fn wwg_stepped_increments_bit_identical_to_single_run() {
    // The acceptance case: the WWG testbed scenario, run whole vs in
    // increments of several fixed sizes.
    let baseline = GridSession::new(&wwg_two_user(27, 25)).run_to_completion();
    assert!(baseline.all_finished());
    assert_eq!(baseline.users[0].gridlets_completed, 25);
    assert_eq!(baseline.users[1].gridlets_completed, 25);

    for increment in [1.0, 17.3, 250.0, 5_000.0] {
        let mut session = GridSession::new(&wwg_two_user(27, 25));
        session.init();
        let mut horizon = 0.0;
        while !session.is_idle() {
            horizon += increment;
            session.run_until(horizon);
        }
        let stepped = session.report().into_scenario_report();
        assert_bit_identical(&baseline, &stepped)
            .unwrap_or_else(|msg| panic!("increment {increment}: {msg}"));
    }
}

#[test]
fn wwg_single_stepping_bit_identical() {
    // One event at a time — the finest possible interleaving.
    let baseline = GridSession::new(&wwg_two_user(7, 12)).run_to_completion();
    let mut session = GridSession::new(&wwg_two_user(7, 12));
    session.init();
    let mut steps = 0u64;
    while session.step().is_some() {
        steps += 1;
    }
    let stepped = session.report().into_scenario_report();
    assert_eq!(steps, stepped.events);
    assert_bit_identical(&baseline, &stepped).unwrap();
}

#[test]
fn prop_random_increments_bit_identical() {
    // Property: for random seeds and random (coarse or fine) increment
    // schedules, stepped == whole, bitwise.
    forall(
        2027,
        12,
        |rng: &mut Rng| {
            let seed = rng.below(1_000);
            let gridlets = 5 + rng.below(15) as usize;
            // Increment schedule: mean size varies over three orders of
            // magnitude across cases.
            let scale = 10f64.powi(rng.below(3) as i32 + 1);
            let jitter = rng.next_f64();
            (seed, gridlets, scale, jitter)
        },
        |&(seed, gridlets, scale, jitter)| {
            let baseline = GridSession::new(&wwg_two_user(seed, gridlets)).run_to_completion();
            let mut session = GridSession::new(&wwg_two_user(seed, gridlets));
            session.init();
            let mut horizon = 0.0;
            let mut k = 0u64;
            while !session.is_idle() {
                k += 1;
                // Deterministic, irregular increments.
                horizon += scale * (0.25 + ((jitter * k as f64).sin().abs()));
                session.run_until(horizon);
            }
            let stepped = session.report().into_scenario_report();
            assert_bit_identical(&baseline, &stepped)
        },
    );
}

#[test]
fn heterogeneous_users_via_builder_api() {
    // Two users on *different* policies and broker configs in one scenario.
    let report = GridSession::new(&wwg_two_user(5, 20)).run_to_completion();
    assert!(report.all_finished());
    let (cost, time) = (&report.users[0], &report.users[1]);
    assert_eq!(cost.gridlets_completed, 20);
    assert_eq!(time.gridlets_completed, 20);
    // Time-optimization fans out to fast expensive resources: it should
    // never pay less than the cost-optimizer on the same workload.
    assert!(
        time.budget_spent >= cost.budget_spent,
        "time {} < cost {}",
        time.budget_spent,
        cost.budget_spent
    );
}

#[test]
fn heterogeneous_users_via_json_loader() {
    let text = r#"{
        "seed": 27,
        "testbed": "wwg",
        "broker": {"max_gridlets_per_pe": 2},
        "users": [
            {"gridlets": 15, "deadline": 3100, "budget": 22000, "policy": "cost"},
            {"gridlets": 15, "deadline": 3100, "budget": 22000, "policy": "time",
             "advisor": "native", "broker": {"max_gridlets_per_pe": 1},
             "submit_delay": 25}
        ]
    }"#;
    let scenario = parse_scenario(text).unwrap();
    assert_eq!(scenario.users[0].experiment.optimization, Optimization::Cost);
    assert_eq!(scenario.users[1].experiment.optimization, Optimization::Time);
    assert_eq!(scenario.users[1].broker.as_ref().unwrap().max_gridlets_per_pe, 1);
    assert_eq!(scenario.users[1].submit_delay, 25.0);

    let mut session = GridSession::new(&scenario);
    let report = session.run_to_completion();
    assert!(report.all_finished());
    assert_eq!(report.users[0].gridlets_completed, 15);
    assert_eq!(report.users[1].gridlets_completed, 15);
    // The delayed user starts later.
    assert!(report.users[1].start_time >= 25.0);
    let final_snap = session.snapshot();
    assert!(final_snap.users.iter().all(|u| u.state == "done"));
}

#[test]
fn observer_and_snapshot_consistent_with_report() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let count = Arc::new(AtomicU64::new(0));
    let sink = count.clone();
    let mut session = GridSession::new(&wwg_two_user(3, 10));
    session.set_observer(Box::new(move |_| {
        sink.fetch_add(1, Ordering::Relaxed);
    }));
    session.init();
    // Interleave stepping styles; the observer must see every event once.
    session.run_until(100.0);
    while session.step().is_some() {}
    let report = session.report().into_scenario_report();
    assert_eq!(count.load(Ordering::Relaxed), report.events);
    let snap = session.snapshot();
    assert_eq!(snap.events, report.events);
    for (progress, result) in snap.users.iter().zip(&report.users) {
        assert_eq!(progress.gridlets_completed, result.gridlets_completed);
        assert_eq!(progress.budget_spent.to_bits(), result.budget_spent.to_bits());
    }
}
