//! Trace sharing: one loaded log, `Arc`-shared across users and sweep
//! cells. Asserts (1) no per-cell or per-user copy of the job list exists —
//! every materialized scenario references the same allocation — and (2) the
//! shared representation changes no result bit relative to independently
//! owned job lists.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::gridsim::AllocPolicy;
use gridsim::scenario::{ResourceSpec, Scenario};
use gridsim::session::GridSession;
use gridsim::sweep::{run_sweep, SweepSpec};
use gridsim::workload::{TraceJob, TraceSelector, WorkloadSpec};
use std::sync::Arc;

fn resource(name: &str, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "test".into(),
        os: "linux".into(),
        machines: 1,
        pes_per_machine: 2,
        mips_per_pe: 100.0 * mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// A 30-job log split between SWF users 3 and 7, some jobs arriving online.
fn log() -> Vec<TraceJob> {
    (0..30)
        .map(|i| {
            let mut j = TraceJob::new(
                (i % 7) as f64 * 5.0,
                800.0 + (i * 37 % 400) as f64,
                1000,
                500,
            );
            j.user = Some(if i % 2 == 0 { 3 } else { 7 });
            j
        })
        .collect()
}

/// The cell grid both halves of the test run: a 3-cell deadline axis over a
/// two-user scenario replaying per-user slices of one log.
fn sweep_over(user3: WorkloadSpec, user7: WorkloadSpec) -> SweepSpec {
    let base = Scenario::builder()
        .resource(resource("R0", 1.0, 1.0))
        .resource(resource("R1", 1.2, 3.0))
        .user(
            ExperimentSpec::new(user3)
                .deadline(10_000.0)
                .budget(1e6)
                .optimization(Optimization::Cost),
        )
        .user(
            ExperimentSpec::new(user7)
                .deadline(10_000.0)
                .budget(1e6)
                .optimization(Optimization::Time),
        )
        .seed(19)
        .build();
    SweepSpec::over(base).deadlines(vec![60.0, 400.0, 10_000.0])
}

fn trace_arc(scenario: &Scenario, user: usize) -> &Arc<[TraceJob]> {
    let WorkloadSpec::Trace { jobs, .. } = &scenario.users[user].experiment.workload else {
        panic!("trace workload expected")
    };
    jobs
}

#[test]
fn one_log_is_shared_across_users_and_cells() {
    let shared: Arc<[TraceJob]> = log().into();
    let spec = sweep_over(
        WorkloadSpec::trace_selected_shared(shared.clone(), TraceSelector::user(3)),
        WorkloadSpec::trace_selected_shared(shared.clone(), TraceSelector::user(7)),
    );
    spec.validate().unwrap();

    // Both base users reference the one allocation…
    assert!(Arc::ptr_eq(trace_arc(&spec.base, 0), &shared));
    assert!(Arc::ptr_eq(trace_arc(&spec.base, 1), &shared));

    // …and so does every user of every materialized cell: a cell's scenario
    // clone never reloads or copies the log.
    let cells = spec.cells();
    assert_eq!(cells.len(), 3);
    for cell in &cells {
        let scenario = spec.scenario_for(cell);
        for user in 0..scenario.users.len() {
            assert!(
                Arc::ptr_eq(trace_arc(&scenario, user), &shared),
                "cell {} user {user} must share the base log",
                cell.index
            );
        }
    }

    // Cell scenarios only held transient Arc clones (dropped with them);
    // the strong count proves nothing retained a copy: our handle (1) plus
    // the two base users (2).
    assert_eq!(Arc::strong_count(&shared), 3);
}

#[test]
fn shared_and_owned_logs_produce_identical_results() {
    let jobs = log();
    let shared: Arc<[TraceJob]> = jobs.clone().into();

    // Shared: both users hold Arc clones of one allocation.
    let shared_spec = sweep_over(
        WorkloadSpec::trace_selected_shared(shared.clone(), TraceSelector::user(3)),
        WorkloadSpec::trace_selected_shared(shared, TraceSelector::user(7)),
    );
    // Owned: each user gets its own independently allocated copy (the
    // pre-Arc representation, emulated).
    let owned_spec = sweep_over(
        WorkloadSpec::trace_selected(jobs.clone(), TraceSelector::user(3)),
        WorkloadSpec::trace_selected(jobs.clone(), TraceSelector::user(7)),
    );

    let a = run_sweep(&shared_spec, 2).unwrap();
    let b = run_sweep(&owned_spec, 2).unwrap();
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.report.events, y.report.events);
        assert_eq!(x.report.end_time.to_bits(), y.report.end_time.to_bits());
        for (u, v) in x.report.users.iter().zip(&y.report.users) {
            assert_eq!(u.gridlets_completed, v.gridlets_completed);
            assert_eq!(u.gridlets_total, v.gridlets_total);
            assert_eq!(u.budget_spent.to_bits(), v.budget_spent.to_bits());
            assert_eq!(u.finish_time.to_bits(), v.finish_time.to_bits());
        }
    }

    // And a sweep cell equals the same scenario run directly (the engine
    // adds orchestration, never semantics) — including for shared traces.
    let direct = GridSession::new(&shared_spec.scenario_for(&shared_spec.cells()[2]))
        .run_to_completion();
    let engine = &a.outcomes[2].report;
    assert_eq!(direct.events, engine.events);
    assert_eq!(direct.end_time.to_bits(), engine.end_time.to_bits());
}

#[test]
fn sweeping_does_not_mutate_the_shared_log() {
    let shared: Arc<[TraceJob]> = log().into();
    let pristine: Vec<TraceJob> = shared.to_vec();
    let spec = sweep_over(
        WorkloadSpec::trace_selected_shared(shared.clone(), TraceSelector::user(3))
            .with_staging(64, 32),
        WorkloadSpec::trace_selected_shared(shared.clone(), TraceSelector::user(7)),
    );
    run_sweep(&spec, 2).unwrap();
    // Even with a staging override in play (copy-on-write at
    // materialization), the shared jobs are byte-for-byte untouched.
    assert_eq!(&shared[..], &pristine[..]);
}
