//! Flow-level network semantics: fair-share contention, rescheduled finish
//! events, determinism across worker counts, and the guarantee that the
//! default (`baud`) path never touches the flow machinery.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::des::{Ctx, Entity, EntityId, Event, EventKind, Simulation};
use gridsim::gridsim::{AllocPolicy, BaudLink, Msg};
use gridsim::network::FlowLink;
use gridsim::output::sweep::{aggregate_csv, long_csv};
use gridsim::scenario::{NetworkSpec, ResourceSpec, Scenario};
use gridsim::session::GridSession;
use gridsim::sweep::{run_sweep, SweepSpec};
use gridsim::workload::{ArrivalProcess, WorkloadSpec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn spec(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
    ResourceSpec {
        name: name.into(),
        arch: "t".into(),
        os: "l".into(),
        machines: 1,
        pes_per_machine: pes,
        mips_per_pe: mips,
        policy: AllocPolicy::TimeShared,
        price,
        time_zone: 0.0,
        calendar: None,
    }
}

/// Sends `n` equal-sized messages to `sink` at t=0 (concurrent flows).
struct Burst {
    sink: EntityId,
    n: usize,
    bytes: u64,
}

impl Entity<Msg> for Burst {
    fn name(&self) -> &str {
        "burst"
    }
    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        for i in 0..self.n {
            ctx.send(self.sink, i as i64, Some(Msg::Control(i as u64)), self.bytes);
        }
    }
    fn on_event(&mut self, _ctx: &mut Ctx<Msg>, _ev: Event<Msg>) {}
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Records the arrival time and payload of every delivery.
struct Sink {
    arrivals: Vec<(f64, i64, Option<u64>)>,
}

impl Entity<Msg> for Sink {
    fn name(&self) -> &str {
        "sink"
    }
    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        let payload = match ev.data.take() {
            Some(Msg::Control(x)) => Some(x),
            _ => None,
        };
        self.arrivals.push((ctx.now(), ev.tag, payload));
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Run `n` simultaneous equal flows over one shared pair of access links and
/// return the delivery times.
fn burst_arrivals(n: usize, capacity: f64, latency: f64) -> Vec<(f64, i64, Option<u64>)> {
    let mut sim: Simulation<Msg> = Simulation::new();
    sim.set_link_model(Box::new(FlowLink::new(capacity, latency)));
    let sink = sim.add(Box::new(Sink { arrivals: vec![] }));
    sim.add(Box::new(Burst { sink, n, bytes: 1_200 }));
    sim.run();
    assert_eq!(sim.active_flows(), 0, "all flows drained");
    sim.get::<Sink>(sink).unwrap().arrivals.clone()
}

#[test]
fn n_equal_flows_finish_at_n_times_solo_time() {
    // 1200 bytes at 9600 bits/unit = exactly 1.0 time units solo.
    let solo = burst_arrivals(1, 9_600.0, 0.0);
    assert_eq!(solo.len(), 1);
    let t_solo = solo[0].0;
    assert!((t_solo - 1.0).abs() < 1e-12, "solo transfer time: {t_solo}");

    for n in [2usize, 4, 8] {
        let arrivals = burst_arrivals(n, 9_600.0, 0.0);
        assert_eq!(arrivals.len(), n, "every flow delivers exactly once");
        let expect = t_solo * n as f64;
        for (t, _, _) in &arrivals {
            // Equal flows share capacity/n throughout, so each finishes at
            // n x the solo time (fair share, not FIFO serialization).
            assert!(
                (t - expect).abs() / expect < 1e-9,
                "{n} fair-shared flows finish at {n}x solo: got {t}, want {expect}"
            );
        }
        // Payloads and tags survive the flow path intact.
        let mut seen: Vec<u64> = arrivals.iter().map(|(_, _, p)| p.unwrap()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>());
    }
}

#[test]
fn flow_latency_is_added_after_the_transfer() {
    let arrivals = burst_arrivals(1, 9_600.0, 0.25);
    assert!((arrivals[0].0 - 1.25).abs() < 1e-12, "1.0 transfer + 0.25 latency");
}

/// A small flow-network scenario with online arrivals (released through the
/// contended network) for the sweep-determinism checks.
fn flow_sweep_spec() -> SweepSpec {
    let workload = WorkloadSpec::online(
        WorkloadSpec::task_farm(8, 1_000.0, 0.10),
        ArrivalProcess::Poisson { mean_interarrival: 5.0 },
    );
    let base = Scenario::builder()
        .resource(spec("R0", 2, 100.0, 1.0))
        .resource(spec("R1", 2, 100.0, 2.0))
        .user(ExperimentSpec::new(workload.clone()).deadline(1e6).budget(1e9))
        .user(ExperimentSpec::new(workload).deadline(1e6).budget(1e9))
        .seed(11)
        .network(NetworkSpec::Flow {
            default_capacity: 9_600.0,
            latency: 0.05,
            capacities: vec![("R0".into(), 19_200.0)],
        })
        .build();
    SweepSpec::over(base).link_capacities(vec![2_400.0, 9_600.0])
}

#[test]
fn flow_sweep_is_byte_identical_at_any_jobs_value() {
    let s = flow_sweep_spec();
    let serial = run_sweep(&s, 1).unwrap();
    let parallel = run_sweep(&s, 4).unwrap();
    assert_eq!(
        long_csv(&s, &serial).to_string(),
        long_csv(&s, &parallel).to_string(),
        "flow-model long CSV must not depend on --jobs"
    );
    assert_eq!(
        aggregate_csv(&s, &serial).to_string(),
        aggregate_csv(&s, &parallel).to_string(),
        "flow-model aggregate CSV must not depend on --jobs"
    );
}

#[test]
fn link_capacity_contention_slows_online_arrivals() {
    let s = flow_sweep_spec();
    let results = run_sweep(&s, 2).unwrap();
    assert_eq!(results.outcomes.len(), 2);
    // Axis order puts 2400 b/u first; a 4x slower shared link cannot beat
    // the faster one (same seed: common random numbers across cells).
    let t_slow = results.outcomes[0].report.mean_finish_time();
    let t_fast = results.outcomes[1].report.mean_finish_time();
    assert!(
        t_slow > t_fast,
        "2400 b/u links must finish later than 9600 b/u: {t_slow} vs {t_fast}"
    );
    for o in &results.outcomes {
        for u in &o.report.users {
            assert_eq!(u.gridlets_completed, u.gridlets_total, "loose constraints");
        }
    }
}

/// The default path must never touch the flow machinery: a baud-network run
/// processes zero `FlowWake` events and is bit-identical run to run.
#[test]
fn baud_networks_never_create_flows() {
    let run = || {
        let mut sim: Simulation<Msg> = Simulation::new();
        sim.set_link_model(Box::new(
            BaudLink::new().with_default_rate(9_600.0).with_default_latency(0.1),
        ));
        let wakes = Arc::new(AtomicU64::new(0));
        let w = Arc::clone(&wakes);
        sim.set_observer(Box::new(move |ev| {
            if ev.kind == EventKind::FlowWake {
                w.fetch_add(1, Ordering::Relaxed);
            }
        }));
        let sink = sim.add(Box::new(Sink { arrivals: vec![] }));
        sim.add(Box::new(Burst { sink, n: 6, bytes: 1_200 }));
        sim.run();
        assert_eq!(sim.active_flows(), 0);
        assert_eq!(wakes.load(Ordering::Relaxed), 0, "baud path is flow-free");
        sim.get::<Sink>(sink).unwrap().arrivals.clone()
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), 6);
    for ((t1, tag1, _), (t2, tag2, _)) in a.iter().zip(&b) {
        assert_eq!(t1.to_bits(), t2.to_bits(), "baud runs are bit-identical");
        assert_eq!(tag1, tag2);
    }
    // Serialized baud semantics, untouched by this subsystem: every message
    // takes latency + bytes*8/rate from its send time, independently.
    for (t, _, _) in &a {
        assert!((t - 1.1).abs() < 1e-12, "independent baud delay: {t}");
    }
}

#[test]
fn default_scenarios_do_not_change_under_the_flow_subsystem() {
    // A scenario with no "network" block (instantaneous default): two runs
    // are bit-identical, exercising the full broker stack with the flow
    // machinery compiled in but never engaged.
    let build = || {
        Scenario::builder()
            .resource(spec("R0", 2, 100.0, 1.0))
            .user(
                ExperimentSpec::task_farm(10, 1_000.0, 0.0)
                    .deadline(10_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            .seed(3)
            .build()
    };
    let a = GridSession::new(&build()).run_to_completion();
    let b = GridSession::new(&build()).run_to_completion();
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits());
    assert_eq!(
        a.users[0].finish_time.to_bits(),
        b.users[0].finish_time.to_bits()
    );
    assert_eq!(a.users[0].gridlets_completed, 10);
}
