//! End-to-end broker scenarios on the WWG testbed (Table 2): the shape
//! checks that pin the paper's single-user evaluation (Figures 21–27).

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;

fn run(deadline: f64, budget: f64, opt: Optimization, n: usize) -> gridsim::scenario::ScenarioReport {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(n, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(opt),
        )
        .seed(31)
        .build();
    GridSession::new(&scenario).run_to_completion()
}

#[test]
fn relaxed_deadline_all_on_cheapest_fig27() {
    // Paper Fig 27: deadline 3100, ample budget → the broker leases just the
    // cheapest resource (R8) and still finishes everything.
    let report = run(3100.0, 22_000.0, Optimization::Cost, 200);
    let u = &report.users[0];
    assert_eq!(u.gridlets_completed, 200, "all Gridlets done");
    let r8 = u.per_resource.iter().find(|r| r.name == "R8").unwrap();
    assert!(
        r8.gridlets_completed >= 195,
        "R8 should take (almost) everything, got {}",
        r8.gridlets_completed
    );
    // And the total spend is near the all-on-R8 floor (~200·10500/380 G$).
    assert!(u.budget_spent < 7_000.0, "cheap completion, spent {}", u.budget_spent);
}

#[test]
fn tight_deadline_uses_expensive_resources_fig25() {
    // Paper Fig 25: a tight deadline with a high budget → the broker must
    // lease many resources including expensive ones. Deadline 60 is provably
    // infeasible for all 200 jobs (2.1e6 MI / 27.6k aggregate MIPS ≈ 76).
    let report = run(60.0, 22_000.0, Optimization::Cost, 200);
    let u = &report.users[0];
    let used = u.per_resource.iter().filter(|r| r.gridlets_completed > 0).count();
    assert!(used >= 6, "tight deadline spreads across resources, used {used}");
    assert!(
        u.gridlets_completed < 200,
        "a 60-unit deadline cannot finish 200×10.5k-MI jobs on the WWG"
    );
    assert!(u.gridlets_completed > 20, "but a good chunk completes");
}

#[test]
fn completions_monotone_in_budget_fig21() {
    // Paper Fig 21: at a tight deadline, more budget → more Gridlets done.
    let mut last = 0;
    let mut grew = false;
    for budget in [6_000.0, 12_000.0, 22_000.0] {
        let done = run(100.0, budget, Optimization::Cost, 200).users[0].gridlets_completed;
        assert!(done + 12 >= last, "roughly monotone: {done} after {last}");
        if done > last {
            grew = true;
        }
        last = done;
    }
    assert!(grew, "budget must buy additional completions somewhere");
}

#[test]
fn completions_monotone_in_deadline_fig22() {
    // Paper Fig 22: at a low budget, relaxing the deadline → more done.
    let mut results = vec![];
    for deadline in [100.0, 1_100.0, 3_100.0] {
        results.push(run(deadline, 6_000.0, Optimization::Cost, 200).users[0].gridlets_completed);
    }
    assert!(results[0] < results[2], "relaxed deadline processes more: {results:?}");
    assert!(results[1] <= results[2] + 10);
}

#[test]
fn budget_spent_bounded_and_utilized_fig24() {
    // Tight deadline: spend approaches the budget. Relaxed: spend stays at
    // the cheap floor regardless of budget.
    let tight = run(100.0, 10_000.0, Optimization::Cost, 200).users[0].budget_spent;
    assert!(tight <= 10_000.0 + 1e-6, "hard budget bound");
    assert!(tight > 5_000.0, "tight deadline spends most of the budget: {tight}");
    let relaxed_lo = run(3_100.0, 10_000.0, Optimization::Cost, 200).users[0].budget_spent;
    let relaxed_hi = run(3_100.0, 22_000.0, Optimization::Cost, 200).users[0].budget_spent;
    assert!(
        (relaxed_lo - relaxed_hi).abs() < 0.15 * relaxed_lo.max(relaxed_hi),
        "relaxed deadline: spend ≈ cheap floor regardless of budget ({relaxed_lo} vs {relaxed_hi})"
    );
}

#[test]
fn time_opt_faster_but_costlier_than_cost_opt() {
    // The classic Nimrod-G trade-off, with deadline/budget slack so both
    // policies finish all jobs.
    let cost = run(3_100.0, 60_000.0, Optimization::Cost, 100);
    let time = run(3_100.0, 60_000.0, Optimization::Time, 100);
    let (cu, tu) = (&cost.users[0], &time.users[0]);
    assert_eq!(cu.gridlets_completed, 100);
    assert_eq!(tu.gridlets_completed, 100);
    let cost_elapsed = cu.finish_time - cu.start_time;
    let time_elapsed = tu.finish_time - tu.start_time;
    assert!(
        time_elapsed < cost_elapsed,
        "time-opt finishes sooner ({time_elapsed} vs {cost_elapsed})"
    );
    assert!(
        tu.budget_spent > cu.budget_spent,
        "and pays for it ({} vs {})",
        tu.budget_spent,
        cu.budget_spent
    );
}

#[test]
fn cost_time_between_cost_and_time() {
    let cost = run(3_100.0, 60_000.0, Optimization::Cost, 100);
    let ct = run(3_100.0, 60_000.0, Optimization::CostTime, 100);
    let cu = &cost.users[0];
    let ctu = &ct.users[0];
    assert_eq!(ctu.gridlets_completed, 100);
    // Cost-time must not be more expensive than cost-opt by more than the
    // equal-price-group rearrangement effect (~small), and should not be
    // slower than cost-opt.
    let cost_elapsed = cu.finish_time - cu.start_time;
    let ct_elapsed = ctu.finish_time - ctu.start_time;
    assert!(
        ct_elapsed <= cost_elapsed * 1.05,
        "cost-time at least as fast as cost ({ct_elapsed} vs {cost_elapsed})"
    );
}

#[test]
fn d_and_b_factors_scale_constraints() {
    // D=B=1 must always complete (Eqs 1-2 guarantee).
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .d_factor(1.0)
                .b_factor(1.0)
                .optimization(Optimization::Cost),
        )
        .seed(5)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    assert_eq!(report.users[0].gridlets_completed, 50);
    // Tiny factors process little or nothing.
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .d_factor(0.0)
                .b_factor(0.0)
                .optimization(Optimization::Cost),
        )
        .seed(5)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    assert!(
        report.users[0].gridlets_completed < 50,
        "D=B=0 is the infeasible corner"
    );
}

#[test]
fn trace_is_recorded_and_monotone() {
    let report = run(1_100.0, 22_000.0, Optimization::Cost, 100);
    let trace = &report.users[0].trace;
    assert!(!trace.is_empty(), "trace must be recorded");
    // Per-resource series must be monotone in completions and spend.
    use std::collections::HashMap;
    let mut last: HashMap<&str, (usize, f64)> = HashMap::new();
    for p in trace {
        let e = last.entry(p.resource.as_str()).or_insert((0, 0.0));
        assert!(p.completed >= e.0, "completions monotone on {}", p.resource);
        assert!(p.spent >= e.1 - 1e-9, "spend monotone on {}", p.resource);
        *e = (p.completed, p.spent);
    }
}

#[test]
fn none_opt_spreads_widely() {
    let report = run(3_100.0, 60_000.0, Optimization::NoOpt, 100);
    let u = &report.users[0];
    let used = u.per_resource.iter().filter(|r| r.gridlets_completed > 0).count();
    assert!(used >= 8, "none-opt uses (almost) all resources: {used}");
}
