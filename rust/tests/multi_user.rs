//! Multi-user competition experiments (paper §5.4, Figures 33–38): varying
//! numbers of identical users, each with a private broker, competing for the
//! same WWG testbed.

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::{Scenario, ScenarioReport};
use gridsim::session::GridSession;

fn run_users(n_users: usize, deadline: f64, budget: f64, gridlets: usize) -> ScenarioReport {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .users(
            n_users,
            ExperimentSpec::task_farm(gridlets, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(Optimization::Cost),
        )
        .seed(17)
        .build();
    GridSession::new(&scenario).run_to_completion()
}

#[test]
fn per_user_completions_decay_with_competition_fig33() {
    // Deadline 3100: more users competing → fewer Gridlets per user.
    let one = run_users(1, 3_100.0, 12_000.0, 60);
    let ten = run_users(10, 3_100.0, 12_000.0, 60);
    assert_eq!(one.users[0].gridlets_completed, 60, "single user finishes all");
    let mean_ten = ten.mean_completed();
    assert!(mean_ten > 10.0, "everyone gets a share: mean {mean_ten}");
}

#[test]
fn users_do_not_starve_under_competition() {
    let report = run_users(8, 3_100.0, 12_000.0, 40);
    for (i, u) in report.users.iter().enumerate() {
        assert!(
            u.gridlets_completed > 0,
            "user {i} starved: {} completed",
            u.gridlets_completed
        );
    }
}

#[test]
fn relaxed_deadline_restores_completions_fig36() {
    // Deadline 10000 (cf. 3100): the same competition completes at least as
    // much per user (paper: "improved substantially due to the relaxed
    // deadline").
    let tight = run_users(20, 3_100.0, 6_000.0, 60);
    let relaxed = run_users(20, 10_000.0, 6_000.0, 60);
    assert!(
        relaxed.mean_completed() >= tight.mean_completed(),
        "relaxed {} vs tight {}",
        relaxed.mean_completed(),
        tight.mean_completed()
    );
}

#[test]
fn heavy_competition_stretches_termination_fig34() {
    // Paper Fig 34: with many users at deadline 3100, termination times
    // stretch toward (and past) the deadline — brokers wait for jobs already
    // deployed under optimistic share estimates.
    let light = run_users(1, 3_100.0, 12_000.0, 60);
    let heavy = run_users(12, 3_100.0, 12_000.0, 60);
    assert!(
        heavy.mean_finish_time() > light.mean_finish_time(),
        "competition stretches termination: {} vs {}",
        heavy.mean_finish_time(),
        light.mean_finish_time()
    );
    let max_finish = heavy
        .users
        .iter()
        .map(|u| u.finish_time - u.start_time)
        .fold(0.0f64, f64::max);
    // Bounded: in-flight gridlets are finite work.
    assert!(max_finish < 3_100.0 * 2.0, "bounded overrun: {max_finish}");
}

#[test]
fn relaxed_deadline_terminates_within_deadline_fig37() {
    // Paper Fig 37: at deadline 10000 the broker can revisit past decisions
    // and terminate in time.
    let report = run_users(10, 10_000.0, 12_000.0, 40);
    for u in &report.users {
        assert!(
            u.finish_time - u.start_time <= 10_000.0 * 1.05,
            "termination {} beyond relaxed deadline",
            u.finish_time - u.start_time
        );
    }
}

#[test]
fn budget_spent_tracks_completions_fig35() {
    // Figs 33 vs 35: the spend curve mirrors the completion curve.
    let report = run_users(10, 3_100.0, 12_000.0, 60);
    for u in &report.users {
        assert!(u.budget_spent <= 12_000.0 + 1e-6, "hard budget bound");
        let per_job = u.budget_spent / u.gridlets_completed.max(1) as f64;
        // 10.5k-MI jobs cost ~27–130 G$ across Table 2 prices.
        assert!(per_job > 20.0 && per_job < 200.0, "per-job cost {per_job}");
    }
}

#[test]
fn more_users_more_total_throughput_until_saturation() {
    // System-level: total completions grow with users until the grid
    // saturates (then flatten, never collapse).
    let totals: Vec<f64> = [1, 5, 10]
        .iter()
        .map(|&n| run_users(n, 3_100.0, 12_000.0, 40).mean_completed() * n as f64)
        .collect();
    assert!(totals[1] > totals[0], "5 users beat 1: {totals:?}");
    assert!(totals[2] >= totals[1] * 0.7, "no collapse at 10 users: {totals:?}");
}

#[test]
fn deterministic_multi_user_runs() {
    let a = run_users(6, 3_100.0, 12_000.0, 30);
    let b = run_users(6, 3_100.0, 12_000.0, 30);
    assert_eq!(a.events, b.events);
    for (x, y) in a.users.iter().zip(&b.users) {
        assert_eq!(x.gridlets_completed, y.gridlets_completed);
        assert_eq!(x.budget_spent, y.budget_spent);
    }
}
