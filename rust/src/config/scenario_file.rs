//! JSON scenario loader: a complete grid + users description in one file.
//!
//! ```json
//! {
//!   "seed": 42,
//!   "advisor": "native",
//!   "network": {"type": "instantaneous"},
//!   "broker": {"max_gridlets_per_pe": 2},
//!   "resources": [
//!     {"name": "R0", "machines": 1, "pes_per_machine": 4, "mips": 515,
//!      "policy": "time", "price": 8.0, "time_zone": 10.0},
//!     {"name": "R7", "machines": 16, "pes_per_machine": 1, "mips": 410,
//!      "policy": "space-fcfs", "price": 4.0}
//!   ],
//!   "users": [
//!     {"gridlets": 200, "length_mi": 10000, "variation": 0.1,
//!      "deadline": 3100, "budget": 22000, "optimization": "cost"},
//!     {"gridlets": 100, "deadline": 3100, "budget": 9000,
//!      "policy": "time", "advisor": "native",
//!      "broker": {"max_gridlets_per_pe": 1}, "submit_delay": 50},
//!     {"workload": {"type": "online_arrivals", "process": "poisson",
//!                   "mean_interarrival": 5.0,
//!                   "workload": {"type": "heavy_tailed", "gridlets": 100,
//!                                "length_mi": 8000, "heavy_fraction": 0.1,
//!                                "heavy_multiplier": 20}},
//!      "deadline": 3100, "budget": 22000}
//!   ]
//! }
//! ```
//!
//! `"testbed": "wwg"` can replace the `resources` array to pull in Table 2.
//! A top-level `"sweep"` section (see [`parse_sweep`]) turns the file into a
//! declarative parameter sweep over the base scenario for `repro sweep`.
//! A top-level `"faults"` block drives resources with failure–repair
//! processes (see [`crate::faults`]), a per-resource `"calendar"` block adds
//! background local load, and the broker's `"resubmission"` key picks what
//! happens to gridlets lost to failures.
//!
//! A user's application is either the flat task-farm keys
//! (`gridlets`/`length_mi`/`variation`/`input_bytes`/`output_bytes` — the
//! historical shape, still the default) or a `"workload"` object selecting
//! any [`crate::workload::WorkloadSpec`] variant (`task_farm`,
//! `heavy_tailed`, `explicit`, `trace`, `concat`, `mix`,
//! `online_arrivals`); giving both is rejected as ambiguous. Trace
//! workloads load legacy 4-column files and full 18-column SWF logs
//! (auto-detected), take SWF conversion knobs (`mips`, `statuses`,
//! `input_bytes`/`output_bytes`) and a `"select"` object
//! (`users`/`partitions`/`max_jobs`) slicing the log per simulated user;
//! relative `path`s — including inside `concat`/`mix` parts — resolve
//! against the scenario file's directory.
//!
//! The loader is strict: unknown keys at any level are rejected with the
//! allowed-key list (and a did-you-mean hint), so a typo like `"dedline"`
//! fails loudly instead of silently falling back to a default. Per-user
//! `policy` (alias of `optimization`), `advisor` and `broker` override the
//! scenario-level defaults (see [`crate::scenario::UserSpec`]).

use super::testbed::wwg_testbed;
use crate::broker::broker::{BrokerConfig, ResubmissionPolicy};
use crate::broker::{ExperimentSpec, Optimization};
use crate::faults::{FaultProcess, FaultsSpec};
use crate::gridsim::{AllocPolicy, ResourceCalendar, SpacePolicy};
use crate::market::{MarketSpec, PriceModel};
use crate::scenario::{AdvisorKind, NetworkSpec, ResourceSpec, Scenario, UserSpec};
use crate::sweep::SweepSpec;
use crate::util::json::{self, Value};
use crate::workload::{
    load_trace_file_with, parse_dot, ArrivalProcess, DagNode, JobSpec, RateEnvelope,
    SwfLoadOptions, TraceJob, TraceSelector, WorkloadSpec,
};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const SCENARIO_KEYS: &[&str] = &[
    "seed", "advisor", "network", "broker", "testbed", "resources", "users", "max_time",
    "sweep", "faults", "pricing", "spot",
];
const NETWORK_KEYS: &[&str] = &["type", "model", "rate", "latency", "capacity", "capacities"];
const SWEEP_KEYS: &[&str] = &[
    "deadlines",
    "budgets",
    "users",
    "policies",
    "resources",
    "replications",
    "mean_interarrivals",
    "heavy_fractions",
    "trace_selectors",
    "mix_weights",
    "link_capacities",
    "mtbf_scalings",
    "spot_discounts",
];
const BROKER_KEYS: &[&str] =
    &["tick_fraction", "min_tick", "trace_interval", "max_gridlets_per_pe", "resubmission"];
const RESUBMISSION_KEYS: &[&str] = &["policy", "max_attempts", "backoff"];
const RESOURCE_KEYS: &[&str] = &[
    "name", "arch", "os", "machines", "pes_per_machine", "pes", "mips", "policy", "price",
    "time_zone", "calendar",
];
const CALENDAR_KEYS: &[&str] =
    &["time_zone", "peak_load", "off_peak_load", "holiday_load", "units_per_hour"];
const FAULTS_KEYS: &[&str] = &["default", "overrides", "mtbf_scaling"];
const PRICING_KEYS: &[&str] = &["default", "overrides"];
const PRICE_MODEL_TYPES: &[&str] = &["static", "utilization_linear", "utilization_step"];
const PRICE_STATIC_KEYS: &[&str] = &["model", "price"];
const PRICE_LINEAR_KEYS: &[&str] = &["model", "base", "slope", "floor", "cap"];
const PRICE_STEP_KEYS: &[&str] = &["model", "base", "steps", "floor", "cap"];
const FAULT_PROCESS_TYPES: &[&str] = &["exponential", "weibull", "trace"];
const FAULT_EXPONENTIAL_KEYS: &[&str] = &["process", "mtbf", "mttr"];
const FAULT_WEIBULL_KEYS: &[&str] = &["process", "mtbf", "mttr", "shape"];
const FAULT_TRACE_KEYS: &[&str] = &["process", "intervals"];
const USER_KEYS: &[&str] = &[
    "workload",
    "gridlets",
    "length_mi",
    "variation",
    "deadline",
    "d_factor",
    "budget",
    "b_factor",
    "optimization",
    "policy",
    "advisor",
    "broker",
    "input_bytes",
    "output_bytes",
    "submit_delay",
    "link_rate",
    "max_spot_price",
];
/// The historical flat task-farm keys; mutually exclusive with `"workload"`.
const FLAT_WORKLOAD_KEYS: &[&str] =
    &["gridlets", "length_mi", "variation", "input_bytes", "output_bytes"];
const WORKLOAD_TYPES: &[&str] = &[
    "task_farm",
    "heavy_tailed",
    "explicit",
    "trace",
    "concat",
    "mix",
    "online_arrivals",
    "dag",
];
const WORKLOAD_TASK_FARM_KEYS: &[&str] =
    &["type", "gridlets", "length_mi", "variation", "input_bytes", "output_bytes"];
const WORKLOAD_HEAVY_KEYS: &[&str] = &[
    "type",
    "gridlets",
    "length_mi",
    "heavy_fraction",
    "heavy_multiplier",
    "input_bytes",
    "output_bytes",
];
const WORKLOAD_EXPLICIT_KEYS: &[&str] = &["type", "jobs"];
const WORKLOAD_DAG_KEYS: &[&str] = &["type", "nodes", "edges", "file"];
const DAG_NODE_KEYS: &[&str] = &["id", "length_mi", "input_bytes", "output_bytes"];
const WORKLOAD_TRACE_KEYS: &[&str] =
    &["type", "path", "select", "mips", "statuses", "input_bytes", "output_bytes"];
const WORKLOAD_CONCAT_KEYS: &[&str] = &["type", "parts"];
const WORKLOAD_MIX_KEYS: &[&str] = &["type", "parts", "weights"];
const WORKLOAD_ONLINE_KEYS: &[&str] = &[
    "type",
    "process",
    "mean_interarrival",
    "interval",
    "period",
    "envelope",
    "amplitude",
    "workload",
];
const JOB_KEYS: &[&str] = &["length_mi", "input_bytes", "output_bytes"];
const SELECT_KEYS: &[&str] = &["users", "partitions", "max_jobs"];

/// Levenshtein distance (for did-you-mean hints on unknown keys).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for i in 1..=a.len() {
        let mut cur = vec![i; b.len() + 1];
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        prev = cur;
    }
    prev[b.len()]
}

fn nearest<'a>(key: &str, allowed: &[&'a str]) -> Option<&'a str> {
    allowed
        .iter()
        .copied()
        .map(|a| (edit_distance(key, a), a))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, a)| a)
}

/// Reject any object key outside `allowed` (with a helpful message) and any
/// duplicated key (the hand-rolled parser keeps both; lookups would silently
/// take the first).
fn reject_unknown_keys(v: &Value, what: &str, allowed: &[&str]) -> Result<()> {
    let Value::Obj(fields) = v else {
        bail!("{what} must be a JSON object");
    };
    let mut seen = std::collections::BTreeSet::new();
    for (key, _) in fields {
        if !allowed.contains(&key.as_str()) {
            let hint = nearest(key, allowed)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!(
                "unknown key {key:?} in {what}{hint}; allowed keys: {}",
                allowed.join(", ")
            );
        }
        if !seen.insert(key.as_str()) {
            bail!("duplicate key {key:?} in {what}");
        }
    }
    Ok(())
}

/// Typed optional getters: a known key holding a wrong-typed value is a
/// hard error, not a silent fallback to the default (same promise as the
/// unknown-key rejection).
fn opt_f64(v: &Value, what: &str, key: &str) -> Result<Option<f64>> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_f64() {
            Some(n) => Ok(Some(n)),
            None => bail!("{what}: {key:?} must be a number"),
        },
    }
}

/// The shared strictness rule for integer-valued JSON numbers: 2^53 is the
/// last f64 that can represent every integer exactly; past it (or for
/// negative/fractional values) an `as` cast would silently mangle input.
fn f64_to_usize(n: f64, what: &str, key: &str) -> Result<usize> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0;
    if n >= 0.0 && n.fract() == 0.0 && n < MAX_EXACT {
        Ok(n as usize)
    } else {
        bail!("{what}: {key:?} must be a non-negative integer (< 2^53), got {n}")
    }
}

fn opt_usize(v: &Value, what: &str, key: &str) -> Result<Option<usize>> {
    match opt_f64(v, what, key)? {
        None => Ok(None),
        Some(n) => f64_to_usize(n, what, key).map(Some),
    }
}

fn opt_str<'a>(v: &'a Value, what: &str, key: &str) -> Result<Option<&'a str>> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_str() {
            Some(s) => Ok(Some(s)),
            None => bail!("{what}: {key:?} must be a string"),
        },
    }
}

fn parse_advisor(s: &str) -> Result<AdvisorKind> {
    match s {
        "native" => Ok(AdvisorKind::Native),
        "xla" => Ok(AdvisorKind::Xla),
        other => bail!("unknown advisor {other:?} (native|xla)"),
    }
}

/// Parse a broker tuning object on top of `base` (partial overrides).
fn parse_broker_config(v: &Value, base: &BrokerConfig) -> Result<BrokerConfig> {
    reject_unknown_keys(v, "broker config", BROKER_KEYS)?;
    let mut config = base.clone();
    if let Some(x) = opt_f64(v, "broker config", "tick_fraction")? {
        config.tick_fraction = x;
    }
    if let Some(x) = opt_f64(v, "broker config", "min_tick")? {
        config.min_tick = x;
    }
    if let Some(x) = opt_f64(v, "broker config", "trace_interval")? {
        config.trace_interval = x;
    }
    if let Some(x) = opt_usize(v, "broker config", "max_gridlets_per_pe")? {
        config.max_gridlets_per_pe = x;
    }
    if let Some(r) = v.get("resubmission") {
        config.resubmission = parse_resubmission(r)?;
    }
    Ok(config)
}

/// Parse the broker's `"resubmission"` policy for gridlets lost to resource
/// failures: the string shorthands `"retry"` (unbounded, adaptive backoff —
/// the default) and `"abandon"`, or an object
/// `{"policy": "retry", "max_attempts": 3, "backoff": 25}` where
/// `max_attempts` 0 (the default) means unbounded and `backoff` 0 (the
/// default) means the adaptive deadline-proportional delay. The knobs only
/// apply to `"retry"` — an `"abandon"` carrying them is rejected rather than
/// silently ignoring a stated bound.
fn parse_resubmission(v: &Value) -> Result<ResubmissionPolicy> {
    let parse_name = |s: &str| -> Result<ResubmissionPolicy> {
        match s {
            "retry" => Ok(ResubmissionPolicy::default_retry()),
            "abandon" => Ok(ResubmissionPolicy::Abandon),
            other => {
                let hint = nearest(other, &["retry", "abandon"])
                    .map(|s| format!(" (did you mean {s:?}?)"))
                    .unwrap_or_default();
                bail!("unknown resubmission policy {other:?}{hint}; allowed: retry, abandon")
            }
        }
    };
    match v {
        Value::Str(s) => parse_name(s),
        Value::Obj(_) => {
            reject_unknown_keys(v, "broker resubmission", RESUBMISSION_KEYS)?;
            let name = opt_str(v, "broker resubmission", "policy")?
                .ok_or_else(|| anyhow!("broker resubmission: missing \"policy\""))?;
            let policy = parse_name(name)?;
            match policy {
                ResubmissionPolicy::Abandon => {
                    for key in ["max_attempts", "backoff"] {
                        if v.get(key).is_some() {
                            bail!(
                                "broker resubmission: {key:?} only applies to \
                                 {{\"policy\": \"retry\"}}"
                            );
                        }
                    }
                    Ok(policy)
                }
                ResubmissionPolicy::RetryWithBackoff { mut max_attempts, mut backoff } => {
                    if let Some(n) = opt_usize(v, "broker resubmission", "max_attempts")? {
                        max_attempts = n;
                    }
                    if let Some(b) = opt_f64(v, "broker resubmission", "backoff")? {
                        check_link_param("broker resubmission", "backoff", b, true)?;
                        backoff = b;
                    }
                    Ok(ResubmissionPolicy::RetryWithBackoff { max_attempts, backoff })
                }
            }
        }
        _ => bail!(
            "broker resubmission must be \"retry\", \"abandon\" or an object like \
             {{\"policy\": \"retry\", \"max_attempts\": 3}}"
        ),
    }
}

/// Parse a scenario from JSON text. A file carrying a `"sweep"` section is
/// rejected — a sweep is not one scenario; run it with `repro sweep`.
/// Relative trace-workload paths resolve against the process CWD; use
/// [`parse_scenario_at`] to resolve them against the scenario file's
/// directory instead.
pub fn parse_scenario(text: &str) -> Result<Scenario> {
    parse_scenario_at(text, None)
}

/// [`parse_scenario`] with an explicit base directory for relative
/// trace-workload paths (pass the scenario file's parent directory, so a
/// trace next to its scenario file loads regardless of the CWD).
pub fn parse_scenario_at(text: &str, base_dir: Option<&Path>) -> Result<Scenario> {
    let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
    reject_unknown_keys(&root, "scenario", SCENARIO_KEYS)?;
    if root.get("sweep").is_some() {
        bail!(
            "this file declares a \"sweep\" section; run it with \
             `repro sweep --scenario FILE` (or delete the section for a single run)"
        );
    }
    scenario_from(&root, base_dir)
}

/// Parse a sweep file: a base scenario plus a `"sweep"` section declaring
/// the axes. A file *without* the section is accepted as a zero-axis sweep
/// over the scenario (one cell) — the CLI layers `--deadlines`-style axis
/// flags on top, so any plain scenario file can be swept.
pub fn parse_sweep(text: &str) -> Result<SweepSpec> {
    parse_sweep_at(text, None)
}

/// [`parse_sweep`] with an explicit base directory for relative
/// trace-workload paths (see [`parse_scenario_at`]).
pub fn parse_sweep_at(text: &str, base_dir: Option<&Path>) -> Result<SweepSpec> {
    let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
    reject_unknown_keys(&root, "scenario", SCENARIO_KEYS)?;
    let base = scenario_from(&root, base_dir)?;
    let spec = match root.get("sweep") {
        Some(section) => parse_sweep_section(section, base)?,
        None => SweepSpec::over(base),
    };
    spec.validate()?;
    Ok(spec)
}

/// The shared scenario-object parser (everything except the `sweep` key).
fn scenario_from(root: &Value, base_dir: Option<&Path>) -> Result<Scenario> {
    let seed = opt_usize(root, "scenario", "seed")?.unwrap_or(0) as u64;

    let resources = match opt_str(root, "scenario", "testbed")? {
        Some("wwg") => {
            if root.get("resources").is_some() {
                bail!("give either \"testbed\" or \"resources\", not both");
            }
            wwg_testbed()
        }
        Some(other) => bail!("unknown testbed {other:?} (only \"wwg\" is built in)"),
        None => {
            let arr = root
                .get("resources")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("missing \"resources\" array (or \"testbed\": \"wwg\")"))?;
            arr.iter().map(parse_resource).collect::<Result<Vec<_>>>()?
        }
    };
    if resources.is_empty() {
        bail!("\"resources\" array is empty");
    }

    let advisor = parse_advisor(opt_str(root, "scenario", "advisor")?.unwrap_or("native"))?;

    // Scenario-level broker tuning is the default every user starts from.
    let broker_default = match root.get("broker") {
        Some(v) => parse_broker_config(v, &BrokerConfig::default())?,
        None => BrokerConfig::default(),
    };

    // One cache per parse: every "trace" workload naming the same file (and
    // SWF options) — across users and inside concat/mix parts — shares one
    // Arc-allocated job list.
    let mut traces = TraceCache::default();
    let users = root
        .get("users")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing \"users\" array"))?
        .iter()
        .enumerate()
        .map(|(i, u)| {
            parse_user(u, &broker_default, base_dir, &mut traces)
                .with_context(|| format!("user #{i}"))
        })
        .collect::<Result<Vec<_>>>()?;
    if users.is_empty() {
        bail!("\"users\" array is empty");
    }

    let network = match root.get("network") {
        None => NetworkSpec::Instantaneous,
        Some(net) => parse_network(net)?,
    };

    let faults = match root.get("faults") {
        None => None,
        Some(f) => {
            let names: Vec<&str> = resources.iter().map(|r| r.name.as_str()).collect();
            Some(parse_faults(f, &names)?)
        }
    };

    let market = parse_market(root, &resources)?;

    let mut builder = Scenario::builder()
        .resources(resources)
        .seed(seed)
        .advisor(advisor)
        .broker_config(broker_default)
        .network(network);
    if let Some(f) = faults {
        builder = builder.faults(f);
    }
    if let Some(m) = market {
        builder = builder.market(m);
    }
    for u in users {
        builder = builder.user(u);
    }
    if let Some(t) = opt_f64(root, "scenario", "max_time")? {
        builder = builder.max_time(t);
    }
    Ok(builder.build())
}

/// Parse the `"network"` block. `"model"` selects the link model
/// (`"type"` is the historical alias): `"instantaneous"` (the default),
/// `"baud"` (closed-form per-message delays) or `"flow"` (shared-bandwidth
/// contention, see [`crate::network`]). Knobs belonging to a different
/// model are rejected rather than silently ignored, and every link
/// parameter goes through [`check_link_param`] — a NaN, infinite,
/// negative or zero rate/capacity would silently simulate nonsense.
fn parse_network(net: &Value) -> Result<NetworkSpec> {
    reject_unknown_keys(net, "network", NETWORK_KEYS)?;
    if net.get("model").is_some() && net.get("type").is_some() {
        bail!("network: give either \"model\" or its alias \"type\", not both");
    }
    let model = match opt_str(net, "network", "model")? {
        Some(m) => Some(m),
        None => opt_str(net, "network", "type")?,
    };
    let reject_knobs = |keys: &[&str], wanted: &str, this: &str| -> Result<()> {
        for &key in keys {
            if net.get(key).is_some() {
                bail!("network: {key:?} only applies to {{\"model\": {wanted:?}}}, not {this}");
            }
        }
        Ok(())
    };
    match model {
        Some("instantaneous") | None => {
            reject_knobs(&["rate", "latency"], "baud", "an instantaneous network")?;
            reject_knobs(&["capacity", "capacities"], "flow", "an instantaneous network")?;
            Ok(NetworkSpec::Instantaneous)
        }
        Some("baud") => {
            reject_knobs(
                &["capacity", "capacities"],
                "flow",
                "a baud network (did you mean \"rate\"?)",
            )?;
            let default_rate = opt_f64(net, "network", "rate")?
                .unwrap_or(crate::gridsim::tags::DEFAULT_BAUD_RATE);
            let latency = opt_f64(net, "network", "latency")?.unwrap_or(0.0);
            check_link_param("network", "rate", default_rate, false)?;
            check_link_param("network", "latency", latency, true)?;
            Ok(NetworkSpec::Baud { default_rate, latency })
        }
        Some("flow") => {
            reject_knobs(&["rate"], "baud", "a flow network (did you mean \"capacity\"?)")?;
            let default_capacity = opt_f64(net, "network", "capacity")?
                .unwrap_or(crate::gridsim::tags::DEFAULT_BAUD_RATE);
            let latency = opt_f64(net, "network", "latency")?.unwrap_or(0.0);
            check_link_param("network", "capacity", default_capacity, false)?;
            check_link_param("network", "latency", latency, true)?;
            let capacities = match net.get("capacities") {
                None => Vec::new(),
                Some(Value::Obj(fields)) => {
                    let mut seen = std::collections::BTreeSet::new();
                    let mut out = Vec::with_capacity(fields.len());
                    for (name, v) in fields {
                        if !seen.insert(name.as_str()) {
                            bail!("network capacities: duplicate entity {name:?}");
                        }
                        let cap = v.as_f64().ok_or_else(|| {
                            anyhow!("network capacities: {name:?} must be a number")
                        })?;
                        check_link_param("network capacities", name, cap, false)?;
                        out.push((name.clone(), cap));
                    }
                    out
                }
                Some(_) => bail!(
                    "network: \"capacities\" must be an object mapping entity names \
                     to capacities, e.g. {{\"R0\": 19200}}"
                ),
            };
            Ok(NetworkSpec::Flow { default_capacity, latency, capacities })
        }
        Some(other) => {
            let hint = nearest(other, &["instantaneous", "baud", "flow"])
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!("unknown network model {other:?}{hint}; allowed: instantaneous, baud, flow")
        }
    }
}

/// Parse the top-level `"faults"` block into a
/// [`FaultsSpec`]: a `"default"` failure–repair
/// process applied to every resource, plus per-resource `"overrides"` keyed
/// by resource name, plus an optional `"mtbf_scaling"` severity factor
/// (multiplies uptimes at sampling time; the sweep axis `mtbf_scalings`
/// overrides it per cell).
///
/// ```json
/// "faults": {
///   "default": {"process": "exponential", "mtbf": 500, "mttr": 50},
///   "overrides": {"R3": {"process": "trace",
///                        "intervals": [[100, 150], [400, 420]]}}
/// }
/// ```
///
/// Each process object names its `"process"` — `"exponential"`
/// (`mtbf`/`mttr`), `"weibull"` (`mtbf`/`mttr`/`shape`) or `"trace"`
/// (`intervals`, an array of `[start, end]` down-windows) — and rejects the
/// other processes' knobs via its own allowed-key list. Parameter sanity
/// (finite, positive, sorted non-overlapping intervals) is enforced by
/// [`FaultsSpec::validate`] before the spec is returned.
fn parse_faults(v: &Value, resource_names: &[&str]) -> Result<FaultsSpec> {
    reject_unknown_keys(v, "faults", FAULTS_KEYS)?;
    let mut spec = FaultsSpec::default();
    if let Some(d) = v.get("default") {
        spec.default = Some(parse_fault_process(d, "faults default")?);
    }
    match v.get("overrides") {
        None => {}
        Some(Value::Obj(fields)) => {
            let mut seen = std::collections::BTreeSet::new();
            for (name, process) in fields {
                if !seen.insert(name.as_str()) {
                    bail!("faults overrides: duplicate resource {name:?}");
                }
                if !resource_names.contains(&name.as_str()) {
                    let hint = nearest(name, resource_names)
                        .map(|s| format!(" (did you mean {s:?}?)"))
                        .unwrap_or_default();
                    bail!(
                        "faults overrides: unknown resource {name:?}{hint}; \
                         scenario has: {}",
                        resource_names.join(", ")
                    );
                }
                let what = format!("faults override {name:?}");
                spec.overrides.push((name.clone(), parse_fault_process(process, &what)?));
            }
        }
        Some(_) => bail!(
            "faults: \"overrides\" must be an object mapping resource names to \
             process objects, e.g. {{\"R0\": {{\"process\": \"exponential\", \
             \"mtbf\": 500, \"mttr\": 50}}}}"
        ),
    }
    if spec.default.is_none() && spec.overrides.is_empty() {
        bail!(
            "faults: give a \"default\" process or at least one entry in \
             \"overrides\" (an empty block drives nothing)"
        );
    }
    if let Some(s) = opt_f64(v, "faults", "mtbf_scaling")? {
        if !s.is_finite() || s <= 0.0 {
            bail!("faults: \"mtbf_scaling\" must be finite and > 0, got {s}");
        }
        spec.mtbf_scaling = s;
    }
    spec.validate().map_err(|e| anyhow!("faults: {e}"))?;
    Ok(spec)
}

/// Parse one failure–repair process object (see [`parse_faults`]).
fn parse_fault_process(v: &Value, what: &str) -> Result<FaultProcess> {
    if !matches!(v, Value::Obj(_)) {
        bail!("{what} must be a JSON object");
    }
    let ty = opt_str(v, what, "process")?.ok_or_else(|| {
        anyhow!("{what}: missing \"process\" (one of: {})", FAULT_PROCESS_TYPES.join(", "))
    })?;
    match ty {
        "exponential" => {
            reject_unknown_keys(v, what, FAULT_EXPONENTIAL_KEYS)?;
            Ok(FaultProcess::Exponential {
                mtbf: v.req_f64("mtbf").context(what.to_string())?,
                mttr: v.req_f64("mttr").context(what.to_string())?,
            })
        }
        "weibull" => {
            reject_unknown_keys(v, what, FAULT_WEIBULL_KEYS)?;
            Ok(FaultProcess::Weibull {
                mtbf: v.req_f64("mtbf").context(what.to_string())?,
                mttr: v.req_f64("mttr").context(what.to_string())?,
                shape: v.req_f64("shape").context(what.to_string())?,
            })
        }
        "trace" => {
            reject_unknown_keys(v, what, FAULT_TRACE_KEYS)?;
            let arr = v
                .get("intervals")
                .and_then(Value::as_arr)
                .ok_or_else(|| {
                    anyhow!("{what}: missing \"intervals\" array of [start, end] pairs")
                })?;
            let intervals = arr
                .iter()
                .enumerate()
                .map(|(i, pair)| {
                    let p = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                        anyhow!("{what}: interval #{i} must be a [start, end] pair")
                    })?;
                    let start = p[0].as_f64().ok_or_else(|| {
                        anyhow!("{what}: interval #{i} start must be a number")
                    })?;
                    let end = p[1].as_f64().ok_or_else(|| {
                        anyhow!("{what}: interval #{i} end must be a number")
                    })?;
                    Ok((start, end))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(FaultProcess::Trace { intervals })
        }
        other => {
            let hint = nearest(other, FAULT_PROCESS_TYPES)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!(
                "{what}: unknown process {other:?}{hint}; allowed: {}",
                FAULT_PROCESS_TYPES.join(", ")
            )
        }
    }
}

/// Parse the top-level `"pricing"` and `"spot"` blocks into a
/// [`MarketSpec`] (see [`crate::market`]). `None` when the file carries
/// neither block — no-market scenarios build bit-identically to before.
///
/// ```json
/// "pricing": {
///   "default": {"model": "utilization_linear", "slope": 4.0},
///   "overrides": {"R0": {"model": "static", "price": 5.0}}
/// },
/// "spot": {"R3": 0.5}
/// ```
///
/// The `"default"` model applies to every resource (folded into one entry
/// per resource here, so the spec is fully resolved); `"overrides"` replace
/// it per resource. A model's `price`/`base` defaults to the resource's
/// configured static price, keeping `{"model": "static"}` a no-op
/// re-statement of the Table 2 price. `"spot"` maps resource names to
/// discounts in `(0, 1]`. Unknown resource names get did-you-mean hints.
fn parse_market(root: &Value, resources: &[ResourceSpec]) -> Result<Option<MarketSpec>> {
    let pricing = root.get("pricing");
    let spot = root.get("spot");
    if pricing.is_none() && spot.is_none() {
        return Ok(None);
    }
    let names: Vec<&str> = resources.iter().map(|r| r.name.as_str()).collect();
    let price_of = |name: &str| -> f64 {
        resources.iter().find(|r| r.name == name).map(|r| r.price).expect("known resource")
    };
    let check_resource = |name: &str, what: &str| -> Result<()> {
        if !names.contains(&name) {
            let hint = nearest(name, &names)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!("{what}: unknown resource {name:?}{hint}; scenario has: {}", names.join(", "));
        }
        Ok(())
    };

    let mut spec = MarketSpec::new();
    if let Some(p) = pricing {
        reject_unknown_keys(p, "pricing", PRICING_KEYS)?;
        let overrides = match p.get("overrides") {
            None => Vec::new(),
            Some(Value::Obj(fields)) => {
                let mut seen = std::collections::BTreeSet::new();
                for (name, _) in fields {
                    if !seen.insert(name.as_str()) {
                        bail!("pricing overrides: duplicate resource {name:?}");
                    }
                    check_resource(name, "pricing overrides")?;
                }
                fields.clone()
            }
            Some(_) => bail!(
                "pricing: \"overrides\" must be an object mapping resource names to \
                 model objects, e.g. {{\"R0\": {{\"model\": \"static\", \"price\": 5}}}}"
            ),
        };
        if p.get("default").is_none() && overrides.is_empty() {
            bail!(
                "pricing: give a \"default\" model or at least one entry in \
                 \"overrides\" (an empty block drives nothing)"
            );
        }
        if let Some(d) = p.get("default") {
            // Fold the default into one fully-resolved entry per resource
            // (overridden below where an override names the resource).
            for r in resources {
                let model = parse_price_model(d, "pricing default", r.price)?;
                spec = spec.pricing_for(r.name.clone(), model);
            }
        }
        for (name, model) in &overrides {
            let what = format!("pricing override {name:?}");
            let model = parse_price_model(model, &what, price_of(name))?;
            spec = spec.pricing_for(name.clone(), model);
        }
    }
    if let Some(s) = spot {
        let Value::Obj(fields) = s else {
            bail!(
                "\"spot\" must be an object mapping resource names to discounts \
                 in (0, 1], e.g. {{\"R3\": 0.5}}"
            );
        };
        let mut seen = std::collections::BTreeSet::new();
        for (name, d) in fields {
            if !seen.insert(name.as_str()) {
                bail!("spot: duplicate resource {name:?}");
            }
            check_resource(name, "spot")?;
            let discount = d
                .as_f64()
                .ok_or_else(|| anyhow!("spot: {name:?} must be a number"))?;
            spec = spec.spot_for(name.clone(), discount);
        }
        if spec.spot.is_empty() {
            bail!("\"spot\" block is empty (it drives nothing)");
        }
    }
    spec.validate().map_err(|e| anyhow!("market: {e}"))?;
    Ok(Some(spec))
}

/// Parse one pricing-model object (see [`parse_market`]). `base_price` is
/// the owning resource's configured static price, the default for
/// `price`/`base`.
fn parse_price_model(v: &Value, what: &str, base_price: f64) -> Result<PriceModel> {
    if !matches!(v, Value::Obj(_)) {
        bail!("{what} must be a JSON object");
    }
    let ty = opt_str(v, what, "model")?.ok_or_else(|| {
        anyhow!("{what}: missing \"model\" (one of: {})", PRICE_MODEL_TYPES.join(", "))
    })?;
    let model = match ty {
        "static" => {
            reject_unknown_keys(v, what, PRICE_STATIC_KEYS)?;
            PriceModel::Static { price: opt_f64(v, what, "price")?.unwrap_or(base_price) }
        }
        "utilization_linear" => {
            reject_unknown_keys(v, what, PRICE_LINEAR_KEYS)?;
            PriceModel::UtilizationLinear {
                base: opt_f64(v, what, "base")?.unwrap_or(base_price),
                slope: v.req_f64("slope").context(what.to_string())?,
                floor: opt_f64(v, what, "floor")?.unwrap_or(0.0),
                cap: opt_f64(v, what, "cap")?.unwrap_or(f64::INFINITY),
            }
        }
        "utilization_step" => {
            reject_unknown_keys(v, what, PRICE_STEP_KEYS)?;
            let arr = v.get("steps").and_then(Value::as_arr).ok_or_else(|| {
                anyhow!("{what}: missing \"steps\" array of [threshold, price] pairs")
            })?;
            let steps = arr
                .iter()
                .enumerate()
                .map(|(i, pair)| {
                    let p = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                        anyhow!("{what}: step #{i} must be a [threshold, price] pair")
                    })?;
                    let threshold = p[0].as_f64().ok_or_else(|| {
                        anyhow!("{what}: step #{i} threshold must be a number")
                    })?;
                    let price = p[1]
                        .as_f64()
                        .ok_or_else(|| anyhow!("{what}: step #{i} price must be a number"))?;
                    Ok((threshold, price))
                })
                .collect::<Result<Vec<_>>>()?;
            PriceModel::UtilizationStep {
                base: opt_f64(v, what, "base")?.unwrap_or(base_price),
                steps,
                floor: opt_f64(v, what, "floor")?.unwrap_or(0.0),
                cap: opt_f64(v, what, "cap")?.unwrap_or(f64::INFINITY),
            }
        }
        other => {
            let hint = nearest(other, PRICE_MODEL_TYPES)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!(
                "{what}: unknown model {other:?}{hint}; allowed: {}",
                PRICE_MODEL_TYPES.join(", ")
            )
        }
    };
    model.validate().map_err(|e| anyhow!("{what}: {e}"))?;
    Ok(model)
}

/// Shared guard for link parameters (baud rates, flow capacities,
/// latencies, per-user link rates): NaN, infinite or negative values — and
/// zero where zero would stall every transfer — are configuration bugs and
/// fail the parse instead of simulating nonsense.
fn check_link_param(what: &str, key: &str, value: f64, zero_ok: bool) -> Result<()> {
    if value.is_nan() {
        bail!("{what}: {key:?} must be a number, got NaN");
    }
    if value.is_infinite() {
        bail!("{what}: {key:?} must be finite, got {value}");
    }
    if value < 0.0 || (!zero_ok && value == 0.0) {
        let bound = if zero_ok { ">= 0" } else { "> 0 (a zero-rate link never delivers)" };
        bail!("{what}: {key:?} must be {bound}, got {value}");
    }
    Ok(())
}

fn parse_resource(v: &Value) -> Result<ResourceSpec> {
    reject_unknown_keys(v, "resource", RESOURCE_KEYS)?;
    let name = v.req_str("name").context("resource")?.to_string();
    let policy = match opt_str(v, "resource", "policy")?.unwrap_or("time") {
        "time" | "time-shared" => AllocPolicy::TimeShared,
        "space-fcfs" | "space" => AllocPolicy::SpaceShared(SpacePolicy::Fcfs),
        "space-sjf" => AllocPolicy::SpaceShared(SpacePolicy::Sjf),
        "space-backfill" => AllocPolicy::SpaceShared(SpacePolicy::BackfillEasy),
        other => bail!("resource {name}: unknown policy {other:?}"),
    };
    if v.get("pes_per_machine").is_some() && v.get("pes").is_some() {
        bail!("resource {name}: give either \"pes_per_machine\" or \"pes\", not both");
    }
    let pes_per_machine = match opt_usize(v, "resource", "pes_per_machine")? {
        Some(n) => n,
        None => opt_usize(v, "resource", "pes")?.unwrap_or(1),
    };
    let time_zone = opt_f64(v, "resource", "time_zone")?.unwrap_or(0.0);
    let calendar = match v.get("calendar") {
        None => None,
        Some(c) => Some(
            parse_calendar(c, time_zone).with_context(|| format!("resource {name}"))?,
        ),
    };
    Ok(ResourceSpec {
        arch: opt_str(v, "resource", "arch")?.unwrap_or("generic").to_string(),
        os: opt_str(v, "resource", "os")?.unwrap_or("linux").to_string(),
        machines: opt_usize(v, "resource", "machines")?.unwrap_or(1),
        pes_per_machine,
        mips_per_pe: v.req_f64("mips").with_context(|| format!("resource {name}"))?,
        policy,
        price: v.req_f64("price").with_context(|| format!("resource {name}"))?,
        time_zone,
        calendar,
        name,
    })
}

/// Parse a resource's `"calendar"` block into a [`ResourceCalendar`]
/// (background local load by business hours, weekends and holidays). Every
/// key is optional: loads default to 0 (no background load), `time_zone`
/// defaults to the *resource's* time zone (one grid site, one clock), and
/// `units_per_hour` defaults to 1. Load factors must lie in `[0, 1)` — a
/// load of 1 would stop the resource forever, which is what the `faults`
/// block is for — and NaN fails the same range check.
fn parse_calendar(v: &Value, resource_time_zone: f64) -> Result<ResourceCalendar> {
    reject_unknown_keys(v, "calendar", CALENDAR_KEYS)?;
    let mut cal = ResourceCalendar::no_load();
    cal.time_zone = opt_f64(v, "calendar", "time_zone")?.unwrap_or(resource_time_zone);
    if !cal.time_zone.is_finite() {
        bail!("calendar: \"time_zone\" must be finite, got {}", cal.time_zone);
    }
    for (key, slot) in [
        ("peak_load", &mut cal.peak_load),
        ("off_peak_load", &mut cal.off_peak_load),
        ("holiday_load", &mut cal.holiday_load),
    ] {
        if let Some(load) = opt_f64(v, "calendar", key)? {
            if !(0.0..1.0).contains(&load) {
                bail!("calendar: {key:?} must be in [0, 1), got {load}");
            }
            *slot = load;
        }
    }
    if let Some(u) = opt_f64(v, "calendar", "units_per_hour")? {
        if !u.is_finite() || u <= 0.0 {
            bail!("calendar: \"units_per_hour\" must be finite and > 0, got {u}");
        }
        cal.units_per_hour = u;
    }
    Ok(cal)
}

/// Typed byte-size getter (non-negative integer, strict like `opt_usize`).
fn opt_bytes(v: &Value, what: &str, key: &str) -> Result<Option<u64>> {
    Ok(opt_usize(v, what, key)?.map(|n| n as u64))
}

/// One scenario parse shares every loaded trace: the cache maps a resolved
/// path plus the *stated* SWF conversion options to the `Arc`-shared job
/// list, so ten users replaying slices of one 10^5-record log hold ten
/// `Arc` clones of a single allocation — and a sweep over the file shares
/// that same allocation across every cell. Lookup is a linear scan because
/// a scenario file names at most a handful of distinct traces (and
/// [`SwfLoadOptions`] holds floats, so it is `PartialEq` but not `Hash`).
#[derive(Default)]
struct TraceCache {
    entries: Vec<((PathBuf, Option<SwfLoadOptions>), Arc<[TraceJob]>)>,
}

impl TraceCache {
    fn load(
        &mut self,
        path: &Path,
        options: Option<&SwfLoadOptions>,
    ) -> Result<Arc<[TraceJob]>> {
        let key = (path.to_path_buf(), options.cloned());
        if let Some((_, jobs)) = self.entries.iter().find(|(k, _)| *k == key) {
            return Ok(jobs.clone());
        }
        let jobs: Arc<[TraceJob]> = load_trace_file_with(path, options)?.into();
        self.entries.push((key, jobs.clone()));
        Ok(jobs)
    }
}

/// Parse a `"workload"` object into a [`WorkloadSpec`]. Each variant has its
/// own allowed-key list; the spec is validated before it is returned, so
/// out-of-range parameters fail at load time with a readable message.
/// Relative trace and DAG-file paths resolve against `base_dir` when given;
/// trace loads go through `traces`, so repeated references to one log share
/// a single `Arc` allocation.
fn parse_workload(
    v: &Value,
    base_dir: Option<&Path>,
    traces: &mut TraceCache,
) -> Result<WorkloadSpec> {
    if !matches!(v, Value::Obj(_)) {
        bail!("\"workload\" must be a JSON object");
    }
    let ty = opt_str(v, "workload", "type")?.ok_or_else(|| {
        anyhow!("workload: missing \"type\" (one of: {})", WORKLOAD_TYPES.join(", "))
    })?;
    let spec = match ty {
        "task_farm" => {
            reject_unknown_keys(v, "task_farm workload", WORKLOAD_TASK_FARM_KEYS)?;
            WorkloadSpec::TaskFarm {
                num_gridlets: opt_usize(v, "workload", "gridlets")?.unwrap_or(200),
                base_length_mi: opt_f64(v, "workload", "length_mi")?.unwrap_or(10_000.0),
                length_variation: opt_f64(v, "workload", "variation")?.unwrap_or(0.10),
                input_bytes: opt_bytes(v, "workload", "input_bytes")?.unwrap_or(1000),
                output_bytes: opt_bytes(v, "workload", "output_bytes")?.unwrap_or(500),
            }
        }
        "heavy_tailed" => {
            reject_unknown_keys(v, "heavy_tailed workload", WORKLOAD_HEAVY_KEYS)?;
            WorkloadSpec::HeavyTailed {
                num_gridlets: opt_usize(v, "workload", "gridlets")?.unwrap_or(200),
                base_length_mi: opt_f64(v, "workload", "length_mi")?.unwrap_or(10_000.0),
                heavy_fraction: opt_f64(v, "workload", "heavy_fraction")?.unwrap_or(0.1),
                heavy_multiplier: opt_f64(v, "workload", "heavy_multiplier")?.unwrap_or(10.0),
                input_bytes: opt_bytes(v, "workload", "input_bytes")?.unwrap_or(1000),
                output_bytes: opt_bytes(v, "workload", "output_bytes")?.unwrap_or(500),
            }
        }
        "explicit" => {
            reject_unknown_keys(v, "explicit workload", WORKLOAD_EXPLICIT_KEYS)?;
            let arr = v
                .get("jobs")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("explicit workload: missing \"jobs\" array"))?;
            let jobs = arr
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    (|| -> Result<JobSpec> {
                        reject_unknown_keys(j, "job", JOB_KEYS)?;
                        Ok(JobSpec {
                            length_mi: j.req_f64("length_mi")?,
                            input_bytes: opt_bytes(j, "job", "input_bytes")?.unwrap_or(1000),
                            output_bytes: opt_bytes(j, "job", "output_bytes")?.unwrap_or(500),
                        })
                    })()
                    .with_context(|| format!("explicit workload job #{i}"))
                })
                .collect::<Result<Vec<_>>>()?;
            if jobs.is_empty() {
                bail!("explicit workload: \"jobs\" array is empty");
            }
            WorkloadSpec::Explicit { jobs }
        }
        "trace" => {
            reject_unknown_keys(v, "trace workload", WORKLOAD_TRACE_KEYS)?;
            let path = v.req_str("path").context("trace workload")?;
            let resolved = match base_dir {
                Some(dir) if Path::new(path).is_relative() => dir.join(path),
                _ => PathBuf::from(path),
            };
            // `Some` only when a conversion knob was actually written in
            // the JSON — an explicitly stated knob against a legacy
            // 4-column file must be rejected even if its value matches the
            // default, never silently ignored.
            let knobs_stated =
                ["mips", "statuses", "input_bytes", "output_bytes"]
                    .iter()
                    .any(|k| v.get(k).is_some());
            let options = if knobs_stated {
                let mut options = SwfLoadOptions::default();
                if let Some(m) = opt_f64(v, "trace workload", "mips")? {
                    options.mips = m;
                }
                if let Some(ss) = opt_i64_array(v, "trace workload", "statuses")? {
                    options.statuses = Some(ss);
                }
                if let Some(b) = opt_bytes(v, "trace workload", "input_bytes")? {
                    options.input_bytes = b;
                }
                if let Some(b) = opt_bytes(v, "trace workload", "output_bytes")? {
                    options.output_bytes = b;
                }
                Some(options)
            } else {
                None
            };
            let selector = match v.get("select") {
                Some(sel) => parse_trace_selector(sel)?,
                None => TraceSelector::all(),
            };
            WorkloadSpec::trace_selected_shared(
                traces.load(&resolved, options.as_ref())?,
                selector,
            )
        }
        "concat" => {
            reject_unknown_keys(v, "concat workload", WORKLOAD_CONCAT_KEYS)?;
            WorkloadSpec::Concat { parts: parse_workload_parts(v, "concat", base_dir, traces)? }
        }
        "mix" => {
            reject_unknown_keys(v, "mix workload", WORKLOAD_MIX_KEYS)?;
            let parts = parse_workload_parts(v, "mix", base_dir, traces)?;
            let weights = match opt_f64_array(v, "mix workload", "weights")? {
                Some(ws) => ws,
                None => vec![1.0; parts.len()],
            };
            WorkloadSpec::Mix { parts, weights }
        }
        "online_arrivals" => {
            reject_unknown_keys(v, "online_arrivals workload", WORKLOAD_ONLINE_KEYS)?;
            let inner_v = v.get("workload").ok_or_else(|| {
                anyhow!("online_arrivals workload: missing inner \"workload\" object")
            })?;
            let inner = parse_workload(inner_v, base_dir, traces).context("online_arrivals")?;
            if matches!(inner, WorkloadSpec::OnlineArrivals { .. }) {
                bail!("online_arrivals cannot wrap another online_arrivals");
            }
            // Each process rejects the other processes' knobs — a stray
            // "interval" on a poisson process must not be silently ignored.
            let only_for = |keys: &[&str], process: &str| -> Result<()> {
                for key in keys {
                    if v.get(key).is_some() {
                        bail!(
                            "online_arrivals: {key:?} only applies to \
                             {{\"process\": {process:?}}}"
                        );
                    }
                }
                Ok(())
            };
            let arrivals = match opt_str(v, "workload", "process")?.unwrap_or("poisson") {
                "poisson" => {
                    only_for(&["interval"], "fixed")?;
                    only_for(&["period", "envelope", "amplitude"], "modulated")?;
                    ArrivalProcess::Poisson {
                        mean_interarrival: v
                            .req_f64("mean_interarrival")
                            .context("online_arrivals workload")?,
                    }
                }
                "fixed" => {
                    only_for(&["mean_interarrival"], "poisson")?;
                    only_for(&["period", "envelope", "amplitude"], "modulated")?;
                    ArrivalProcess::Fixed {
                        interval: v.req_f64("interval").context("online_arrivals workload")?,
                    }
                }
                "modulated" => {
                    only_for(&["interval"], "fixed")?;
                    let mean_interarrival = v
                        .req_f64("mean_interarrival")
                        .context("online_arrivals workload")?;
                    let period =
                        v.req_f64("period").context("modulated arrivals")?;
                    let envelope = match (
                        opt_f64_array(v, "modulated arrivals", "envelope")?,
                        opt_f64(v, "modulated arrivals", "amplitude")?,
                    ) {
                        (Some(rates), None) => RateEnvelope::Piecewise { period, rates },
                        (None, Some(amplitude)) => {
                            RateEnvelope::Sinusoid { period, amplitude }
                        }
                        (Some(_), Some(_)) => bail!(
                            "modulated arrivals: give either \"envelope\" \
                             (piecewise rates) or \"amplitude\" (sinusoid), not both"
                        ),
                        (None, None) => bail!(
                            "modulated arrivals: missing \"envelope\" (piecewise \
                             rates array) or \"amplitude\" (sinusoid depth)"
                        ),
                    };
                    ArrivalProcess::Modulated { mean_interarrival, envelope }
                }
                other => bail!("unknown arrival process {other:?} (poisson|fixed|modulated)"),
            };
            WorkloadSpec::OnlineArrivals { workload: Box::new(inner), arrivals }
        }
        "dag" => {
            reject_unknown_keys(v, "dag workload", WORKLOAD_DAG_KEYS)?;
            let inline = v.get("nodes").is_some() || v.get("edges").is_some();
            let (nodes, edges) = match (inline, v.get("file")) {
                (true, Some(_)) => bail!(
                    "dag workload: give inline \"nodes\"/\"edges\" or a \"file\", not both"
                ),
                (false, None) => bail!(
                    "dag workload: missing \"nodes\"/\"edges\" (inline graph) or \
                     \"file\" (DOT-like graph file)"
                ),
                (false, Some(_)) => {
                    let path = v.req_str("file").context("dag workload")?;
                    let resolved = match base_dir {
                        Some(dir) if Path::new(path).is_relative() => dir.join(path),
                        _ => PathBuf::from(path),
                    };
                    let text = std::fs::read_to_string(&resolved).with_context(|| {
                        format!("dag workload: reading {}", resolved.display())
                    })?;
                    parse_dot(&text)
                        .with_context(|| format!("dag workload: {}", resolved.display()))?
                }
                (true, None) => {
                    let arr = v.get("nodes").and_then(Value::as_arr).ok_or_else(|| {
                        anyhow!("dag workload: \"nodes\" must be an array of node objects")
                    })?;
                    let nodes = arr
                        .iter()
                        .enumerate()
                        .map(|(i, n)| {
                            (|| -> Result<DagNode> {
                                reject_unknown_keys(n, "dag node", DAG_NODE_KEYS)?;
                                let mut node = DagNode::new(
                                    n.req_str("id")?,
                                    n.req_f64("length_mi")?,
                                );
                                if let Some(b) = opt_bytes(n, "dag node", "input_bytes")? {
                                    node.input_bytes = b;
                                }
                                if let Some(b) = opt_bytes(n, "dag node", "output_bytes")? {
                                    node.output_bytes = b;
                                }
                                Ok(node)
                            })()
                            .with_context(|| format!("dag workload node #{i}"))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    let edges = match v.get("edges") {
                        None => Vec::new(),
                        Some(e) => {
                            let arr = e.as_arr().ok_or_else(|| {
                                anyhow!(
                                    "dag workload: \"edges\" must be an array of \
                                     [parent, child] string pairs"
                                )
                            })?;
                            arr.iter()
                                .enumerate()
                                .map(|(i, pair)| {
                                    let err = || {
                                        anyhow!(
                                            "dag workload edge #{i}: expected a \
                                             [parent, child] string pair"
                                        )
                                    };
                                    let pair = pair.as_arr().ok_or_else(err)?;
                                    let [a, b] = pair else { return Err(err()) };
                                    let a = a.as_str().ok_or_else(err)?;
                                    let b = b.as_str().ok_or_else(err)?;
                                    Ok((a.to_string(), b.to_string()))
                                })
                                .collect::<Result<Vec<_>>>()?
                        }
                    };
                    (nodes, edges)
                }
            };
            WorkloadSpec::Dag { nodes, edges }
        }
        other => {
            let hint = nearest(other, WORKLOAD_TYPES)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            bail!(
                "unknown workload type {other:?}{hint}; allowed types: {}",
                WORKLOAD_TYPES.join(", ")
            );
        }
    };
    spec.validate().with_context(|| format!("{} workload", spec.label()))?;
    Ok(spec)
}

/// Parse the `"parts"` array of a `concat`/`mix` workload, recursing into
/// [`parse_workload`] — `base_dir` and the trace cache are threaded
/// through, so a relative trace path inside a composition resolves against
/// the scenario file's directory (and shares the loaded log) exactly like a
/// top-level trace.
fn parse_workload_parts(
    v: &Value,
    what: &str,
    base_dir: Option<&Path>,
    traces: &mut TraceCache,
) -> Result<Vec<WorkloadSpec>> {
    let arr = v
        .get("parts")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("{what} workload: missing \"parts\" array"))?;
    if arr.is_empty() {
        bail!("{what} workload: \"parts\" array is empty");
    }
    arr.iter()
        .enumerate()
        .map(|(i, p)| {
            parse_workload(p, base_dir, traces).with_context(|| format!("{what} part #{i}"))
        })
        .collect()
}

/// Parse a trace `"select"` object into a [`TraceSelector`]:
/// `{"users": [3, 7], "partitions": [1], "max_jobs": 100}` — every key
/// optional, an absent key filters nothing.
fn parse_trace_selector(v: &Value) -> Result<TraceSelector> {
    reject_unknown_keys(v, "trace select", SELECT_KEYS)?;
    Ok(TraceSelector {
        users: opt_i64_array(v, "trace select", "users")?.unwrap_or_default(),
        partitions: opt_i64_array(v, "trace select", "partitions")?.unwrap_or_default(),
        max_jobs: opt_usize(v, "trace select", "max_jobs")?,
    })
}

/// Typed optional array of SWF integers. `-1` is legal — it is the SWF
/// missing-value sentinel, and `"statuses": [1, -1]` legitimately keeps
/// jobs with an unrecorded status.
fn opt_i64_array(v: &Value, what: &str, key: &str) -> Result<Option<Vec<i64>>> {
    match opt_f64_array(v, what, key)? {
        None => Ok(None),
        Some(ns) => ns
            .into_iter()
            .map(|n| {
                if n.fract() == 0.0 && (-1.0..9_007_199_254_740_992.0).contains(&n) {
                    Ok(n as i64)
                } else {
                    bail!("{what}: {key:?} must hold integers >= -1, got {n}")
                }
            })
            .collect::<Result<Vec<_>>>()
            .map(Some),
    }
}

fn parse_user(
    v: &Value,
    broker_default: &BrokerConfig,
    base_dir: Option<&Path>,
    traces: &mut TraceCache,
) -> Result<UserSpec> {
    reject_unknown_keys(v, "user", USER_KEYS)?;
    let mut spec = if let Some(w) = v.get("workload") {
        if let Some(flat) = FLAT_WORKLOAD_KEYS.iter().find(|k| v.get(k).is_some()) {
            bail!(
                "give either \"workload\" or the flat task-farm key {flat:?}, not both \
                 (put the job shape inside the \"workload\" object)"
            );
        }
        ExperimentSpec::new(parse_workload(w, base_dir, traces)?)
    } else {
        let mut spec = ExperimentSpec::task_farm(
            opt_usize(v, "user", "gridlets")?.unwrap_or(200),
            opt_f64(v, "user", "length_mi")?.unwrap_or(10_000.0),
            opt_f64(v, "user", "variation")?.unwrap_or(0.10),
        );
        let input = opt_bytes(v, "user", "input_bytes")?;
        let output = opt_bytes(v, "user", "output_bytes")?;
        if input.is_some() || output.is_some() {
            spec = spec.staging(input.unwrap_or(1000), output.unwrap_or(500));
        }
        spec.workload.validate().context("user workload")?;
        spec
    };
    if v.get("deadline").is_some() && v.get("d_factor").is_some() {
        bail!("give either \"deadline\" or \"d_factor\", not both");
    }
    if v.get("budget").is_some() && v.get("b_factor").is_some() {
        bail!("give either \"budget\" or \"b_factor\", not both");
    }
    if let Some(d) = opt_f64(v, "user", "deadline")? {
        spec = spec.deadline(d);
    } else if let Some(f) = opt_f64(v, "user", "d_factor")? {
        spec = spec.d_factor(f);
    }
    if let Some(b) = opt_f64(v, "user", "budget")? {
        spec = spec.budget(b);
    } else if let Some(f) = opt_f64(v, "user", "b_factor")? {
        spec = spec.b_factor(f);
    }
    // "policy" is the per-user alias of "optimization" (the scheduling
    // policy this user's broker runs); giving both is ambiguous.
    let opt = match (v.get("optimization").is_some(), v.get("policy").is_some()) {
        (true, true) => bail!("give either \"optimization\" or \"policy\", not both"),
        (true, false) => opt_str(v, "user", "optimization")?,
        (false, true) => opt_str(v, "user", "policy")?,
        (false, false) => None,
    };
    if let Some(s) = opt {
        spec = spec.optimization(
            Optimization::parse(s).ok_or_else(|| anyhow!("unknown optimization {s:?}"))?,
        );
    }
    let mut user = UserSpec::new(spec);
    if let Some(s) = opt_str(v, "user", "advisor")? {
        user = user.advisor(parse_advisor(s)?);
    }
    if let Some(b) = v.get("broker") {
        user = user.broker(parse_broker_config(b, broker_default)?);
    }
    if let Some(d) = opt_f64(v, "user", "submit_delay")? {
        if d < 0.0 {
            bail!("submit_delay must be >= 0, got {d}");
        }
        user = user.submit_delay(d);
    }
    if let Some(r) = opt_f64(v, "user", "link_rate")? {
        check_link_param("user", "link_rate", r, false)?;
        user = user.link_rate(r);
    }
    if let Some(b) = opt_f64(v, "user", "max_spot_price")? {
        if !b.is_finite() || b < 0.0 {
            bail!("user: \"max_spot_price\" must be finite and >= 0, got {b}");
        }
        user = user.max_spot_price(b);
    }
    Ok(user)
}

/// Typed optional array getters, same strictness discipline as the scalar
/// getters: a known key holding a non-array (or wrong-element-typed array)
/// is a hard error.
fn opt_f64_array(v: &Value, what: &str, key: &str) -> Result<Option<Vec<f64>>> {
    let Some(x) = v.get(key) else { return Ok(None) };
    let arr = x
        .as_arr()
        .ok_or_else(|| anyhow!("{what}: {key:?} must be an array of numbers"))?;
    arr.iter()
        .map(|e| e.as_f64().ok_or_else(|| anyhow!("{what}: {key:?} must hold only numbers")))
        .collect::<Result<Vec<_>>>()
        .map(Some)
}

fn opt_usize_array(v: &Value, what: &str, key: &str) -> Result<Option<Vec<usize>>> {
    match opt_f64_array(v, what, key)? {
        None => Ok(None),
        Some(ns) => ns
            .into_iter()
            .map(|n| f64_to_usize(n, what, key))
            .collect::<Result<Vec<_>>>()
            .map(Some),
    }
}

/// Parse the `"sweep"` section into a [`SweepSpec`] over `base`.
///
/// ```json
/// "sweep": {
///   "deadlines": [100, 600, 1100],
///   "budgets": [5000, 10000, 22000],
///   "users": [1, 10, 20],
///   "policies": ["cost", "time"],
///   "resources": [["R0", "R1"], ["R8"]],
///   "replications": 3
/// }
/// ```
///
/// Every key is optional (an absent axis keeps the base scenario's value);
/// unknown keys are rejected with the same did-you-mean hints as the rest of
/// the file.
fn parse_sweep_section(v: &Value, base: Scenario) -> Result<SweepSpec> {
    reject_unknown_keys(v, "sweep", SWEEP_KEYS)?;
    let mut spec = SweepSpec::over(base);
    if let Some(ds) = opt_f64_array(v, "sweep", "deadlines")? {
        spec = spec.deadlines(ds);
    }
    if let Some(bs) = opt_f64_array(v, "sweep", "budgets")? {
        spec = spec.budgets(bs);
    }
    if let Some(us) = opt_usize_array(v, "sweep", "users")? {
        spec = spec.user_counts(us);
    }
    if let Some(ps) = v.get("policies") {
        let arr = ps
            .as_arr()
            .ok_or_else(|| anyhow!("sweep: \"policies\" must be an array of strings"))?;
        let policies = arr
            .iter()
            .map(|p| {
                let s = p
                    .as_str()
                    .ok_or_else(|| anyhow!("sweep: \"policies\" must hold only strings"))?;
                s.parse::<Optimization>().map_err(|e| anyhow!("sweep: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        spec = spec.policies(policies);
    }
    if let Some(rs) = v.get("resources") {
        let arr = rs.as_arr().ok_or_else(|| {
            anyhow!("sweep: \"resources\" must be an array of resource-name arrays")
        })?;
        let subsets = arr
            .iter()
            .enumerate()
            .map(|(i, subset)| {
                let names = subset.as_arr().ok_or_else(|| {
                    anyhow!("sweep: resource subset #{i} must be an array of names")
                })?;
                names
                    .iter()
                    .map(|n| {
                        n.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow!("sweep: resource subset #{i} must hold only strings")
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        spec = spec.resource_subsets(subsets);
    }
    if let Some(ms) = opt_f64_array(v, "sweep", "mean_interarrivals")? {
        spec = spec.mean_interarrivals(ms);
    }
    if let Some(fs) = opt_f64_array(v, "sweep", "heavy_fractions")? {
        spec = spec.heavy_fractions(fs);
    }
    if let Some(sels) = v.get("trace_selectors") {
        let arr = sels.as_arr().ok_or_else(|| {
            anyhow!("sweep: \"trace_selectors\" must be an array of select objects")
        })?;
        let selectors = arr
            .iter()
            .enumerate()
            .map(|(i, s)| {
                parse_trace_selector(s).with_context(|| format!("sweep trace selector #{i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        spec = spec.trace_selectors(selectors);
    }
    if let Some(ws) = v.get("mix_weights") {
        let arr = ws.as_arr().ok_or_else(|| {
            anyhow!("sweep: \"mix_weights\" must be an array of weight arrays")
        })?;
        let weight_sets = arr
            .iter()
            .enumerate()
            .map(|(i, row)| {
                row.as_arr()
                    .ok_or_else(|| {
                        anyhow!("sweep: mix_weights entry #{i} must be an array of numbers")
                    })?
                    .iter()
                    .map(|w| {
                        w.as_f64().ok_or_else(|| {
                            anyhow!("sweep: mix_weights entry #{i} must hold only numbers")
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        spec = spec.mix_weights(weight_sets);
    }
    if let Some(caps) = opt_f64_array(v, "sweep", "link_capacities")? {
        for c in &caps {
            check_link_param("sweep link_capacities", "capacity", *c, false)?;
        }
        spec = spec.link_capacities(caps);
    }
    if let Some(ss) = opt_f64_array(v, "sweep", "mtbf_scalings")? {
        // Positivity and the faulted-base requirement are enforced by
        // SweepSpec::validate(), which parse_sweep_at always runs.
        spec = spec.mtbf_scalings(ss);
    }
    if let Some(ds) = opt_f64_array(v, "sweep", "spot_discounts")? {
        // Range and the spot-carrying-base requirement are enforced by
        // SweepSpec::validate().
        spec = spec.spot_discounts(ds);
    }
    if let Some(n) = opt_usize(v, "sweep", "replications")? {
        spec = spec.replications(n);
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_scenario() {
        let text = r#"{
            "seed": 7,
            "advisor": "native",
            "network": {"type": "baud", "rate": 19200, "latency": 0.5},
            "resources": [
                {"name": "A", "pes": 4, "mips": 500, "policy": "time", "price": 2.0},
                {"name": "B", "machines": 8, "pes_per_machine": 1, "mips": 400,
                 "policy": "space-backfill", "price": 1.0}
            ],
            "users": [
                {"gridlets": 50, "length_mi": 5000, "deadline": 1000,
                 "budget": 9000, "optimization": "cost-time"}
            ]
        }"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.resources.len(), 2);
        assert_eq!(s.resources[1].machines, 8);
        assert!(!s.resources[1].policy.is_time_shared());
        assert_eq!(s.users.len(), 1);
        assert_eq!(s.users[0].experiment.num_gridlets(), 50);
        assert_eq!(s.users[0].experiment.optimization, Optimization::CostTime);
        assert!(s.users[0].advisor.is_none());
        assert!(s.users[0].broker.is_none());
        assert_eq!(
            s.network,
            NetworkSpec::Baud { default_rate: 19200.0, latency: 0.5 }
        );
    }

    #[test]
    fn wwg_testbed_shortcut() {
        let text = r#"{"testbed": "wwg", "users": [{"gridlets": 10}]}"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.resources.len(), 11);
    }

    #[test]
    fn d_b_factors() {
        let text = r#"{"testbed": "wwg",
            "users": [{"gridlets": 10, "d_factor": 0.5, "b_factor": 0.25}]}"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.users[0].experiment.deadline, crate::broker::DeadlineSpec::Factor(0.5));
        assert_eq!(s.users[0].experiment.budget, crate::broker::BudgetSpec::Factor(0.25));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_scenario("{").is_err());
        assert!(parse_scenario(r#"{"users": []}"#).is_err());
        assert!(parse_scenario(r#"{"testbed": "wwg", "users": []}"#).is_err());
        assert!(parse_scenario(r#"{"resources": [], "users": [{}]}"#).is_err());
        assert!(parse_scenario(r#"{"testbed": "unknown", "users": [{}]}"#).is_err());
        assert!(parse_scenario(
            r#"{"resources": [{"name": "A", "mips": 1, "price": 1, "policy": "bogus"}],
                "users": [{}]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_ambiguous_key_pairs() {
        for (text, needle) in [
            (
                r#"{"testbed": "wwg", "users": [{"deadline": 3100, "d_factor": 0.5}]}"#,
                "d_factor",
            ),
            (
                r#"{"testbed": "wwg", "users": [{"budget": 9000, "b_factor": 0.5}]}"#,
                "b_factor",
            ),
            (
                r#"{"users": [{}], "resources":
                    [{"name": "A", "mips": 1, "price": 1, "pes": 2, "pes_per_machine": 2}]}"#,
                "pes_per_machine",
            ),
        ] {
            let err = parse_scenario(text).unwrap_err().to_string();
            assert!(err.contains("either") && err.contains(needle), "{err}");
        }
    }

    #[test]
    fn rejects_unknown_keys_with_hint() {
        // Typo'd user key: the old loader silently fell back to the default
        // deadline; now it is a hard error with a did-you-mean hint.
        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"gridlets": 10, "dedline": 100}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("dedline"), "{err}");
        assert!(err.contains("deadline"), "hint expected: {err}");
        assert!(err.contains("user #0"), "context expected: {err}");

        let err = parse_scenario(r#"{"testbed": "wwg", "sede": 1, "users": [{}]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("sede") && err.contains("seed"), "{err}");

        let err = parse_scenario(
            r#"{"users": [{}],
                "resources": [{"name": "A", "mips": 1, "price": 1, "prize": 2}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("prize") && err.contains("price"), "{err}");

        let err = parse_scenario(
            r#"{"testbed": "wwg", "network": {"type": "baud", "ratee": 1},
                "users": [{}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("ratee") && err.contains("rate"), "{err}");
    }

    #[test]
    fn per_user_overrides() {
        let text = r#"{
            "testbed": "wwg",
            "broker": {"max_gridlets_per_pe": 4},
            "users": [
                {"gridlets": 10, "policy": "time"},
                {"gridlets": 20, "optimization": "cost", "advisor": "native",
                 "broker": {"min_tick": 2.5}, "submit_delay": 10}
            ]
        }"#;
        let s = parse_scenario(text).unwrap();
        // Scenario-level broker default applies to everyone...
        assert_eq!(s.broker_config.max_gridlets_per_pe, 4);
        assert_eq!(s.users[0].experiment.optimization, Optimization::Time);
        assert!(s.users[0].broker.is_none());
        // ...and the per-user override layers on top of it.
        let b1 = s.users[1].broker.as_ref().unwrap();
        assert_eq!(b1.max_gridlets_per_pe, 4, "inherits scenario default");
        assert_eq!(b1.min_tick, 2.5, "overrides min_tick");
        assert_eq!(s.users[1].advisor, Some(AdvisorKind::Native));
        assert_eq!(s.users[1].submit_delay, 10.0);
    }

    #[test]
    fn rejects_wrong_typed_values_for_known_keys() {
        // Known key + wrong type is as loud as an unknown key.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"broker": {"min_tick": "2.5"}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("min_tick") && err.contains("number"), "{err}");

        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"submit_delay": "50"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("submit_delay"), "{err}");

        let err = parse_scenario(r#"{"testbed": "wwg", "seed": "x", "users": [{}]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("seed"), "{err}");

        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"gridlets": 10.5}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("gridlets") && err.contains("integer"), "{err}");

        // Out-of-f64-precision integers would saturate under an `as` cast.
        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"gridlets": 1e30}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("gridlets"), "{err}");

        // Duplicate keys: first-wins lookup would silently drop the second.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"deadline": 100, "budget": 1, "deadline": 3100}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("duplicate") && err.contains("deadline"), "{err}");

        let err = parse_scenario(r#"{"testbed": 3, "users": [{}]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("testbed"), "{err}");

        // Fractional / negative seeds would silently change the RNG stream
        // under an `as u64` cast; they are hard errors instead.
        for bad in [r#"{"testbed": "wwg", "seed": 1.7, "users": [{}]}"#,
                    r#"{"testbed": "wwg", "seed": -3, "users": [{}]}"#] {
            let err = parse_scenario(bad).unwrap_err().to_string();
            assert!(err.contains("seed") && err.contains("integer"), "{err}");
        }
    }

    #[test]
    fn rejects_baud_knobs_on_instantaneous_network() {
        // Forgetting "type": "baud" must not silently drop rate/latency.
        let err = parse_scenario(
            r#"{"testbed": "wwg", "network": {"rate": 9600},
                "users": [{}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("rate") && err.contains("baud"), "{err}");

        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "network": {"type": "instantaneous", "latency": 0.5},
                "users": [{}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("latency"), "{err}");
    }

    #[test]
    fn rejects_ambiguous_policy_plus_optimization() {
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"policy": "time", "optimization": "cost"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("either"), "{err}");
    }

    #[test]
    fn rejects_testbed_plus_resources() {
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "resources": [{"name": "A", "mips": 1, "price": 1}],
                "users": [{}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not both"), "{err}");
    }

    #[test]
    fn edit_distance_hints() {
        assert_eq!(edit_distance("dedline", "deadline"), 1);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(nearest("dedline", USER_KEYS), Some("deadline"));
        assert_eq!(nearest("zzzzzz", USER_KEYS), None);
    }

    #[test]
    fn parses_sweep_section() {
        let text = r#"{
            "testbed": "wwg",
            "seed": 27,
            "users": [{"gridlets": 50, "deadline": 3100, "budget": 22000}],
            "sweep": {
                "deadlines": [100, 1100],
                "budgets": [5000, 10000, 22000],
                "users": [1, 10],
                "policies": ["cost", "time"],
                "resources": [["R8"], ["R8", "R4"]],
                "replications": 2
            }
        }"#;
        let spec = parse_sweep(text).unwrap();
        assert_eq!(spec.base.seed, 27);
        assert_eq!(spec.deadlines, vec![100.0, 1_100.0]);
        assert_eq!(spec.budgets.len(), 3);
        assert_eq!(spec.user_counts, vec![1, 10]);
        assert_eq!(spec.policies, vec![Optimization::Cost, Optimization::Time]);
        assert_eq!(spec.resource_subsets.len(), 2);
        assert_eq!(spec.replications, 2);
        // 2 subsets × 2 policies × 2 user counts × 2 deadlines × 3 budgets
        // × 2 replications.
        assert_eq!(spec.cell_count(), 96);
    }

    #[test]
    fn sweep_axes_are_all_optional() {
        let text = r#"{"testbed": "wwg", "users": [{"gridlets": 5}], "sweep": {}}"#;
        let spec = parse_sweep(text).unwrap();
        assert_eq!(spec.cell_count(), 1);
        assert_eq!(spec.replications, 1);
    }

    #[test]
    fn sweep_section_rejects_unknown_and_wrong_typed_keys() {
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"replciations": 3}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("replciations") && err.contains("replications"), "{err}");

        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"deadlines": 100}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("deadlines") && err.contains("array"), "{err}");

        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"policies": ["warp"]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("warp"), "{err}");

        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"users": [1.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("integer"), "{err}");

        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"resources": [["NoSuch"]]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("NoSuch"), "{err}");
    }

    #[test]
    fn parses_workload_objects() {
        use crate::workload::{ArrivalProcess, WorkloadSpec};
        let text = r#"{
            "testbed": "wwg",
            "users": [
                {"workload": {"type": "task_farm", "gridlets": 30,
                              "length_mi": 5000, "input_bytes": 10},
                 "deadline": 3100, "budget": 22000},
                {"workload": {"type": "heavy_tailed", "gridlets": 40,
                              "heavy_fraction": 0.2, "heavy_multiplier": 30}},
                {"workload": {"type": "explicit",
                              "jobs": [{"length_mi": 100},
                                       {"length_mi": 200, "input_bytes": 5}]}},
                {"workload": {"type": "online_arrivals", "process": "poisson",
                              "mean_interarrival": 4.5,
                              "workload": {"type": "task_farm", "gridlets": 10}}},
                {"workload": {"type": "online_arrivals", "process": "fixed",
                              "interval": 2,
                              "workload": {"type": "heavy_tailed"}}}
            ]
        }"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.users.len(), 5);
        let WorkloadSpec::TaskFarm { num_gridlets, base_length_mi, input_bytes, .. } =
            s.users[0].experiment.workload
        else {
            panic!("task farm expected")
        };
        assert_eq!((num_gridlets, base_length_mi, input_bytes), (30, 5_000.0, 10));
        let WorkloadSpec::HeavyTailed { heavy_fraction, heavy_multiplier, .. } =
            s.users[1].experiment.workload
        else {
            panic!("heavy tailed expected")
        };
        assert_eq!((heavy_fraction, heavy_multiplier), (0.2, 30.0));
        let WorkloadSpec::Explicit { jobs } = &s.users[2].experiment.workload else {
            panic!("explicit expected")
        };
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].input_bytes, 1000, "job staging defaults apply");
        assert_eq!(jobs[1].input_bytes, 5);
        let WorkloadSpec::OnlineArrivals { workload, arrivals } =
            &s.users[3].experiment.workload
        else {
            panic!("online expected")
        };
        assert_eq!(*arrivals, ArrivalProcess::Poisson { mean_interarrival: 4.5 });
        assert_eq!(workload.declared_jobs(), 10);
        assert!(s.users[4].experiment.workload.has_arrival_process());
    }

    #[test]
    fn parses_trace_workload_from_file() {
        let dir = std::env::temp_dir().join("gridsim_loader_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.swf");
        std::fs::write(&path, "; header\n0 10000 1000 500\n50 9000 1000 500\n").unwrap();
        let text = format!(
            r#"{{"testbed": "wwg",
                "users": [{{"workload": {{"type": "trace", "path": {path:?}}},
                            "deadline": 3100, "budget": 22000}}]}}"#,
            path = path.display().to_string()
        );
        let s = parse_scenario(&text).unwrap();
        assert_eq!(s.users[0].experiment.num_gridlets(), 2);
        assert!(s.users[0].experiment.workload.is_online());

        // A *relative* trace path resolves against the given base dir (what
        // the CLI passes: the scenario file's parent), not the CWD.
        let relative = r#"{"testbed": "wwg",
            "users": [{"workload": {"type": "trace", "path": "w.swf"}}]}"#;
        assert!(parse_scenario(relative).is_err(), "no base dir: CWD lookup fails");
        let s = parse_scenario_at(relative, Some(dir.as_path())).unwrap();
        assert_eq!(s.users[0].experiment.num_gridlets(), 2);
        std::fs::remove_dir_all(&dir).ok();

        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "trace", "path": "/no/such.swf"}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("/no/such.swf"), "{err}");
    }

    #[test]
    fn workload_object_rejects_bad_input() {
        // Unknown type with a did-you-mean hint.
        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"workload": {"type": "task_frm"}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("task_frm") && err.contains("task_farm"), "{err}");

        // Unknown key inside a typed workload object.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "task_farm", "gridletz": 5}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("gridletz") && err.contains("gridlets"), "{err}");

        // Mixing the flat keys with a workload object is ambiguous.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"gridlets": 5, "workload": {"type": "task_farm"}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not both"), "{err}");

        // Wrong process knob for the arrival process.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "online_arrivals",
                                        "process": "fixed", "mean_interarrival": 3,
                                        "workload": {"type": "task_farm"}}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mean_interarrival"), "{err}");

        // Nested online arrivals.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "online_arrivals", "mean_interarrival": 3,
                    "workload": {"type": "online_arrivals", "mean_interarrival": 2,
                                 "workload": {"type": "task_farm"}}}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("nest") || err.contains("wrap"), "{err}");

        // Out-of-range parameters fail at load time via validate().
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "heavy_tailed", "heavy_fraction": 1.5}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("heavy_fraction"), "{err}");

        // Empty explicit job list.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "explicit", "jobs": []}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn parses_dag_workload_inline() {
        use crate::workload::WorkloadSpec;
        let text = r#"{
            "testbed": "wwg",
            "users": [{"workload": {"type": "dag",
                "nodes": [{"id": "prep", "length_mi": 1000},
                          {"id": "sim", "length_mi": 4000, "input_bytes": 64},
                          {"id": "post", "length_mi": 500}],
                "edges": [["prep", "sim"], ["sim", "post"]]},
                "deadline": 3100, "budget": 22000}]
        }"#;
        let s = parse_scenario(text).unwrap();
        let WorkloadSpec::Dag { nodes, edges } = &s.users[0].experiment.workload else {
            panic!("dag expected")
        };
        assert_eq!(nodes.len(), 3);
        assert_eq!(nodes[1].input_bytes, 64);
        assert_eq!(nodes[2].input_bytes, 1000, "node staging defaults apply");
        assert_eq!(
            edges,
            &vec![
                ("prep".to_string(), "sim".to_string()),
                ("sim".to_string(), "post".to_string())
            ]
        );
        assert_eq!(s.users[0].experiment.num_gridlets(), 3);
    }

    #[test]
    fn parses_dag_workload_from_dot_file() {
        let dir = std::env::temp_dir().join("gridsim_loader_dag_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("wf.dot"),
            "digraph wf {\n  a [length_mi=1000];\n  b [length_mi=2000];\n  a -> b;\n}\n",
        )
        .unwrap();
        let text = r#"{"testbed": "wwg",
            "users": [{"workload": {"type": "dag", "file": "wf.dot"}}]}"#;
        // A relative graph path resolves against the scenario file's
        // directory, exactly like a relative trace path.
        assert!(parse_scenario(text).is_err(), "no base dir: CWD lookup fails");
        let s = parse_scenario_at(text, Some(dir.as_path())).unwrap();
        assert_eq!(s.users[0].experiment.num_gridlets(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dag_workload_rejects_bad_input() {
        // Inline graph and file are mutually exclusive, and one is required.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "dag", "file": "x.dot",
                    "nodes": [{"id": "a", "length_mi": 1}]}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not both"), "{err}");
        let err = parse_scenario(
            r#"{"testbed": "wwg", "users": [{"workload": {"type": "dag"}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("nodes") && err.contains("file"), "{err}");

        // Edges must be [parent, child] string pairs.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "dag",
                    "nodes": [{"id": "a", "length_mi": 1}], "edges": [["a"]]}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("pair"), "{err}");

        // Graph-level validation runs at load time: a dangling edge gets a
        // did-you-mean hint...
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "dag",
                    "nodes": [{"id": "prep", "length_mi": 1},
                              {"id": "sim", "length_mi": 1}],
                    "edges": [["prep", "sm"]]}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("sm") && err.contains("sim"), "{err}");

        // ...and a cycle names its members.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "dag",
                    "nodes": [{"id": "a", "length_mi": 1}, {"id": "b", "length_mi": 1}],
                    "edges": [["a", "b"], ["b", "a"]]}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("cycle"), "{err}");

        // Unknown node key with a hint.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "dag",
                    "nodes": [{"id": "a", "lenght_mi": 1}]}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("lenght_mi") && err.contains("length_mi"), "{err}");

        // Precedence gating cannot ride under a timed arrival process.
        let err = parse_scenario(
            r#"{"testbed": "wwg",
                "users": [{"workload": {"type": "online_arrivals",
                    "mean_interarrival": 3,
                    "workload": {"type": "dag",
                        "nodes": [{"id": "a", "length_mi": 1},
                                  {"id": "b", "length_mi": 1}],
                        "edges": [["a", "b"]]}}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("dag"), "{err}");
    }

    #[test]
    fn sweep_workload_axes_parse_and_validate() {
        let text = r#"{
            "testbed": "wwg",
            "users": [{"workload": {"type": "online_arrivals", "mean_interarrival": 5,
                                    "workload": {"type": "heavy_tailed", "gridlets": 20}},
                       "deadline": 3100, "budget": 22000}],
            "sweep": {"mean_interarrivals": [1, 5, 25], "heavy_fractions": [0, 0.1, 0.5]}
        }"#;
        let spec = parse_sweep(text).unwrap();
        assert_eq!(spec.mean_interarrivals, vec![1.0, 5.0, 25.0]);
        assert_eq!(spec.heavy_fractions, vec![0.0, 0.1, 0.5]);
        assert_eq!(spec.cell_count(), 9);

        // The axes demand a compatible workload somewhere in the base.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{"gridlets": 5}],
                "sweep": {"mean_interarrivals": [1]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("online_arrivals"), "{err}");
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{"gridlets": 5}],
                "sweep": {"heavy_fractions": [0.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("heavy_tailed"), "{err}");
    }

    /// A tiny 18-column SWF file with two users (3, 7) for loader tests.
    fn write_swf(dir: &std::path::Path, name: &str) -> std::path::PathBuf {
        let text = "\
; Version: 2\n\
; UnixStartTime: 845923442\n\
1 100 5 60 4 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1\n\
2 160 -1 30 2 -1 -1 2 40 -1 1 7 1 -1 1 1 -1 -1\n\
3 200 1 45 2 -1 -1 2 -1 -1 1 3 1 -1 1 0 -1 -1\n";
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        path
    }

    #[test]
    fn parses_swf_trace_with_select_and_conversion_knobs() {
        use crate::workload::WorkloadSpec;
        let dir = std::env::temp_dir().join("gridsim_loader_swf_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_swf(&dir, "log.swf");

        // Per-user split of one log (the selector), plus SWF conversion
        // knobs (mips scale, uniform staging).
        let text = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "trace", "path": "log.swf", "mips": 10,
                          "input_bytes": 256, "select": {"users": [3]}},
             "deadline": 1e6, "budget": 1e9},
            {"workload": {"type": "trace", "path": "log.swf",
                          "select": {"users": [7]}}},
            {"workload": {"type": "trace", "path": "log.swf",
                          "select": {"users": [3]}}}
        ]}"#;
        let s = parse_scenario_at(text, Some(dir.as_path())).unwrap();
        assert_eq!(s.users[0].experiment.num_gridlets(), 2, "user 3's jobs");
        assert_eq!(s.users[1].experiment.num_gridlets(), 1, "user 7's jobs");
        let WorkloadSpec::Trace { jobs, selector, .. } = &s.users[0].experiment.workload
        else {
            panic!("trace expected")
        };
        assert_eq!(jobs.len(), 3, "the full log is retained for re-selection");
        assert_eq!(selector.users, vec![3]);
        assert_eq!(jobs[0].length_mi, 60.0 * 4.0 * 10.0, "mips scales MI");
        assert_eq!(jobs[0].input_bytes, 256);

        // Same path + same options ⇒ one shared allocation (users 1 and 2);
        // different conversion knobs (user 0) ⇒ a distinct load.
        fn trace_arc(s: &crate::scenario::Scenario, u: usize) -> &Arc<[TraceJob]> {
            let WorkloadSpec::Trace { jobs, .. } = &s.users[u].experiment.workload else {
                panic!("trace expected")
            };
            jobs
        }
        assert!(Arc::ptr_eq(trace_arc(&s, 1), trace_arc(&s, 2)), "one log, one allocation");
        assert!(
            !Arc::ptr_eq(trace_arc(&s, 0), trace_arc(&s, 1)),
            "stated knobs load separately"
        );

        // A selector that keeps nothing fails at load time.
        let empty = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "trace", "path": "log.swf",
                          "select": {"users": [99]}}}]}"#;
        let err = format!("{:#}", parse_scenario_at(empty, Some(dir.as_path())).unwrap_err());
        assert!(err.contains("keeps none"), "{err}");

        // Unknown select key gets the usual did-you-mean treatment.
        let typo = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "trace", "path": "log.swf",
                          "select": {"userz": [3]}}}]}"#;
        let err = format!("{:#}", parse_scenario_at(typo, Some(dir.as_path())).unwrap_err());
        assert!(err.contains("userz") && err.contains("users"), "{err}");

        // SWF knobs against a legacy 4-column file are rejected loudly.
        std::fs::write(dir.join("legacy.swf"), "0 1000 1 1\n").unwrap();
        let legacy = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "trace", "path": "legacy.swf", "mips": 2}}]}"#;
        let err = format!("{:#}", parse_scenario_at(legacy, Some(dir.as_path())).unwrap_err());
        assert!(err.contains("legacy"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_concat_and_mix_workloads_resolving_nested_paths() {
        use crate::workload::WorkloadSpec;
        let dir = std::env::temp_dir().join("gridsim_loader_mix_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_swf(&dir, "log.swf");

        // The regression this pins: a *relative* trace path nested inside a
        // mix/concat part resolves against the scenario file's directory,
        // exactly like a top-level trace workload.
        let text = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "mix",
                          "weights": [3, 1],
                          "parts": [
                              {"type": "heavy_tailed", "gridlets": 10},
                              {"type": "trace", "path": "log.swf"}]},
             "deadline": 1e6, "budget": 1e9},
            {"workload": {"type": "concat",
                          "parts": [
                              {"type": "task_farm", "gridlets": 5},
                              {"type": "trace", "path": "log.swf",
                               "select": {"users": [3]}}]}}
        ]}"#;
        assert!(parse_scenario(text).is_err(), "no base dir: CWD lookup fails");
        let s = parse_scenario_at(text, Some(dir.as_path())).unwrap();
        let WorkloadSpec::Mix { parts, weights } = &s.users[0].experiment.workload else {
            panic!("mix expected")
        };
        assert_eq!(parts.len(), 2);
        assert_eq!(weights, &vec![3.0, 1.0]);
        assert_eq!(s.users[0].experiment.num_gridlets(), 13);
        assert_eq!(s.users[1].experiment.num_gridlets(), 7, "concat: 5 farm + 2 trace");

        // Default weights are all-1; weight/part arity mismatch is loud.
        let unweighted = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "mix", "parts": [
                {"type": "task_farm", "gridlets": 2},
                {"type": "task_farm", "gridlets": 3}]}}]}"#;
        let s = parse_scenario(unweighted).unwrap();
        let WorkloadSpec::Mix { weights, .. } = &s.users[0].experiment.workload else {
            panic!("mix expected")
        };
        assert_eq!(weights, &vec![1.0, 1.0]);
        let mismatched = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "mix", "weights": [1],
                          "parts": [{"type": "task_farm"},
                                    {"type": "task_farm"}]}}]}"#;
        let err = format!("{:#}", parse_scenario(mismatched).unwrap_err());
        assert!(err.contains("weight"), "{err}");

        // Empty parts are rejected with the array named.
        let empty = r#"{"testbed": "wwg",
            "users": [{"workload": {"type": "concat", "parts": []}}]}"#;
        let err = parse_scenario(empty).unwrap_err().to_string();
        assert!(err.contains("parts"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_modulated_arrivals() {
        use crate::workload::{ArrivalProcess, RateEnvelope};
        let text = r#"{"testbed": "wwg", "users": [
            {"workload": {"type": "online_arrivals", "process": "modulated",
                          "mean_interarrival": 10, "period": 1000,
                          "envelope": [1.0, 0.2],
                          "workload": {"type": "task_farm", "gridlets": 20}}},
            {"workload": {"type": "online_arrivals", "process": "modulated",
                          "mean_interarrival": 5, "period": 500, "amplitude": 0.8,
                          "workload": {"type": "task_farm", "gridlets": 20}}}
        ]}"#;
        let s = parse_scenario(text).unwrap();
        let crate::workload::WorkloadSpec::OnlineArrivals { arrivals, .. } =
            &s.users[0].experiment.workload
        else {
            panic!("online expected")
        };
        assert_eq!(
            *arrivals,
            ArrivalProcess::Modulated {
                mean_interarrival: 10.0,
                envelope: RateEnvelope::Piecewise { period: 1_000.0, rates: vec![1.0, 0.2] },
            }
        );
        let crate::workload::WorkloadSpec::OnlineArrivals { arrivals, .. } =
            &s.users[1].experiment.workload
        else {
            panic!("online expected")
        };
        assert_eq!(
            *arrivals,
            ArrivalProcess::Modulated {
                mean_interarrival: 5.0,
                envelope: RateEnvelope::Sinusoid { period: 500.0, amplitude: 0.8 },
            }
        );

        // Envelope xor amplitude; period required; knobs rejected on the
        // wrong process; out-of-range values fail via validate().
        for (bad, needle) in [
            (
                r#"{"type": "online_arrivals", "process": "modulated",
                    "mean_interarrival": 10, "period": 100,
                    "envelope": [1], "amplitude": 0.5,
                    "workload": {"type": "task_farm"}}"#,
                "not both",
            ),
            (
                r#"{"type": "online_arrivals", "process": "modulated",
                    "mean_interarrival": 10, "period": 100,
                    "workload": {"type": "task_farm"}}"#,
                "envelope",
            ),
            (
                r#"{"type": "online_arrivals", "process": "modulated",
                    "mean_interarrival": 10, "envelope": [1],
                    "workload": {"type": "task_farm"}}"#,
                "period",
            ),
            (
                r#"{"type": "online_arrivals", "process": "poisson",
                    "mean_interarrival": 10, "amplitude": 0.5,
                    "workload": {"type": "task_farm"}}"#,
                "modulated",
            ),
            (
                r#"{"type": "online_arrivals", "process": "modulated",
                    "mean_interarrival": 10, "period": 100, "amplitude": 2,
                    "workload": {"type": "task_farm"}}"#,
                "amplitude",
            ),
            (
                r#"{"type": "online_arrivals", "process": "modulated",
                    "mean_interarrival": 10, "period": 100, "envelope": [0, 0],
                    "workload": {"type": "task_farm"}}"#,
                "all 0",
            ),
        ] {
            let text = format!(
                r#"{{"testbed": "wwg", "users": [{{"workload": {bad}}}]}}"#
            );
            let err = format!("{:#}", parse_scenario(&text).unwrap_err());
            assert!(err.contains(needle), "{needle}: {err}");
        }
    }

    #[test]
    fn sweep_trace_selector_and_mix_weight_axes_parse() {
        let dir = std::env::temp_dir().join("gridsim_loader_sweep_axes_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_swf(&dir, "log.swf");
        let text = r#"{
            "testbed": "wwg",
            "users": [{"workload": {"type": "mix", "parts": [
                           {"type": "heavy_tailed", "gridlets": 10},
                           {"type": "trace", "path": "log.swf"}]},
                       "deadline": 1e6, "budget": 1e9}],
            "sweep": {"trace_selectors": [{"users": [3]}, {"users": [7]}],
                      "mix_weights": [[1, 1], [5, 1]]}
        }"#;
        let spec = parse_sweep_at(text, Some(dir.as_path())).unwrap();
        assert_eq!(spec.trace_selectors.len(), 2);
        assert_eq!(spec.trace_selectors[0].users, vec![3]);
        assert_eq!(spec.mix_weights, vec![vec![1.0, 1.0], vec![5.0, 1.0]]);
        assert_eq!(spec.cell_count(), 4);

        // The axes demand a compatible workload in the base.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{"gridlets": 5}],
                "sweep": {"trace_selectors": [{"users": [3]}]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("trace"), "{err}");
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{"gridlets": 5}],
                "sweep": {"mix_weights": [[1, 2]]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mix"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_faults_block() {
        let text = r#"{
            "testbed": "wwg",
            "users": [{"gridlets": 10, "deadline": 3100, "budget": 22000}],
            "faults": {
                "default": {"process": "exponential", "mtbf": 500, "mttr": 50},
                "overrides": {
                    "R3": {"process": "weibull", "mtbf": 800, "mttr": 40, "shape": 1.5},
                    "R8": {"process": "trace", "intervals": [[100, 150], [400, 420]]}
                },
                "mtbf_scaling": 0.5
            }
        }"#;
        let s = parse_scenario(text).unwrap();
        let faults = s.faults.as_ref().unwrap();
        assert_eq!(
            faults.default,
            Some(FaultProcess::Exponential { mtbf: 500.0, mttr: 50.0 })
        );
        assert_eq!(faults.mtbf_scaling, 0.5);
        assert_eq!(
            faults.process_for("R3"),
            Some(&FaultProcess::Weibull { mtbf: 800.0, mttr: 40.0, shape: 1.5 })
        );
        assert_eq!(
            faults.process_for("R8"),
            Some(&FaultProcess::Trace { intervals: vec![(100.0, 150.0), (400.0, 420.0)] })
        );
        // Unlisted resources fall back to the default.
        assert_eq!(
            faults.process_for("R0"),
            Some(&FaultProcess::Exponential { mtbf: 500.0, mttr: 50.0 })
        );

        // A scenario without the block carries no spec at all.
        let clean = parse_scenario(r#"{"testbed": "wwg", "users": [{}]}"#).unwrap();
        assert!(clean.faults.is_none());
    }

    #[test]
    fn faults_block_rejects_bad_input() {
        let wrap = |faults: &str| {
            format!(r#"{{"testbed": "wwg", "users": [{{}}], "faults": {faults}}}"#)
        };
        for (faults, needle) in [
            // Typo'd block key, with a hint.
            (r#"{"defualt": {"process": "exponential", "mtbf": 1, "mttr": 1}}"#, "default"),
            // Typo'd process name, with a hint.
            (r#"{"default": {"process": "expnential", "mtbf": 1, "mttr": 1}}"#, "exponential"),
            // Wrong process knob: shape belongs to weibull only.
            (
                r#"{"default": {"process": "exponential", "mtbf": 1, "mttr": 1,
                               "shape": 2}}"#,
                "shape",
            ),
            // Missing required parameters.
            (r#"{"default": {"process": "exponential", "mtbf": 1}}"#, "mttr"),
            (r#"{"default": {"process": "weibull", "mtbf": 1, "mttr": 1}}"#, "shape"),
            // Non-finite / non-positive parameters die in validate().
            (r#"{"default": {"process": "exponential", "mtbf": -5, "mttr": 1}}"#, "mtbf"),
            (r#"{"default": {"process": "exponential", "mtbf": 1e999, "mttr": 1}}"#, "mtbf"),
            // Trace intervals must be sorted, non-overlapping pairs.
            (
                r#"{"default": {"process": "trace", "intervals": [[100, 50]]}}"#,
                "end",
            ),
            (
                r#"{"default": {"process": "trace", "intervals": [[0, 10], [5, 20]]}}"#,
                "overlap",
            ),
            (r#"{"default": {"process": "trace", "intervals": [[1, 2, 3]]}}"#, "pair"),
            // Overrides must name real resources (did-you-mean included).
            (
                r#"{"overrides": {"R99": {"process": "exponential",
                                          "mtbf": 1, "mttr": 1}}}"#,
                "R99",
            ),
            // An empty block drives nothing — reject it loudly.
            (r#"{}"#, "default"),
            // Severity factor must be positive and finite.
            (
                r#"{"default": {"process": "exponential", "mtbf": 1, "mttr": 1},
                    "mtbf_scaling": 0}"#,
                "mtbf_scaling",
            ),
        ] {
            let err = format!("{:#}", parse_scenario(&wrap(faults)).unwrap_err());
            assert!(err.contains(needle), "{faults} → {err}");
        }
    }

    #[test]
    fn parses_resource_calendar() {
        let text = r#"{
            "resources": [
                {"name": "A", "mips": 100, "price": 1, "time_zone": 9,
                 "calendar": {"peak_load": 0.8, "off_peak_load": 0.2,
                              "holiday_load": 0.05, "units_per_hour": 3600}},
                {"name": "B", "mips": 100, "price": 1,
                 "calendar": {"time_zone": -5, "peak_load": 0.5}}
            ],
            "users": [{"gridlets": 5}]
        }"#;
        let s = parse_scenario(text).unwrap();
        let a = s.resources[0].calendar.as_ref().unwrap();
        assert_eq!(a.time_zone, 9.0, "calendar inherits the resource's time zone");
        assert_eq!((a.peak_load, a.off_peak_load, a.holiday_load), (0.8, 0.2, 0.05));
        assert_eq!(a.units_per_hour, 3600.0);
        let b = s.resources[1].calendar.as_ref().unwrap();
        assert_eq!(b.time_zone, -5.0, "explicit calendar time zone wins");
        assert_eq!((b.off_peak_load, b.units_per_hour), (0.0, 1.0), "defaults");
        assert!(s.resources[0].calendar.is_some());

        for (calendar, needle) in [
            // Loads live in [0, 1): a load of 1 stops the resource forever.
            (r#"{"peak_load": 1.0}"#, "peak_load"),
            (r#"{"off_peak_load": -0.1}"#, "off_peak_load"),
            // Typo'd key with a hint.
            (r#"{"peek_load": 0.5}"#, "peak_load"),
            // Zero units_per_hour would divide simulation time by zero.
            (r#"{"units_per_hour": 0}"#, "units_per_hour"),
        ] {
            let text = format!(
                r#"{{"resources": [{{"name": "A", "mips": 1, "price": 1,
                     "calendar": {calendar}}}], "users": [{{}}]}}"#
            );
            let err = format!("{:#}", parse_scenario(&text).unwrap_err());
            assert!(err.contains(needle), "{calendar} → {err}");
        }
    }

    #[test]
    fn parses_broker_resubmission_policy() {
        // String shorthands.
        let s = parse_scenario(
            r#"{"testbed": "wwg", "broker": {"resubmission": "abandon"}, "users": [{}]}"#,
        )
        .unwrap();
        assert_eq!(s.broker_config.resubmission, ResubmissionPolicy::Abandon);
        let s = parse_scenario(
            r#"{"testbed": "wwg", "broker": {"resubmission": "retry"}, "users": [{}]}"#,
        )
        .unwrap();
        assert_eq!(s.broker_config.resubmission, ResubmissionPolicy::default_retry());

        // Object form with bounds, per user.
        let s = parse_scenario(
            r#"{"testbed": "wwg", "users": [
                {"broker": {"resubmission": {"policy": "retry", "max_attempts": 3,
                                             "backoff": 25}}}]}"#,
        )
        .unwrap();
        assert_eq!(
            s.users[0].broker.as_ref().unwrap().resubmission,
            ResubmissionPolicy::RetryWithBackoff { max_attempts: 3, backoff: 25.0 }
        );

        // The default (no key) keeps pre-reliability behavior.
        let s = parse_scenario(r#"{"testbed": "wwg", "users": [{}]}"#).unwrap();
        assert_eq!(s.broker_config.resubmission, ResubmissionPolicy::default_retry());

        for (broker, needle) in [
            (r#"{"resubmission": "abandn"}"#, "abandon"),
            (r#"{"resubmission": {"policy": "abandon", "max_attempts": 3}}"#, "retry"),
            (r#"{"resubmission": {"max_attempts": 3}}"#, "policy"),
            (r#"{"resubmission": {"policy": "retry", "backoff": -1}}"#, "backoff"),
            (r#"{"resubmission": 3}"#, "object"),
        ] {
            let text = format!(
                r#"{{"testbed": "wwg", "broker": {broker}, "users": [{{}}]}}"#
            );
            let err = format!("{:#}", parse_scenario(&text).unwrap_err());
            assert!(err.contains(needle), "{broker} → {err}");
        }
    }

    #[test]
    fn sweep_mtbf_scalings_axis_parses_and_demands_faults() {
        let text = r#"{
            "testbed": "wwg",
            "users": [{"gridlets": 10, "deadline": 3100, "budget": 22000}],
            "faults": {"default": {"process": "exponential", "mtbf": 500, "mttr": 50}},
            "sweep": {"mtbf_scalings": [0.25, 0.5, 1, 2], "replications": 2}
        }"#;
        let spec = parse_sweep(text).unwrap();
        assert_eq!(spec.mtbf_scalings, vec![0.25, 0.5, 1.0, 2.0]);
        assert_eq!(spec.cell_count(), 8);

        // Without a faults block the axis has nothing to scale.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"mtbf_scalings": [0.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("faults"), "{err}");
        // Typo'd axis name gets the usual hint.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"mtbf_scaling": [0.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mtbf_scalings"), "{err}");
    }

    #[test]
    fn parses_market_blocks() {
        let text = r#"{
            "testbed": "wwg",
            "users": [{"gridlets": 10, "deadline": 3100, "budget": 22000,
                       "max_spot_price": 2.5}],
            "pricing": {
                "default": {"model": "utilization_linear", "slope": 4.0, "cap": 12.0},
                "overrides": {
                    "R0": {"model": "static", "price": 5.0},
                    "R8": {"model": "utilization_step",
                           "steps": [[0.5, 2.0], [0.9, 6.0]]}
                }
            },
            "spot": {"R3": 0.5, "R8": 0.8}
        }"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.users[0].max_spot_price, Some(2.5));
        let market = s.market.as_ref().unwrap();
        // The default folds into one fully-resolved entry per resource,
        // its base defaulting to the resource's Table 2 price (R1: 4 G$).
        let (m, d) = market.config_for("R1", 4.0).unwrap();
        assert_eq!(
            m,
            PriceModel::UtilizationLinear { base: 4.0, slope: 4.0, floor: 0.0, cap: 12.0 }
        );
        assert_eq!(d, None);
        // Overrides replace the default per resource.
        let (m, _) = market.config_for("R0", 8.0).unwrap();
        assert_eq!(m, PriceModel::Static { price: 5.0 });
        let (m, d) = market.config_for("R8", 1.0).unwrap();
        assert_eq!(
            m,
            PriceModel::UtilizationStep {
                base: 1.0,
                steps: vec![(0.5, 2.0), (0.9, 6.0)],
                floor: 0.0,
                cap: f64::INFINITY,
            }
        );
        assert_eq!(d, Some(0.8));
        let (_, d) = market.config_for("R3", 3.0).unwrap();
        assert_eq!(d, Some(0.5));

        // A spot-only file prices the tier's resources Static at their
        // configured price (handled inside config_for).
        let spot_only = parse_scenario(
            r#"{"testbed": "wwg", "users": [{}], "spot": {"R4": 0.7}}"#,
        )
        .unwrap();
        let m = spot_only.market.unwrap();
        assert!(m.pricing.is_empty());
        assert_eq!(
            m.config_for("R4", 2.0),
            Some((PriceModel::Static { price: 2.0 }, Some(0.7)))
        );

        // A scenario without the blocks carries no market spec at all —
        // the byte-identity guarantee for pre-market files.
        let clean = parse_scenario(r#"{"testbed": "wwg", "users": [{}]}"#).unwrap();
        assert!(clean.market.is_none());
    }

    #[test]
    fn market_blocks_reject_bad_input() {
        let wrap =
            |extra: &str| format!(r#"{{"testbed": "wwg", "users": [{{}}], {extra}}}"#);
        for (block, needle) in [
            // Typo'd pricing key, with a hint.
            (r#""pricing": {"overides": {"R0": {"model": "static"}}}"#, "overrides"),
            // Typo'd model name, with a hint.
            (
                r#""pricing": {"default": {"model": "utilization_liner", "slope": 1}}"#,
                "utilization_linear",
            ),
            // Wrong model knob: slope belongs to utilization_linear only.
            (r#""pricing": {"default": {"model": "static", "slope": 1}}"#, "slope"),
            // Missing required parameters.
            (r#""pricing": {"default": {"model": "utilization_linear"}}"#, "slope"),
            (r#""pricing": {"default": {"model": "utilization_step"}}"#, "steps"),
            (r#""pricing": {"default": {"price": 5}}"#, "model"),
            // An empty block drives nothing — reject it loudly.
            (r#""pricing": {}"#, "default"),
            // Envelope and step-shape violations die in validate().
            (
                r#""pricing": {"default": {"model": "utilization_linear", "slope": 1,
                                          "floor": 5, "cap": 2}}"#,
                "cap",
            ),
            (
                r#""pricing": {"default": {"model": "utilization_step",
                                          "steps": [[0.5, 2], [0.4, 3]]}}"#,
                "ascending",
            ),
            (
                r#""pricing": {"default": {"model": "utilization_step",
                                          "steps": [[0.5, 2, 3]]}}"#,
                "pair",
            ),
            // Overrides must name real resources, exactly once each.
            (r#""pricing": {"overrides": {"R99": {"model": "static"}}}"#, "R99"),
            (
                r#""pricing": {"overrides": {"R0": {"model": "static"},
                                            "R0": {"model": "static"}}}"#,
                "duplicate",
            ),
            // Spot discounts live in (0, 1] and name real resources.
            (r#""spot": {"R0": 0}"#, "(0, 1]"),
            (r#""spot": {"R0": 1.5}"#, "(0, 1]"),
            (r#""spot": {"R99": 0.5}"#, "R99"),
            (r#""spot": {}"#, "empty"),
            (r#""spot": 0.5"#, "object"),
        ] {
            let err = format!("{:#}", parse_scenario(&wrap(block)).unwrap_err());
            assert!(err.contains(needle), "{block} → {err}");
        }

        // A spot bid must be finite and non-negative.
        let err = parse_scenario(r#"{"testbed": "wwg", "users": [{"max_spot_price": -1}]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_spot_price"), "{err}");
    }

    #[test]
    fn sweep_spot_discounts_axis_parses_and_demands_spot() {
        let text = r#"{
            "testbed": "wwg",
            "users": [{"gridlets": 10, "deadline": 3100, "budget": 22000,
                       "max_spot_price": 2.0}],
            "spot": {"R4": 0.5},
            "sweep": {"spot_discounts": [0.25, 0.5, 1], "policies": ["cost", "time"]}
        }"#;
        let spec = parse_sweep(text).unwrap();
        assert_eq!(spec.spot_discounts, vec![0.25, 0.5, 1.0]);
        assert_eq!(spec.cell_count(), 6);

        // Without a spot tier the axis has nothing to discount.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"spot_discounts": [0.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("spot"), "{err}");
        // Typo'd axis name gets the usual hint.
        let err = parse_sweep(
            r#"{"testbed": "wwg", "users": [{}],
                "sweep": {"spot_discount": [0.5]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("spot_discounts"), "{err}");
    }

    #[test]
    fn plain_run_rejects_sweep_files_but_sweep_accepts_plain_files() {
        let sweep_file = r#"{"testbed": "wwg", "users": [{}], "sweep": {}}"#;
        let err = parse_scenario(sweep_file).unwrap_err().to_string();
        assert!(err.contains("repro sweep"), "{err}");

        // The reverse direction is allowed: a plain scenario file is a
        // zero-axis sweep (the CLI supplies the axes).
        let plain_file = r#"{"testbed": "wwg", "users": [{}]}"#;
        let spec = parse_sweep(plain_file).unwrap();
        assert_eq!(spec.cell_count(), 1);
    }
}
