//! JSON scenario loader: a complete grid + users description in one file.
//!
//! ```json
//! {
//!   "seed": 42,
//!   "advisor": "native",
//!   "network": {"type": "instantaneous"},
//!   "resources": [
//!     {"name": "R0", "machines": 1, "pes_per_machine": 4, "mips": 515,
//!      "policy": "time", "price": 8.0, "time_zone": 10.0},
//!     {"name": "R7", "machines": 16, "pes_per_machine": 1, "mips": 410,
//!      "policy": "space-fcfs", "price": 4.0}
//!   ],
//!   "users": [
//!     {"gridlets": 200, "length_mi": 10000, "variation": 0.1,
//!      "deadline": 3100, "budget": 22000, "optimization": "cost"}
//!   ]
//! }
//! ```
//!
//! `"testbed": "wwg"` can replace the `resources` array to pull in Table 2.

use super::testbed::wwg_testbed;
use crate::broker::{ExperimentSpec, Optimization};
use crate::gridsim::{AllocPolicy, SpacePolicy};
use crate::scenario::{AdvisorKind, NetworkSpec, ResourceSpec, Scenario};
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};

/// Parse a scenario from JSON text.
pub fn parse_scenario(text: &str) -> Result<Scenario> {
    let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
    let seed = root.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64;

    let resources = match root.get("testbed").and_then(Value::as_str) {
        Some("wwg") => wwg_testbed(),
        Some(other) => bail!("unknown testbed {other:?} (only \"wwg\" is built in)"),
        None => {
            let arr = root
                .get("resources")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("missing \"resources\" array (or \"testbed\": \"wwg\")"))?;
            arr.iter().map(parse_resource).collect::<Result<Vec<_>>>()?
        }
    };

    let users = root
        .get("users")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing \"users\" array"))?
        .iter()
        .map(parse_user)
        .collect::<Result<Vec<_>>>()?;

    let advisor = match root.get("advisor").and_then(Value::as_str).unwrap_or("native") {
        "native" => AdvisorKind::Native,
        "xla" => AdvisorKind::Xla,
        other => bail!("unknown advisor {other:?} (native|xla)"),
    };

    let network = match root.get("network") {
        None => NetworkSpec::Instantaneous,
        Some(net) => match net.get("type").and_then(Value::as_str) {
            Some("instantaneous") | None => NetworkSpec::Instantaneous,
            Some("baud") => NetworkSpec::Baud {
                default_rate: net
                    .get("rate")
                    .and_then(Value::as_f64)
                    .unwrap_or(crate::gridsim::tags::DEFAULT_BAUD_RATE),
                latency: net.get("latency").and_then(Value::as_f64).unwrap_or(0.0),
            },
            Some(other) => bail!("unknown network type {other:?}"),
        },
    };

    let mut builder = Scenario::builder()
        .resources(resources)
        .seed(seed)
        .advisor(advisor)
        .network(network);
    for u in users {
        builder = builder.user(u);
    }
    if let Some(t) = root.get("max_time").and_then(Value::as_f64) {
        builder = builder.max_time(t);
    }
    Ok(builder.build())
}

fn parse_resource(v: &Value) -> Result<ResourceSpec> {
    let name = v.req_str("name").context("resource")?.to_string();
    let policy = match v.get("policy").and_then(Value::as_str).unwrap_or("time") {
        "time" | "time-shared" => AllocPolicy::TimeShared,
        "space-fcfs" | "space" => AllocPolicy::SpaceShared(SpacePolicy::Fcfs),
        "space-sjf" => AllocPolicy::SpaceShared(SpacePolicy::Sjf),
        "space-backfill" => AllocPolicy::SpaceShared(SpacePolicy::BackfillEasy),
        other => bail!("resource {name}: unknown policy {other:?}"),
    };
    Ok(ResourceSpec {
        arch: v.get("arch").and_then(Value::as_str).unwrap_or("generic").to_string(),
        os: v.get("os").and_then(Value::as_str).unwrap_or("linux").to_string(),
        machines: v.get("machines").and_then(Value::as_usize).unwrap_or(1),
        pes_per_machine: v
            .get("pes_per_machine")
            .and_then(Value::as_usize)
            .or_else(|| v.get("pes").and_then(Value::as_usize))
            .unwrap_or(1),
        mips_per_pe: v.req_f64("mips").with_context(|| format!("resource {name}"))?,
        policy,
        price: v.req_f64("price").with_context(|| format!("resource {name}"))?,
        time_zone: v.get("time_zone").and_then(Value::as_f64).unwrap_or(0.0),
        calendar: None,
        name,
    })
}

fn parse_user(v: &Value) -> Result<ExperimentSpec> {
    let mut spec = ExperimentSpec::task_farm(
        v.get("gridlets").and_then(Value::as_usize).unwrap_or(200),
        v.get("length_mi").and_then(Value::as_f64).unwrap_or(10_000.0),
        v.get("variation").and_then(Value::as_f64).unwrap_or(0.10),
    );
    if let Some(d) = v.get("deadline").and_then(Value::as_f64) {
        spec = spec.deadline(d);
    } else if let Some(f) = v.get("d_factor").and_then(Value::as_f64) {
        spec = spec.d_factor(f);
    }
    if let Some(b) = v.get("budget").and_then(Value::as_f64) {
        spec = spec.budget(b);
    } else if let Some(f) = v.get("b_factor").and_then(Value::as_f64) {
        spec = spec.b_factor(f);
    }
    if let Some(o) = v.get("optimization").and_then(Value::as_str) {
        spec = spec.optimization(
            Optimization::parse(o).ok_or_else(|| anyhow!("unknown optimization {o:?}"))?,
        );
    }
    if let Some(n) = v.get("input_bytes").and_then(Value::as_f64) {
        spec.input_bytes = n as u64;
    }
    if let Some(n) = v.get("output_bytes").and_then(Value::as_f64) {
        spec.output_bytes = n as u64;
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_scenario() {
        let text = r#"{
            "seed": 7,
            "advisor": "native",
            "network": {"type": "baud", "rate": 19200, "latency": 0.5},
            "resources": [
                {"name": "A", "pes": 4, "mips": 500, "policy": "time", "price": 2.0},
                {"name": "B", "machines": 8, "pes_per_machine": 1, "mips": 400,
                 "policy": "space-backfill", "price": 1.0}
            ],
            "users": [
                {"gridlets": 50, "length_mi": 5000, "deadline": 1000,
                 "budget": 9000, "optimization": "cost-time"}
            ]
        }"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.resources.len(), 2);
        assert_eq!(s.resources[1].machines, 8);
        assert!(!s.resources[1].policy.is_time_shared());
        assert_eq!(s.users.len(), 1);
        assert_eq!(s.users[0].num_gridlets, 50);
        assert_eq!(s.users[0].optimization, Optimization::CostTime);
        assert_eq!(
            s.network,
            NetworkSpec::Baud { default_rate: 19200.0, latency: 0.5 }
        );
    }

    #[test]
    fn wwg_testbed_shortcut() {
        let text = r#"{"testbed": "wwg", "users": [{"gridlets": 10}]}"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.resources.len(), 11);
    }

    #[test]
    fn d_b_factors() {
        let text = r#"{"testbed": "wwg",
            "users": [{"gridlets": 10, "d_factor": 0.5, "b_factor": 0.25}]}"#;
        let s = parse_scenario(text).unwrap();
        assert_eq!(s.users[0].deadline, crate::broker::DeadlineSpec::Factor(0.5));
        assert_eq!(s.users[0].budget, crate::broker::BudgetSpec::Factor(0.25));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_scenario("{").is_err());
        assert!(parse_scenario(r#"{"users": []}"#).is_err());
        assert!(parse_scenario(r#"{"testbed": "unknown", "users": [{}]}"#).is_err());
        assert!(parse_scenario(
            r#"{"resources": [{"name": "A", "mips": 1, "price": 1, "policy": "bogus"}],
                "users": [{}]}"#
        )
        .is_err());
    }
}
