//! Scenario configuration: the built-in WWG testbed of Table 2 and a JSON
//! scenario loader for user-defined grids.

pub mod scenario_file;
pub mod testbed;
