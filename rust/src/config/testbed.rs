//! The simulated World-Wide Grid testbed — paper Table 2, verbatim:
//! 11 resources modeled after real WWG hosts with SPEC CPU (INT) 2000
//! ratings as MIPS and G$ prices per PE-time-unit.

use crate::gridsim::{AllocPolicy, SpacePolicy};
use crate::scenario::ResourceSpec;

/// One Table 2 row.
struct Row {
    name: &'static str,
    arch: &'static str,
    os: &'static str,
    pes: usize,
    mips: f64,
    time_shared: bool,
    price: f64,
    /// Time zone of the real host's location (hours from UTC; drives the
    /// local-load calendar when enabled).
    time_zone: f64,
}

const ROWS: &[Row] = &[
    Row { name: "R0", arch: "Compaq AlphaServer", os: "OSF1", pes: 4, mips: 515.0, time_shared: true, price: 8.0, time_zone: 10.0 },   // VPAC Melbourne
    Row { name: "R1", arch: "Sun Ultra", os: "Solaris", pes: 4, mips: 377.0, time_shared: true, price: 4.0, time_zone: 9.0 },          // AIST Tokyo
    Row { name: "R2", arch: "Sun Ultra", os: "Solaris", pes: 4, mips: 377.0, time_shared: true, price: 3.0, time_zone: 9.0 },          // AIST Tokyo
    Row { name: "R3", arch: "Sun Ultra", os: "Solaris", pes: 2, mips: 377.0, time_shared: true, price: 3.0, time_zone: 9.0 },          // AIST Tokyo
    Row { name: "R4", arch: "Intel Pentium/VC820", os: "Linux", pes: 2, mips: 380.0, time_shared: true, price: 2.0, time_zone: 1.0 },  // CNR Pisa
    Row { name: "R5", arch: "SGI Origin 3200", os: "IRIX", pes: 6, mips: 410.0, time_shared: true, price: 5.0, time_zone: 1.0 },       // ZIB Berlin
    Row { name: "R6", arch: "SGI Origin 3200", os: "IRIX", pes: 16, mips: 410.0, time_shared: true, price: 5.0, time_zone: 1.0 },      // ZIB Berlin
    Row { name: "R7", arch: "SGI Origin 3200", os: "IRIX", pes: 16, mips: 410.0, time_shared: false, price: 4.0, time_zone: 1.0 },     // Charles U. Prague
    Row { name: "R8", arch: "Intel Pentium/VC820", os: "Linux", pes: 2, mips: 380.0, time_shared: true, price: 1.0, time_zone: 0.0 },  // Portsmouth UK
    Row { name: "R9", arch: "SGI Origin 3200", os: "IRIX", pes: 4, mips: 410.0, time_shared: true, price: 6.0, time_zone: 0.0 },       // Manchester UK
    Row { name: "R10", arch: "Sun Ultra", os: "Solaris", pes: 8, mips: 377.0, time_shared: true, price: 3.0, time_zone: -6.0 },        // ANL Chicago
];

/// The 11-resource WWG testbed of Table 2. The single space-shared resource
/// (R7, the Prague Origin 3200 behind a queueing system) is modeled as a
/// cluster of uniprocessor nodes under FCFS.
pub fn wwg_testbed() -> Vec<ResourceSpec> {
    ROWS.iter()
        .map(|row| {
            let (machines, pes_per_machine, policy) = if row.time_shared {
                (1, row.pes, AllocPolicy::TimeShared)
            } else {
                (row.pes, 1, AllocPolicy::SpaceShared(SpacePolicy::Fcfs))
            };
            ResourceSpec {
                name: row.name.into(),
                arch: row.arch.into(),
                os: row.os.into(),
                machines,
                pes_per_machine,
                mips_per_pe: row.mips,
                policy,
                price: row.price,
                time_zone: row.time_zone,
                calendar: None,
            }
        })
        .collect()
}

/// Table 2's "MIPS per G$" column, for the `table2` report.
pub fn mips_per_dollar(spec: &ResourceSpec) -> f64 {
    spec.mips_per_pe / spec.price
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eleven_resources() {
        let tb = wwg_testbed();
        assert_eq!(tb.len(), 11);
    }

    #[test]
    fn table2_mips_per_dollar_column() {
        // Spot-check the published MIPS/G$ values.
        let tb = wwg_testbed();
        let by_name = |n: &str| tb.iter().find(|r| r.name == n).unwrap();
        assert!((mips_per_dollar(by_name("R0")) - 64.375).abs() < 0.01); // paper: 64.37
        assert!((mips_per_dollar(by_name("R1")) - 94.25).abs() < 0.01);
        assert!((mips_per_dollar(by_name("R2")) - 125.66).abs() < 0.01);
        assert!((mips_per_dollar(by_name("R4")) - 190.0).abs() < 0.01);
        assert!((mips_per_dollar(by_name("R7")) - 102.5).abs() < 0.01);
        assert!((mips_per_dollar(by_name("R8")) - 380.0).abs() < 0.01);
        assert!((mips_per_dollar(by_name("R9")) - 68.33).abs() < 0.01);
    }

    #[test]
    fn r8_is_cheapest_per_mi() {
        let tb = wwg_testbed();
        let r8 = tb.iter().find(|r| r.name == "R8").unwrap();
        let c8 = r8.price / r8.mips_per_pe;
        for r in &tb {
            let c = r.price / r.mips_per_pe;
            assert!(c >= c8, "{} beats R8", r.name);
        }
    }

    #[test]
    fn only_r7_space_shared() {
        let tb = wwg_testbed();
        for r in &tb {
            let expect_space = r.name == "R7";
            assert_eq!(!r.policy.is_time_shared(), expect_space, "{}", r.name);
        }
    }

    #[test]
    fn total_pe_count_matches_table() {
        // 4+4+4+2+2+6+16+16+2+4+8 = 68 PEs.
        let tb = wwg_testbed();
        let total: usize = tb.iter().map(|r| r.num_pe()).sum();
        assert_eq!(total, 68);
    }
}
