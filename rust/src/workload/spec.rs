//! `WorkloadSpec` — the first-class application model (paper §4.2.1: "users
//! and application models", with "primitives for creation of application
//! tasks").
//!
//! A workload is a *value* describing how a user's Gridlets come into
//! existence and when they are released to the broker:
//!
//! * [`WorkloadSpec::TaskFarm`] — the paper's §5.2 uniform task farm
//!   (`n` jobs of at least `base` MI with a 0–`variation` positive random
//!   spread). The default, and byte-identical to the historical
//!   `ExperimentSpec` task-farm fields.
//! * [`WorkloadSpec::HeavyTailed`] — mostly-uniform jobs with a fraction
//!   stretched by up to a multiplier (exercises SJF/backfilling and broker
//!   re-planning under heterogeneous job lengths).
//! * [`WorkloadSpec::Explicit`] — a literal job list.
//! * [`WorkloadSpec::Dag`] — a workflow: named jobs plus precedence edges,
//!   where a child is released only after every parent's Gridlet completes
//!   (see [`crate::workload::dag`]).
//! * [`WorkloadSpec::Trace`] — jobs replayed from a trace file (legacy
//!   4-column or full 18-column SWF, see [`crate::workload::trace`]),
//!   optionally sliced by a [`TraceSelector`] (e.g. one SWF `user_id`'s jobs
//!   per simulated user); jobs with `submit_time > 0` arrive online. The
//!   job list is an immutable `Arc<[TraceJob]>`: cloning a spec — a second
//!   user on the same log, every cell of a sweep — shares one loaded log
//!   instead of copying it, and per-spec variation (selector, staging)
//!   applies copy-on-write at materialization.
//! * [`WorkloadSpec::Concat`] — parts replayed side by side as one
//!   workload: job lists are appended (ids in part order), release offsets
//!   kept.
//! * [`WorkloadSpec::Mix`] — like `Concat`, but the combined dispatch order
//!   is a weight-biased, seed-stable random interleave — the declarative way
//!   to blend e.g. a heavy-tailed batch with a trace replay.
//! * [`WorkloadSpec::OnlineArrivals`] — any of the above with release times
//!   reassigned by a Poisson, fixed-interval, or rate-modulated
//!   [`ArrivalProcess`] (Nimrod/G-style parameter-sweep jobs streaming in
//!   over time; [`ArrivalProcess::Modulated`] models day/night cycles).
//!
//! [`WorkloadSpec::materialize`] turns the spec into a deterministic list of
//! [`Release`]s (offset from submission + Gridlet) using the caller's seeded
//! [`GridSimRandom`]; releases at offset 0 form the experiment's initial
//! batch and later ones are streamed to the broker as `GRIDLET_ARRIVAL`
//! events by the user entity.

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::random::GridSimRandom;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::Arc;

use super::dag;
pub use super::dag::DagNode;
pub use super::trace::TraceSelector;

/// One job of an [`WorkloadSpec::Explicit`] workload.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Processing requirement in MI.
    pub length_mi: f64,
    /// Input staging size in bytes.
    pub input_bytes: u64,
    /// Output staging size in bytes.
    pub output_bytes: u64,
}

/// One job of an [`WorkloadSpec::Trace`] workload: a job shape plus the
/// submission offset (simulation time units after the experiment starts)
/// and, for jobs derived from an 18-column SWF log, the originating
/// `user_id`/`partition` (what a [`TraceSelector`] filters on).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    /// Release offset from experiment submission (0 = initial batch).
    pub submit_time: f64,
    /// Processing requirement in MI.
    pub length_mi: f64,
    /// Input staging size in bytes.
    pub input_bytes: u64,
    /// Output staging size in bytes.
    pub output_bytes: u64,
    /// SWF `user_id` the job came from (`None` for legacy 4-column jobs).
    pub user: Option<i64>,
    /// SWF `partition` the job ran in (`None` for legacy 4-column jobs).
    pub partition: Option<i64>,
}

impl TraceJob {
    /// A metadata-free trace job (the legacy 4-column shape).
    pub fn new(submit_time: f64, length_mi: f64, input_bytes: u64, output_bytes: u64) -> TraceJob {
        TraceJob { submit_time, length_mi, input_bytes, output_bytes, user: None, partition: None }
    }
}

/// Rate envelope for [`ArrivalProcess::Modulated`]: a periodic multiplier
/// `e(t) ≥ 0` applied to the base Poisson rate `1/mean_interarrival`, so
/// the instantaneous rate is `λ(t) = e(t)/mean_interarrival`.
#[derive(Debug, Clone, PartialEq)]
pub enum RateEnvelope {
    /// Piecewise-constant multipliers over equal segments of one `period`,
    /// cycled forever: `rates[i]` applies on
    /// `t mod period ∈ [i·period/n, (i+1)·period/n)`. A two-segment
    /// `rates: [1.0, 0.1]` is a day/night cycle; a zero segment shuts
    /// arrivals off entirely during it.
    Piecewise {
        /// Cycle length in simulation time units.
        period: f64,
        /// Per-segment rate multipliers (`≥ 0`, at least one `> 0`).
        rates: Vec<f64>,
    },
    /// Smooth diurnal modulation `e(t) = 1 + amplitude·sin(2πt/period)`
    /// with `amplitude ∈ [0, 1]`.
    Sinusoid {
        /// Cycle length in simulation time units.
        period: f64,
        /// Modulation depth in `[0, 1]` (0 = plain Poisson).
        amplitude: f64,
    },
}

impl RateEnvelope {
    /// The multiplier at time `t` (periodic).
    pub fn multiplier(&self, t: f64) -> f64 {
        match self {
            RateEnvelope::Piecewise { period, rates } => {
                let phase = t.rem_euclid(*period) / period;
                let idx = ((phase * rates.len() as f64) as usize).min(rates.len() - 1);
                rates[idx]
            }
            RateEnvelope::Sinusoid { period, amplitude } => {
                1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period).sin()
            }
        }
    }

    /// The envelope's maximum multiplier (the thinning majorant).
    pub fn max_multiplier(&self) -> f64 {
        match self {
            RateEnvelope::Piecewise { rates, .. } => {
                rates.iter().copied().fold(0.0, f64::max)
            }
            RateEnvelope::Sinusoid { amplitude, .. } => 1.0 + amplitude,
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            RateEnvelope::Piecewise { period, rates } => {
                if *period <= 0.0 || !period.is_finite() {
                    bail!("modulated arrivals need period > 0, got {period}");
                }
                if rates.is_empty() {
                    bail!("modulated arrivals need at least one envelope rate");
                }
                if let Some(r) = rates.iter().find(|r| !r.is_finite() || **r < 0.0) {
                    bail!("envelope rates must be finite and >= 0, got {r}");
                }
                if rates.iter().all(|&r| r == 0.0) {
                    bail!("envelope rates are all 0 — no job could ever arrive");
                }
            }
            RateEnvelope::Sinusoid { period, amplitude } => {
                if *period <= 0.0 || !period.is_finite() {
                    bail!("modulated arrivals need period > 0, got {period}");
                }
                if !(0.0..=1.0).contains(amplitude) {
                    bail!("sinusoid amplitude must be in [0, 1], got {amplitude}");
                }
            }
        }
        Ok(())
    }
}

/// When online jobs are released to the broker, relative to experiment
/// submission.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process: exponential inter-arrival gaps with the given mean
    /// (the promoted `poisson_arrivals` helper). The first job arrives after
    /// the first gap.
    Poisson {
        /// Mean inter-arrival gap.
        mean_interarrival: f64,
    },
    /// Fixed-interval release: job `i` arrives at `i × interval` (the first
    /// job is part of the initial batch).
    Fixed {
        /// Gap between consecutive releases.
        interval: f64,
    },
    /// Non-homogeneous Poisson process: a base rate `1/mean_interarrival`
    /// shaped by a periodic [`RateEnvelope`] (day/night cycles). Sampled by
    /// Lewis–Shedler thinning of the constant-rate majorant
    /// `max_multiplier/mean_interarrival`, so offsets are a pure function of
    /// the RNG stream — the determinism and common-random-numbers sweep
    /// guarantees hold exactly as for [`ArrivalProcess::Poisson`].
    Modulated {
        /// Mean inter-arrival gap while the envelope multiplier is 1.
        mean_interarrival: f64,
        /// The periodic rate modulation.
        envelope: RateEnvelope,
    },
}

impl ArrivalProcess {
    /// Release offsets for `n` jobs, drawn from `rng` (Poisson/modulated) or
    /// computed (fixed). Monotonically non-decreasing.
    pub fn offsets(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { mean_interarrival } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(*mean_interarrival);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Fixed { interval } => (0..n).map(|i| i as f64 * interval).collect(),
            ArrivalProcess::Modulated { mean_interarrival, envelope } => {
                // Thinning: candidates from the constant majorant rate
                // e_max/mean, each accepted with probability e(t)/e_max.
                // Every candidate consumes exactly one exponential draw and
                // one uniform draw, so the offsets depend only on the RNG
                // stream, never on wall-clock or evaluation order.
                let e_max = envelope.max_multiplier();
                // Hard assert (not debug): with e_max = 0 no candidate can
                // ever be accepted and this loop would hang a release build.
                // validate() reports the same condition as a readable error.
                assert!(e_max > 0.0, "modulated arrivals: envelope rates are all 0");
                let mut t = 0.0;
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    t += rng.exponential(*mean_interarrival / e_max);
                    if rng.next_f64() * e_max < envelope.multiplier(t) {
                        out.push(t);
                    }
                }
                out
            }
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            ArrivalProcess::Poisson { mean_interarrival } => {
                if *mean_interarrival <= 0.0 || mean_interarrival.is_nan() {
                    bail!("poisson arrivals need mean_interarrival > 0, got {mean_interarrival}");
                }
            }
            ArrivalProcess::Fixed { interval } => {
                if *interval < 0.0 || interval.is_nan() {
                    bail!("fixed arrivals need interval >= 0, got {interval}");
                }
            }
            ArrivalProcess::Modulated { mean_interarrival, envelope } => {
                if *mean_interarrival <= 0.0 || mean_interarrival.is_nan() {
                    bail!(
                        "modulated arrivals need mean_interarrival > 0, got {mean_interarrival}"
                    );
                }
                envelope.validate()?;
            }
        }
        Ok(())
    }
}

/// One materialized job release: the Gridlet plus its release offset from
/// experiment submission (0 = part of the initial batch) and, for workflow
/// jobs, the Gridlet ids of its precedence parents.
#[derive(Debug, Clone)]
pub struct Release {
    /// Release offset from experiment submission.
    pub offset: f64,
    /// Gridlet ids (within the same materialized workload) that must all
    /// complete before this job may be released. Empty for every non-DAG
    /// workload; the user entity withholds a non-empty-parents release —
    /// regardless of `offset` — until the broker reports the last parent
    /// complete.
    pub parents: Vec<usize>,
    /// The job released at that offset.
    pub gridlet: Gridlet,
}

/// Declarative application model — how a user's Gridlets are generated and
/// when they are released. See the module docs for the variants.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// Paper §5.2: `num_gridlets` jobs of `base_length_mi` MI with a
    /// 0–`length_variation` positive random variation.
    TaskFarm {
        /// Number of jobs.
        num_gridlets: usize,
        /// Minimum job length in MI.
        base_length_mi: f64,
        /// Upper bound of the positive random spread, as a fraction of
        /// `base_length_mi` (in `[0, 1]`).
        length_variation: f64,
        /// Input staging size per job, bytes.
        input_bytes: u64,
        /// Output staging size per job, bytes.
        output_bytes: u64,
    },
    /// Most jobs within ±10% of `base_length_mi`; a `heavy_fraction` of them
    /// stretched by up to `heavy_multiplier`×.
    HeavyTailed {
        /// Number of jobs.
        num_gridlets: usize,
        /// Central job length in MI.
        base_length_mi: f64,
        /// Fraction of jobs stretched (in `[0, 1]`).
        heavy_fraction: f64,
        /// Maximum stretch factor (`>= 1`).
        heavy_multiplier: f64,
        /// Input staging size per job, bytes.
        input_bytes: u64,
        /// Output staging size per job, bytes.
        output_bytes: u64,
    },
    /// A literal job list, released as one batch.
    Explicit {
        /// The jobs, in dispatch order.
        jobs: Vec<JobSpec>,
    },
    /// A workflow: a directed acyclic graph of jobs where a child becomes
    /// eligible only once every parent's Gridlet completes. Materialization
    /// assigns ids in descending HEFT upward-rank order and fills
    /// [`Release::parents`]; the user entity withholds children until the
    /// broker reports their parents complete (see [`crate::workload::dag`]).
    Dag {
        /// Workflow nodes (jobs), addressed by id.
        nodes: Vec<DagNode>,
        /// Precedence edges as `(parent id, child id)` pairs.
        edges: Vec<(String, String)>,
    },
    /// Trace replay (legacy 4-column or SWF-derived): each job carries its
    /// own submission offset, and `selector` picks the replayed slice
    /// (e.g. one SWF user's jobs). `declared_jobs` and `materialize` both
    /// see the *selected* jobs only.
    ///
    /// The job list is `Arc`-shared and **immutable**: cloning the spec (a
    /// second `UserSpec` on the same log, a sweep cell's scenario clone)
    /// clones the `Arc`, never the jobs — one loaded 10^5-record SWF log is
    /// a single allocation no matter how many users and cells replay it.
    /// Nothing may mutate a `TraceJob` after it enters the `Arc`; per-spec
    /// variation goes through the value-typed `selector` and `staging`
    /// fields instead (copy-on-write at materialization time).
    Trace {
        /// The full job list as loaded from the trace file, shared across
        /// every clone of this spec.
        jobs: Arc<[TraceJob]>,
        /// The slice of `jobs` this workload replays
        /// ([`TraceSelector::all`] = everything).
        selector: TraceSelector,
        /// Staging-size override `(input_bytes, output_bytes)` applied at
        /// materialization time ([`WorkloadSpec::with_staging`]). `None`
        /// keeps each job's own sizes. This is what lets `set_staging`
        /// leave the shared job list untouched.
        staging: Option<(u64, u64)>,
    },
    /// Composition: the parts' job lists appended into one workload — ids in
    /// part order, each job keeping its own release offset. Two batch parts
    /// become one larger batch; two traces become a merged replay.
    Concat {
        /// The composed workloads, in order.
        parts: Vec<WorkloadSpec>,
    },
    /// Composition with a weight-biased, seed-stable random interleave:
    /// every part contributes all of its jobs, but the combined generation
    /// order (which sets Gridlet ids, i.e. dispatch order among
    /// equal-offset jobs) is drawn by repeatedly picking a non-exhausted
    /// part with probability proportional to its weight. Offsets are kept,
    /// exactly as in [`WorkloadSpec::Concat`].
    Mix {
        /// The composed workloads.
        parts: Vec<WorkloadSpec>,
        /// Relative interleave weights, one per part (`> 0`).
        weights: Vec<f64>,
    },
    /// A generative wrapper: `workload`'s jobs with release times reassigned
    /// by `arrivals` (nesting another `OnlineArrivals` is rejected).
    OnlineArrivals {
        /// The workload whose jobs are re-timed.
        workload: Box<WorkloadSpec>,
        /// The arrival process assigning release offsets.
        arrivals: ArrivalProcess,
    },
}

impl WorkloadSpec {
    /// The paper's §5.2 task farm with its staging sizes (1000 B in, 500 B
    /// out).
    pub fn task_farm(n: usize, base_mi: f64, variation: f64) -> WorkloadSpec {
        WorkloadSpec::TaskFarm {
            num_gridlets: n,
            base_length_mi: base_mi,
            length_variation: variation,
            input_bytes: 1000,
            output_bytes: 500,
        }
    }

    /// A heavy-tailed farm with the paper's staging sizes.
    pub fn heavy_tailed(n: usize, base_mi: f64, fraction: f64, multiplier: f64) -> WorkloadSpec {
        WorkloadSpec::HeavyTailed {
            num_gridlets: n,
            base_length_mi: base_mi,
            heavy_fraction: fraction,
            heavy_multiplier: multiplier,
            input_bytes: 1000,
            output_bytes: 500,
        }
    }

    /// A literal job list.
    pub fn explicit(jobs: Vec<JobSpec>) -> WorkloadSpec {
        WorkloadSpec::Explicit { jobs }
    }

    /// A workflow over `nodes` with `(parent, child)` precedence `edges`
    /// (see [`WorkloadSpec::Dag`]). Like every constructor this does not
    /// validate — [`WorkloadSpec::validate`] rejects cycles, duplicate ids,
    /// and dangling edges.
    pub fn dag(nodes: Vec<DagNode>, edges: Vec<(String, String)>) -> WorkloadSpec {
        WorkloadSpec::Dag { nodes, edges }
    }

    /// A trace replay of every job in `jobs`.
    pub fn trace(jobs: Vec<TraceJob>) -> WorkloadSpec {
        WorkloadSpec::trace_shared(jobs.into())
    }

    /// A trace replay of the slice `selector` keeps of `jobs`.
    pub fn trace_selected(jobs: Vec<TraceJob>, selector: TraceSelector) -> WorkloadSpec {
        WorkloadSpec::trace_selected_shared(jobs.into(), selector)
    }

    /// A trace replay over an already-shared job list: the spec holds a
    /// clone of the `Arc`, so many users (and every sweep cell) reference
    /// one loaded log instead of copying it.
    pub fn trace_shared(jobs: Arc<[TraceJob]>) -> WorkloadSpec {
        WorkloadSpec::Trace { jobs, selector: TraceSelector::all(), staging: None }
    }

    /// [`WorkloadSpec::trace_shared`] replaying only the slice `selector`
    /// keeps — the per-user split of one shared log.
    pub fn trace_selected_shared(jobs: Arc<[TraceJob]>, selector: TraceSelector) -> WorkloadSpec {
        WorkloadSpec::Trace { jobs, selector, staging: None }
    }

    /// Append `parts` into one workload (see [`WorkloadSpec::Concat`]).
    pub fn concat(parts: Vec<WorkloadSpec>) -> WorkloadSpec {
        WorkloadSpec::Concat { parts }
    }

    /// Interleave `parts` with equal weights (see [`WorkloadSpec::Mix`]).
    pub fn mix(parts: Vec<WorkloadSpec>) -> WorkloadSpec {
        let weights = vec![1.0; parts.len()];
        WorkloadSpec::Mix { parts, weights }
    }

    /// Interleave `parts` with explicit weights (one per part, `> 0`).
    pub fn mix_weighted(parts: Vec<WorkloadSpec>, weights: Vec<f64>) -> WorkloadSpec {
        WorkloadSpec::Mix { parts, weights }
    }

    /// Wrap `workload` with an online arrival process.
    ///
    /// Panics when `workload` already carries an arrival process — directly
    /// or inside a `concat`/`mix` part (one arrival process per workload:
    /// the wrapper reassigns *every* offset, so an inner process would be
    /// silently discarded; the JSON loader rejects this too).
    pub fn online(workload: WorkloadSpec, arrivals: ArrivalProcess) -> WorkloadSpec {
        assert!(
            !workload.has_arrival_process(),
            "online_arrivals cannot wrap another online_arrivals"
        );
        assert!(
            !workload.has_dag(),
            "online_arrivals cannot wrap a dag workload (precedence, not an \
             arrival process, times its releases)"
        );
        WorkloadSpec::OnlineArrivals { workload: Box::new(workload), arrivals }
    }

    /// Override the staging sizes on every job of the workload.
    pub fn with_staging(mut self, input: u64, output: u64) -> WorkloadSpec {
        self.set_staging(input, output);
        self
    }

    fn set_staging(&mut self, input: u64, output: u64) {
        match self {
            WorkloadSpec::TaskFarm { input_bytes, output_bytes, .. }
            | WorkloadSpec::HeavyTailed { input_bytes, output_bytes, .. } => {
                *input_bytes = input;
                *output_bytes = output;
            }
            WorkloadSpec::Explicit { jobs } => {
                for j in jobs {
                    j.input_bytes = input;
                    j.output_bytes = output;
                }
            }
            WorkloadSpec::Dag { nodes, .. } => {
                for n in nodes {
                    n.input_bytes = input;
                    n.output_bytes = output;
                }
            }
            // The shared job list is immutable; record the override and
            // apply it copy-on-write when materializing (same observable
            // Gridlets as the historical in-place mutation — pinned by
            // `staging_override_is_copy_on_write`).
            WorkloadSpec::Trace { staging, .. } => *staging = Some((input, output)),
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                for p in parts {
                    p.set_staging(input, output);
                }
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.set_staging(input, output),
        }
    }

    /// Number of jobs the workload declares (independent of release times;
    /// for traces, the *selected* slice).
    pub fn declared_jobs(&self) -> usize {
        match self {
            WorkloadSpec::TaskFarm { num_gridlets, .. }
            | WorkloadSpec::HeavyTailed { num_gridlets, .. } => *num_gridlets,
            WorkloadSpec::Explicit { jobs } => jobs.len(),
            WorkloadSpec::Dag { nodes, .. } => nodes.len(),
            WorkloadSpec::Trace { jobs, selector, .. } => selector.count(jobs),
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().map(WorkloadSpec::declared_jobs).sum()
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.declared_jobs(),
        }
    }

    /// Does any job arrive after submission (trace offsets or an arrival
    /// process)?
    pub fn is_online(&self) -> bool {
        match self {
            WorkloadSpec::Trace { jobs, selector, .. } => {
                selector.selected(jobs).any(|j| j.submit_time > 0.0)
            }
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().any(WorkloadSpec::is_online)
            }
            WorkloadSpec::OnlineArrivals { .. } => true,
            _ => false,
        }
    }

    /// Is there an [`ArrivalProcess`] anywhere in the spec (sweepable via
    /// the `mean_interarrivals` axis)?
    pub fn has_arrival_process(&self) -> bool {
        match self {
            WorkloadSpec::OnlineArrivals { .. } => true,
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().any(WorkloadSpec::has_arrival_process)
            }
            _ => false,
        }
    }

    /// Is there a [`WorkloadSpec::Dag`] anywhere in the spec? When true,
    /// materialized releases may carry [`Release::parents`], the user
    /// entity gates them on completion notices, and the experiment asks
    /// the broker to send those notices.
    pub fn has_dag(&self) -> bool {
        match self {
            WorkloadSpec::Dag { .. } => true,
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().any(WorkloadSpec::has_dag)
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.has_dag(),
            _ => false,
        }
    }

    /// Is there a heavy-tailed generator anywhere in the spec (sweepable via
    /// the `heavy_fractions` axis)?
    pub fn has_heavy_tail(&self) -> bool {
        match self {
            WorkloadSpec::HeavyTailed { .. } => true,
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().any(WorkloadSpec::has_heavy_tail)
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.has_heavy_tail(),
            _ => false,
        }
    }

    /// Is there a trace replay anywhere in the spec (sweepable via the
    /// `trace_selectors` axis)?
    pub fn has_trace(&self) -> bool {
        match self {
            WorkloadSpec::Trace { .. } => true,
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                parts.iter().any(WorkloadSpec::has_trace)
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.has_trace(),
            _ => false,
        }
    }

    /// Is there a [`WorkloadSpec::Mix`] with exactly `arity` parts anywhere
    /// in the spec (what a `mix_weights` sweep entry of that length can
    /// retarget)?
    pub fn has_mix_of(&self, arity: usize) -> bool {
        match self {
            WorkloadSpec::Mix { parts, .. } => {
                parts.len() == arity || parts.iter().any(|p| p.has_mix_of(arity))
            }
            WorkloadSpec::Concat { parts } => parts.iter().any(|p| p.has_mix_of(arity)),
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.has_mix_of(arity),
            _ => false,
        }
    }

    /// Override the arrival process's mean inter-arrival (Poisson/modulated
    /// mean or fixed interval), everywhere one exists. Returns whether
    /// anything was changed.
    pub fn set_arrival_mean(&mut self, mean: f64) -> bool {
        match self {
            WorkloadSpec::OnlineArrivals { arrivals, .. } => {
                match arrivals {
                    ArrivalProcess::Poisson { mean_interarrival }
                    | ArrivalProcess::Modulated { mean_interarrival, .. } => {
                        *mean_interarrival = mean
                    }
                    ArrivalProcess::Fixed { interval } => *interval = mean,
                }
                true
            }
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                let mut changed = false;
                for p in parts {
                    changed |= p.set_arrival_mean(mean);
                }
                changed
            }
            _ => false,
        }
    }

    /// Override the heavy-tail fraction, everywhere a heavy-tailed generator
    /// exists. Returns whether anything was changed.
    pub fn set_heavy_fraction(&mut self, fraction: f64) -> bool {
        match self {
            WorkloadSpec::HeavyTailed { heavy_fraction, .. } => {
                *heavy_fraction = fraction;
                true
            }
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                let mut changed = false;
                for p in parts {
                    changed |= p.set_heavy_fraction(fraction);
                }
                changed
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => {
                workload.set_heavy_fraction(fraction)
            }
            _ => false,
        }
    }

    /// Validate `selector` against every trace replay in the spec without
    /// mutating or cloning anything — what the `trace_selectors` sweep axis
    /// checks up front. Returns whether the spec holds any trace at all.
    pub fn check_trace_selector(&self, selector: &TraceSelector) -> Result<bool> {
        match self {
            WorkloadSpec::Trace { jobs, .. } => selector.validate(jobs).map(|()| true),
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                let mut any = false;
                for p in parts {
                    any |= p.check_trace_selector(selector)?;
                }
                Ok(any)
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => {
                workload.check_trace_selector(selector)
            }
            _ => Ok(false),
        }
    }

    /// Override the [`TraceSelector`] of every trace replay in the spec.
    /// Returns whether anything was changed.
    pub fn set_trace_selector(&mut self, selector: &TraceSelector) -> bool {
        match self {
            WorkloadSpec::Trace { selector: s, .. } => {
                *s = selector.clone();
                true
            }
            WorkloadSpec::Concat { parts } | WorkloadSpec::Mix { parts, .. } => {
                let mut changed = false;
                for p in parts {
                    changed |= p.set_trace_selector(selector);
                }
                changed
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => {
                workload.set_trace_selector(selector)
            }
            _ => false,
        }
    }

    /// Override the interleave weights of every [`WorkloadSpec::Mix`] whose
    /// part count matches `weights.len()`. Returns whether anything was
    /// changed.
    pub fn set_mix_weights(&mut self, weights: &[f64]) -> bool {
        match self {
            WorkloadSpec::Mix { parts, weights: w } => {
                let mut changed = false;
                if parts.len() == weights.len() {
                    *w = weights.to_vec();
                    changed = true;
                }
                for p in parts {
                    changed |= p.set_mix_weights(weights);
                }
                changed
            }
            WorkloadSpec::Concat { parts } => {
                let mut changed = false;
                for p in parts {
                    changed |= p.set_mix_weights(weights);
                }
                changed
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.set_mix_weights(weights),
            _ => false,
        }
    }

    /// Short label for reports and error messages.
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadSpec::TaskFarm { .. } => "task_farm",
            WorkloadSpec::HeavyTailed { .. } => "heavy_tailed",
            WorkloadSpec::Explicit { .. } => "explicit",
            WorkloadSpec::Dag { .. } => "dag",
            WorkloadSpec::Trace { .. } => "trace",
            WorkloadSpec::Concat { .. } => "concat",
            WorkloadSpec::Mix { .. } => "mix",
            WorkloadSpec::OnlineArrivals { .. } => "online_arrivals",
        }
    }

    /// Reject impossible parameters with a readable error (the JSON loader
    /// and sweep validation call this; `materialize` asserts as a backstop).
    pub fn validate(&self) -> Result<()> {
        match self {
            WorkloadSpec::TaskFarm { base_length_mi, length_variation, .. } => {
                if *base_length_mi <= 0.0 || base_length_mi.is_nan() {
                    bail!("task_farm: length_mi must be > 0, got {base_length_mi}");
                }
                if !(0.0..=1.0).contains(length_variation) {
                    bail!("task_farm: variation must be in [0, 1], got {length_variation}");
                }
            }
            WorkloadSpec::HeavyTailed {
                base_length_mi, heavy_fraction, heavy_multiplier, ..
            } => {
                if *base_length_mi <= 0.0 || base_length_mi.is_nan() {
                    bail!("heavy_tailed: length_mi must be > 0, got {base_length_mi}");
                }
                if !(0.0..=1.0).contains(heavy_fraction) {
                    bail!("heavy_tailed: heavy_fraction must be in [0, 1], got {heavy_fraction}");
                }
                if *heavy_multiplier < 1.0 || heavy_multiplier.is_nan() {
                    bail!("heavy_tailed: heavy_multiplier must be >= 1, got {heavy_multiplier}");
                }
            }
            WorkloadSpec::Explicit { jobs } => {
                for (i, j) in jobs.iter().enumerate() {
                    if j.length_mi <= 0.0 || j.length_mi.is_nan() {
                        bail!("explicit job #{i}: length_mi must be > 0, got {}", j.length_mi);
                    }
                }
            }
            WorkloadSpec::Dag { nodes, edges } => dag::validate_dag(nodes, edges)?,
            WorkloadSpec::Trace { jobs, selector, .. } => {
                for (i, j) in jobs.iter().enumerate() {
                    if j.length_mi <= 0.0 || j.length_mi.is_nan() {
                        bail!("trace job #{i}: length_mi must be > 0, got {}", j.length_mi);
                    }
                    if j.submit_time < 0.0 || j.submit_time.is_nan() {
                        bail!("trace job #{i}: submit_time must be >= 0, got {}", j.submit_time);
                    }
                }
                selector.validate(jobs)?;
            }
            WorkloadSpec::Concat { parts } => {
                if parts.is_empty() {
                    bail!("concat: needs at least one part");
                }
                for (i, p) in parts.iter().enumerate() {
                    p.validate().map_err(|e| e.context(format!("concat part #{i}")))?;
                }
            }
            WorkloadSpec::Mix { parts, weights } => {
                if parts.is_empty() {
                    bail!("mix: needs at least one part");
                }
                if weights.len() != parts.len() {
                    bail!(
                        "mix: {} weights for {} parts (one weight per part)",
                        weights.len(),
                        parts.len()
                    );
                }
                if let Some(w) = weights.iter().find(|w| !w.is_finite() || **w <= 0.0) {
                    bail!("mix: weights must be finite and > 0, got {w}");
                }
                for (i, p) in parts.iter().enumerate() {
                    p.validate().map_err(|e| e.context(format!("mix part #{i}")))?;
                }
            }
            WorkloadSpec::OnlineArrivals { workload, arrivals } => {
                // Recursive on purpose: an inner process hidden in a
                // concat/mix part would be consumed from the RNG stream and
                // then thrown away when this wrapper reassigns offsets.
                if workload.has_arrival_process() {
                    bail!(
                        "online_arrivals cannot wrap another online_arrivals \
                         (found one inside the wrapped workload)"
                    );
                }
                // Equally recursive: a workflow's releases are timed by
                // precedence, so reassigned offsets would fight the gating.
                if workload.has_dag() {
                    bail!(
                        "online_arrivals cannot wrap a dag workload \
                         (found one inside the wrapped workload)"
                    );
                }
                arrivals.validate()?;
                workload.validate()?;
            }
        }
        Ok(())
    }

    /// Materialize the workload into release order, drawing every random
    /// quantity from `rand`: two materializations with equally-seeded
    /// generators are bit-identical. Gridlet ids are assigned in generation
    /// order (0..n); the returned list is stably sorted by release offset.
    ///
    /// The `TaskFarm` draw sequence (`real(base, 0, variation)` per job) is
    /// the historical `ExperimentSpec::materialize` stream, so pre-existing
    /// scenarios reproduce bit-for-bit. Composite variants materialize their
    /// parts in order on the shared stream, then renumber ids 0..n across
    /// the combination (`Concat`: parts appended; `Mix`: one weighted draw
    /// per job decides which part contributes next), rewriting any DAG
    /// parent references to the combined ids. `Dag` draws nothing: ids
    /// follow descending upward rank (see [`crate::workload::dag`]).
    pub fn materialize(&self, rand: &mut GridSimRandom) -> Vec<Release> {
        let mut releases: Vec<Release> = match self {
            WorkloadSpec::TaskFarm {
                num_gridlets,
                base_length_mi,
                length_variation,
                input_bytes,
                output_bytes,
            } => (0..*num_gridlets)
                .map(|i| {
                    let len = rand.real(*base_length_mi, 0.0, *length_variation);
                    Release {
                        offset: 0.0,
                        parents: Vec::new(),
                        gridlet: Gridlet::new(i, len, *input_bytes, *output_bytes),
                    }
                })
                .collect(),
            WorkloadSpec::HeavyTailed {
                num_gridlets,
                base_length_mi,
                heavy_fraction,
                heavy_multiplier,
                input_bytes,
                output_bytes,
            } => {
                assert!((0.0..=1.0).contains(heavy_fraction));
                assert!(*heavy_multiplier >= 1.0);
                let rng = rand.rng();
                (0..*num_gridlets)
                    .map(|i| {
                        let mut len = base_length_mi * rng.uniform(0.9, 1.1);
                        if rng.next_f64() < *heavy_fraction {
                            len *= rng.uniform(1.0, *heavy_multiplier);
                        }
                        Release {
                            offset: 0.0,
                            parents: Vec::new(),
                            gridlet: Gridlet::new(i, len, *input_bytes, *output_bytes),
                        }
                    })
                    .collect()
            }
            WorkloadSpec::Explicit { jobs } => jobs
                .iter()
                .enumerate()
                .map(|(i, j)| Release {
                    offset: 0.0,
                    parents: Vec::new(),
                    gridlet: Gridlet::new(i, j.length_mi, j.input_bytes, j.output_bytes),
                })
                .collect(),
            WorkloadSpec::Dag { nodes, edges } => dag::materialize_dag(nodes, edges),
            WorkloadSpec::Trace { jobs, selector, staging } => selector
                .selected(jobs)
                .enumerate()
                .map(|(i, j)| {
                    // Copy-on-write staging: the shared log stays pristine;
                    // the override is applied to the materialized Gridlet.
                    let (input, output) =
                        staging.unwrap_or((j.input_bytes, j.output_bytes));
                    Release {
                        offset: j.submit_time,
                        parents: Vec::new(),
                        gridlet: Gridlet::new(i, j.length_mi, input, output),
                    }
                })
                .collect(),
            WorkloadSpec::Concat { parts } => {
                let mut all: Vec<Release> = Vec::with_capacity(self.declared_jobs());
                for part in parts {
                    // Each part's ids are contiguous 0..n in generation
                    // order, so renumbering is a fixed shift — which also
                    // remaps any DAG parent references within the part.
                    let base = all.len();
                    for mut r in part.materialize_generation_order(rand) {
                        r.gridlet.id = base + r.gridlet.id;
                        for p in &mut r.parents {
                            *p += base;
                        }
                        all.push(r);
                    }
                }
                all
            }
            WorkloadSpec::Mix { parts, weights } => {
                // Parts materialize in order on the shared stream; the
                // interleave then takes one uniform draw per job, always
                // over the *full* weight mass of the non-exhausted parts —
                // seed-stable and independent of float summation order.
                let mut queues: Vec<std::collections::VecDeque<Release>> = parts
                    .iter()
                    .map(|p| p.materialize_generation_order(rand).into())
                    .collect();
                let total: usize = queues.iter().map(|q| q.len()).sum();
                let mut all: Vec<Release> = Vec::with_capacity(total);
                // The interleave scatters each part's ids, so DAG parent
                // references can't be shifted in place like Concat's:
                // record each job's (part, old id) origin and rewrite
                // parents once the full renumbering is known.
                let mut origin: Vec<(usize, usize)> = Vec::with_capacity(total);
                let rng = rand.rng();
                while all.len() < total {
                    let mass: f64 = queues
                        .iter()
                        .zip(weights)
                        .filter(|(q, _)| !q.is_empty())
                        .map(|(_, w)| *w)
                        .sum();
                    let mut pick = rng.next_f64() * mass;
                    let mut chosen = None;
                    for (i, (q, w)) in queues.iter().zip(weights).enumerate() {
                        if q.is_empty() {
                            continue;
                        }
                        chosen = Some(i);
                        pick -= w;
                        if pick < 0.0 {
                            break;
                        }
                    }
                    let i = chosen.expect("some queue is non-empty while all.len() < total");
                    let mut r = queues[i].pop_front().expect("chosen queue is non-empty");
                    origin.push((i, r.gridlet.id));
                    r.gridlet.id = all.len();
                    all.push(r);
                }
                if all.iter().any(|r| !r.parents.is_empty()) {
                    // new_ids[part][old id] = interleaved id.
                    let mut new_ids: Vec<Vec<usize>> =
                        parts.iter().map(|p| vec![0; p.declared_jobs()]).collect();
                    for (new, &(part, old)) in origin.iter().enumerate() {
                        new_ids[part][old] = new;
                    }
                    for (r, &(part, _)) in all.iter_mut().zip(&origin) {
                        for p in &mut r.parents {
                            *p = new_ids[part][*p];
                        }
                    }
                }
                all
            }
            WorkloadSpec::OnlineArrivals { workload, arrivals } => {
                // Generate jobs first, then release times, so the inner
                // draw stream matches the unwrapped workload's.
                let mut releases = workload.materialize_generation_order(rand);
                let offsets = arrivals.offsets(releases.len(), rand.rng());
                for (r, off) in releases.iter_mut().zip(offsets) {
                    r.offset = off;
                }
                releases
            }
        };
        // Stable: equal offsets keep generation (id) order.
        releases.sort_by(|a, b| a.offset.total_cmp(&b.offset));
        releases
    }

    /// [`materialize`](Self::materialize) with the releases returned in
    /// generation (id) order instead of release order — what wrappers that
    /// renumber or re-time jobs consume.
    fn materialize_generation_order(&self, rand: &mut GridSimRandom) -> Vec<Release> {
        let mut releases = self.materialize(rand);
        releases.sort_by_key(|r| r.gridlet.id);
        releases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn materialize(spec: &WorkloadSpec, seed: u64) -> Vec<Release> {
        spec.materialize(&mut GridSimRandom::new(seed))
    }

    #[test]
    fn task_farm_matches_legacy_stream() {
        // The pre-WorkloadSpec materialization: real(base, 0, var) per job.
        let mut legacy = GridSimRandom::new(41);
        let expected: Vec<f64> =
            (0..50).map(|_| legacy.real(10_000.0, 0.0, 0.10)).collect();
        let releases = materialize(&WorkloadSpec::task_farm(50, 10_000.0, 0.10), 41);
        assert_eq!(releases.len(), 50);
        for (i, r) in releases.iter().enumerate() {
            assert_eq!(r.gridlet.id, i);
            assert_eq!(r.offset, 0.0);
            assert_eq!(r.gridlet.length_mi.to_bits(), expected[i].to_bits());
            assert_eq!(r.gridlet.input_bytes, 1000);
            assert_eq!(r.gridlet.output_bytes, 500);
        }
    }

    #[test]
    fn heavy_tailed_matches_promoted_generator() {
        let releases = materialize(&WorkloadSpec::heavy_tailed(500, 1_000.0, 0.1, 50.0), 2);
        let legacy = crate::workload::heavy_tailed_farm(500, 1_000.0, 0.1, 50.0, 2);
        assert_eq!(releases.len(), legacy.len());
        for (r, g) in releases.iter().zip(&legacy) {
            assert_eq!(r.gridlet.length_mi.to_bits(), g.length_mi.to_bits());
        }
        let heavy = releases.iter().filter(|r| r.gridlet.length_mi > 2_000.0).count();
        assert!(heavy > 10 && heavy < 150, "{heavy} heavy jobs");
    }

    #[test]
    fn explicit_and_trace_materialize_literally() {
        let explicit = WorkloadSpec::explicit(vec![
            JobSpec { length_mi: 10.0, input_bytes: 1, output_bytes: 2 },
            JobSpec { length_mi: 20.0, input_bytes: 3, output_bytes: 4 },
        ]);
        let r = materialize(&explicit, 1);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].gridlet.length_mi, 10.0);
        assert_eq!(r[1].gridlet.input_bytes, 3);
        assert!(r.iter().all(|r| r.offset == 0.0));

        // Trace jobs keep their submit offsets and are sorted by them.
        let trace = WorkloadSpec::trace(vec![
            TraceJob::new(5.0, 10.0, 1, 1),
            TraceJob::new(0.0, 20.0, 1, 1),
        ]);
        let r = materialize(&trace, 1);
        assert_eq!(r[0].offset, 0.0);
        assert_eq!(r[0].gridlet.id, 1, "sorted by submit time, ids kept");
        assert_eq!(r[1].offset, 5.0);
        assert_eq!(r[1].gridlet.id, 0);
        assert!(trace.is_online());
    }

    #[test]
    fn trace_selector_limits_jobs_and_totals() {
        let mut jobs = vec![
            TraceJob::new(0.0, 10.0, 1, 1),
            TraceJob::new(1.0, 20.0, 1, 1),
            TraceJob::new(2.0, 30.0, 1, 1),
        ];
        jobs[0].user = Some(3);
        jobs[1].user = Some(7);
        jobs[2].user = Some(3);
        let spec = WorkloadSpec::trace_selected(jobs.clone(), TraceSelector::user(3));
        assert_eq!(spec.declared_jobs(), 2);
        let r = materialize(&spec, 1);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].gridlet.length_mi, 10.0);
        assert_eq!(r[1].gridlet.length_mi, 30.0);
        assert_eq!((r[0].gridlet.id, r[1].gridlet.id), (0, 1), "ids renumber the slice");
        assert!(spec.has_trace());
        assert!(spec.validate().is_ok());

        // An empty selection is a validation error, not an empty run.
        let spec = WorkloadSpec::trace_selected(jobs, TraceSelector::user(99));
        assert!(spec.validate().is_err());

        // set_trace_selector retargets the slice.
        let mut spec = WorkloadSpec::trace(vec![TraceJob::new(0.0, 10.0, 1, 1)]);
        assert!(spec.set_trace_selector(&TraceSelector::all().with_max_jobs(1)));
        assert_eq!(spec.declared_jobs(), 1);
    }

    #[test]
    fn online_poisson_offsets_are_monotone_and_reassign_times() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(100, 1_000.0, 0.10),
            ArrivalProcess::Poisson { mean_interarrival: 5.0 },
        );
        let r = materialize(&spec, 9);
        assert_eq!(r.len(), 100);
        assert!(r.windows(2).all(|w| w[0].offset <= w[1].offset));
        assert!(r[0].offset > 0.0, "poisson: first job arrives after a gap");
        // The job lengths are the inner farm's, untouched by the wrapper.
        let inner = materialize(&WorkloadSpec::task_farm(100, 1_000.0, 0.10), 9);
        for (a, b) in r.iter().zip(&inner) {
            assert_eq!(a.gridlet.length_mi.to_bits(), b.gridlet.length_mi.to_bits());
        }
    }

    #[test]
    fn fixed_interval_starts_at_zero() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(4, 100.0, 0.0),
            ArrivalProcess::Fixed { interval: 7.0 },
        );
        let r = materialize(&spec, 1);
        let offsets: Vec<f64> = r.iter().map(|r| r.offset).collect();
        assert_eq!(offsets, vec![0.0, 7.0, 14.0, 21.0]);
    }

    #[test]
    fn modulated_arrivals_respect_the_envelope() {
        // A hard day/night cycle: rate 1 in [0, 50), 0 in [50, 100) — every
        // arrival must land in a "day" half-period.
        let envelope =
            RateEnvelope::Piecewise { period: 100.0, rates: vec![1.0, 0.0] };
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(200, 100.0, 0.0),
            ArrivalProcess::Modulated { mean_interarrival: 2.0, envelope },
        );
        spec.validate().unwrap();
        let r = materialize(&spec, 5);
        assert_eq!(r.len(), 200);
        assert!(r.windows(2).all(|w| w[0].offset <= w[1].offset));
        for rel in &r {
            let phase = rel.offset.rem_euclid(100.0);
            assert!(phase < 50.0, "arrival at {} fell in the zero-rate window", rel.offset);
        }
        // Deterministic under a fixed seed.
        let again = materialize(&spec, 5);
        for (a, b) in r.iter().zip(&again) {
            assert_eq!(a.offset.to_bits(), b.offset.to_bits());
        }

        // Sinusoid: amplitude 0 degenerates to a plain Poisson *rate* —
        // offsets still monotone, and roughly `n × mean` long.
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(2_000, 100.0, 0.0),
            ArrivalProcess::Modulated {
                mean_interarrival: 3.0,
                envelope: RateEnvelope::Sinusoid { period: 500.0, amplitude: 0.5 },
            },
        );
        let r = materialize(&spec, 8);
        let span = r.last().unwrap().offset;
        assert!((span / 2_000.0 - 3.0).abs() < 0.5, "mean gap ≈ 3, got {}", span / 2_000.0);
    }

    #[test]
    fn envelope_multipliers() {
        let p = RateEnvelope::Piecewise { period: 10.0, rates: vec![2.0, 0.5] };
        assert_eq!(p.multiplier(0.0), 2.0);
        assert_eq!(p.multiplier(4.999), 2.0);
        assert_eq!(p.multiplier(5.0), 0.5);
        assert_eq!(p.multiplier(12.0), 2.0, "periodic");
        assert_eq!(p.max_multiplier(), 2.0);
        let s = RateEnvelope::Sinusoid { period: 4.0, amplitude: 1.0 };
        assert!((s.multiplier(1.0) - 2.0).abs() < 1e-12);
        assert!(s.multiplier(3.0).abs() < 1e-12);
        assert_eq!(s.max_multiplier(), 2.0);
    }

    #[test]
    fn concat_appends_parts_in_order() {
        let spec = WorkloadSpec::concat(vec![
            WorkloadSpec::explicit(vec![JobSpec { length_mi: 1.0, input_bytes: 0, output_bytes: 0 }]),
            WorkloadSpec::trace(vec![
                TraceJob::new(3.0, 2.0, 0, 0),
                TraceJob::new(0.0, 3.0, 0, 0),
            ]),
        ]);
        assert_eq!(spec.declared_jobs(), 3);
        assert!(spec.is_online(), "the trace part has online jobs");
        let r = materialize(&spec, 1);
        assert_eq!(r.len(), 3);
        // Ids are assigned part-by-part in generation order: explicit job
        // (id 0), then the trace's two jobs in file order (ids 1, 2).
        assert_eq!(r[0].gridlet.id, 0);
        assert_eq!(r[0].gridlet.length_mi, 1.0);
        assert_eq!(r[1].gridlet.id, 2, "trace file order, not release order");
        assert_eq!(r[1].gridlet.length_mi, 3.0);
        assert_eq!((r[1].offset, r[2].offset), (0.0, 3.0));
    }

    #[test]
    fn mix_interleaves_with_weights_seed_stably() {
        let farm = |mi: f64| WorkloadSpec::task_farm(20, mi, 0.0);
        let spec = WorkloadSpec::mix_weighted(vec![farm(100.0), farm(900.0)], vec![3.0, 1.0]);
        assert_eq!(spec.declared_jobs(), 40);
        let r = materialize(&spec, 7);
        assert_eq!(r.len(), 40);
        let mut ids: Vec<usize> = r.iter().map(|x| x.gridlet.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
        // Both parts fully drain…
        assert_eq!(r.iter().filter(|x| x.gridlet.length_mi == 100.0).count(), 20);
        assert_eq!(r.iter().filter(|x| x.gridlet.length_mi == 900.0).count(), 20);
        // …and the weighted part front-loads: among the first 20 generated
        // ids, the weight-3 part is expected to contribute ~15; even a very
        // unlucky stream stays above 8.
        let early_light = r
            .iter()
            .filter(|x| x.gridlet.id < 20 && x.gridlet.length_mi == 100.0)
            .count();
        assert!(early_light >= 8, "{early_light} of the first 20 from the weight-3 part");
        // Seed-stable.
        let again = materialize(&spec, 7);
        for (a, b) in r.iter().zip(&again) {
            assert_eq!(a.gridlet.id, b.gridlet.id);
            assert_eq!(a.gridlet.length_mi.to_bits(), b.gridlet.length_mi.to_bits());
        }

        // set_mix_weights retargets matching-arity mixes only.
        let mut spec = spec;
        assert!(spec.set_mix_weights(&[1.0, 5.0]));
        assert!(!spec.set_mix_weights(&[1.0, 1.0, 1.0]), "arity mismatch leaves it alone");
        assert!(spec.has_mix_of(2));
        assert!(!spec.has_mix_of(3));
    }

    #[test]
    fn staging_override_reaches_every_variant() {
        let specs = [
            WorkloadSpec::task_farm(3, 100.0, 0.0),
            WorkloadSpec::heavy_tailed(3, 100.0, 0.5, 2.0),
            WorkloadSpec::explicit(vec![JobSpec {
                length_mi: 1.0,
                input_bytes: 9,
                output_bytes: 9,
            }]),
            WorkloadSpec::trace(vec![TraceJob::new(0.0, 1.0, 9, 9)]),
            WorkloadSpec::concat(vec![
                WorkloadSpec::task_farm(2, 100.0, 0.0),
                WorkloadSpec::trace(vec![TraceJob::new(0.0, 1.0, 9, 9)]),
            ]),
            WorkloadSpec::mix(vec![
                WorkloadSpec::task_farm(2, 100.0, 0.0),
                WorkloadSpec::heavy_tailed(2, 100.0, 0.5, 2.0),
            ]),
            WorkloadSpec::online(
                WorkloadSpec::task_farm(3, 100.0, 0.0),
                ArrivalProcess::Fixed { interval: 1.0 },
            ),
        ];
        for spec in specs {
            let spec = spec.with_staging(42, 24);
            for r in materialize(&spec, 1) {
                assert_eq!(r.gridlet.input_bytes, 42, "{}", spec.label());
                assert_eq!(r.gridlet.output_bytes, 24, "{}", spec.label());
            }
        }
    }

    #[test]
    fn staging_override_is_copy_on_write() {
        // Legacy behavior pin: before the Arc-shared job list, set_staging
        // mutated every TraceJob in place. The copy-on-write override must
        // produce digest-identical releases — and must leave the shared
        // log untouched.
        let jobs: Vec<TraceJob> = (0..20)
            .map(|i| TraceJob::new(i as f64 * 3.5, 100.0 + i as f64, 9, 9))
            .collect();
        let shared: Arc<[TraceJob]> = jobs.clone().into();

        // The historical semantics, emulated by hand on an owned copy.
        let mut mutated = jobs.clone();
        for j in &mut mutated {
            j.input_bytes = 42;
            j.output_bytes = 24;
        }
        let legacy = materialize(&WorkloadSpec::trace(mutated), 5);

        let spec = WorkloadSpec::trace_shared(shared.clone()).with_staging(42, 24);
        let cow = materialize(&spec, 5);
        let digest = |rs: &[Release]| -> String {
            rs.iter()
                .map(|r| {
                    format!(
                        "{}:{}:{}:{}:{}",
                        r.offset,
                        r.gridlet.id,
                        r.gridlet.length_mi,
                        r.gridlet.input_bytes,
                        r.gridlet.output_bytes
                    )
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        assert_eq!(digest(&legacy), digest(&cow), "COW staging == legacy in-place staging");

        // The shared allocation is still referenced (no clone happened) and
        // its jobs still carry the original staging sizes.
        let WorkloadSpec::Trace { jobs: held, .. } = &spec else { panic!("trace expected") };
        assert!(Arc::ptr_eq(held, &shared), "with_staging must not copy the log");
        assert!(shared.iter().all(|j| j.input_bytes == 9 && j.output_bytes == 9));
    }

    #[test]
    fn shared_trace_clones_share_one_allocation() {
        let shared: Arc<[TraceJob]> =
            vec![TraceJob::new(0.0, 10.0, 1, 1), TraceJob::new(2.0, 20.0, 1, 1)].into();
        let a = WorkloadSpec::trace_shared(shared.clone());
        let b = a.clone();
        let c = WorkloadSpec::trace_selected_shared(
            shared.clone(),
            TraceSelector::all().with_max_jobs(1),
        );
        for spec in [&a, &b, &c] {
            let WorkloadSpec::Trace { jobs, .. } = spec else { panic!("trace expected") };
            assert!(Arc::ptr_eq(jobs, &shared), "clones must share the log");
        }
        assert_eq!(a.declared_jobs(), 2);
        assert_eq!(c.declared_jobs(), 1, "selector narrows without copying");
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        for (spec, needle) in [
            (WorkloadSpec::task_farm(1, 0.0, 0.1), "length_mi"),
            (WorkloadSpec::task_farm(1, 1.0, 1.5), "variation"),
            (WorkloadSpec::heavy_tailed(1, 1.0, 1.5, 2.0), "heavy_fraction"),
            (WorkloadSpec::heavy_tailed(1, 1.0, 0.5, 0.5), "heavy_multiplier"),
            (
                WorkloadSpec::explicit(vec![JobSpec {
                    length_mi: 0.0,
                    input_bytes: 0,
                    output_bytes: 0,
                }]),
                "length_mi",
            ),
            (
                WorkloadSpec::trace(vec![TraceJob::new(-1.0, 1.0, 0, 0)]),
                "submit_time",
            ),
            (WorkloadSpec::concat(vec![]), "at least one part"),
            (WorkloadSpec::mix(vec![]), "at least one part"),
            (
                WorkloadSpec::mix_weighted(
                    vec![WorkloadSpec::task_farm(1, 1.0, 0.0)],
                    vec![1.0, 2.0],
                ),
                "weights",
            ),
            (
                WorkloadSpec::mix_weighted(
                    vec![WorkloadSpec::task_farm(1, 1.0, 0.0)],
                    vec![0.0],
                ),
                "> 0",
            ),
            (
                WorkloadSpec::concat(vec![WorkloadSpec::task_farm(1, 0.0, 0.0)]),
                "part #0",
            ),
            (
                WorkloadSpec::online(
                    WorkloadSpec::task_farm(1, 1.0, 0.0),
                    ArrivalProcess::Poisson { mean_interarrival: 0.0 },
                ),
                "mean_interarrival",
            ),
            (
                WorkloadSpec::online(
                    WorkloadSpec::task_farm(1, 1.0, 0.0),
                    ArrivalProcess::Modulated {
                        mean_interarrival: 1.0,
                        envelope: RateEnvelope::Piecewise { period: 10.0, rates: vec![0.0] },
                    },
                ),
                "all 0",
            ),
            (
                WorkloadSpec::online(
                    WorkloadSpec::task_farm(1, 1.0, 0.0),
                    ArrivalProcess::Modulated {
                        mean_interarrival: 1.0,
                        envelope: RateEnvelope::Sinusoid { period: 10.0, amplitude: 1.5 },
                    },
                ),
                "amplitude",
            ),
        ] {
            let err = format!("{:#}", spec.validate().unwrap_err());
            assert!(err.contains(needle), "{err}");
        }
        assert!(WorkloadSpec::task_farm(0, 1.0, 0.0).validate().is_ok(), "empty farm is legal");
    }

    #[test]
    fn sweep_override_hooks() {
        let mut spec = WorkloadSpec::online(
            WorkloadSpec::heavy_tailed(10, 100.0, 0.1, 10.0),
            ArrivalProcess::Poisson { mean_interarrival: 5.0 },
        );
        assert!(spec.has_arrival_process());
        assert!(spec.has_heavy_tail());
        assert!(spec.set_arrival_mean(2.0));
        assert!(spec.set_heavy_fraction(0.9));
        let WorkloadSpec::OnlineArrivals { workload, arrivals } = &spec else { panic!() };
        assert_eq!(*arrivals, ArrivalProcess::Poisson { mean_interarrival: 2.0 });
        let WorkloadSpec::HeavyTailed { heavy_fraction, .. } = **workload else { panic!() };
        assert_eq!(heavy_fraction, 0.9);

        // The hooks recurse into compositions.
        let mut mixed = WorkloadSpec::mix(vec![
            WorkloadSpec::heavy_tailed(5, 100.0, 0.1, 10.0),
            WorkloadSpec::online(
                WorkloadSpec::task_farm(5, 100.0, 0.0),
                ArrivalProcess::Modulated {
                    mean_interarrival: 4.0,
                    envelope: RateEnvelope::Sinusoid { period: 100.0, amplitude: 0.5 },
                },
            ),
        ]);
        assert!(mixed.has_arrival_process());
        assert!(mixed.has_heavy_tail());
        assert!(mixed.set_arrival_mean(9.0));
        assert!(mixed.set_heavy_fraction(0.4));
        let WorkloadSpec::Mix { parts, .. } = &mixed else { panic!() };
        let WorkloadSpec::HeavyTailed { heavy_fraction, .. } = parts[0] else { panic!() };
        assert_eq!(heavy_fraction, 0.4);
        let WorkloadSpec::OnlineArrivals { arrivals, .. } = &parts[1] else { panic!() };
        let ArrivalProcess::Modulated { mean_interarrival, .. } = arrivals else { panic!() };
        assert_eq!(*mean_interarrival, 9.0);

        let mut farm = WorkloadSpec::task_farm(1, 1.0, 0.0);
        assert!(!farm.set_arrival_mean(1.0));
        assert!(!farm.set_heavy_fraction(0.5));
        assert!(!farm.set_trace_selector(&TraceSelector::all()));
        assert!(!farm.set_mix_weights(&[1.0]));
        assert!(!farm.has_arrival_process());
        assert!(!farm.is_online());
        assert!(!farm.has_trace());
    }

    #[test]
    #[should_panic(expected = "cannot wrap")]
    fn nested_online_rejected() {
        let inner = WorkloadSpec::online(
            WorkloadSpec::task_farm(1, 1.0, 0.0),
            ArrivalProcess::Fixed { interval: 1.0 },
        );
        WorkloadSpec::online(inner, ArrivalProcess::Fixed { interval: 1.0 });
    }

    #[test]
    fn online_hidden_inside_composition_rejected() {
        // The nesting rule is recursive: an inner arrival process buried in
        // a concat/mix part must not be silently discarded by the wrapper.
        let hidden = WorkloadSpec::Concat {
            parts: vec![WorkloadSpec::online(
                WorkloadSpec::task_farm(2, 1.0, 0.0),
                ArrivalProcess::Poisson { mean_interarrival: 1.0 },
            )],
        };
        let spec = WorkloadSpec::OnlineArrivals {
            workload: Box::new(hidden),
            arrivals: ArrivalProcess::Fixed { interval: 1.0 },
        };
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("cannot wrap"), "{err}");

        // check_trace_selector walks compositions without mutating them.
        let mut jobs = vec![TraceJob::new(0.0, 1.0, 0, 0)];
        jobs[0].user = Some(4);
        let mixed = WorkloadSpec::mix(vec![
            WorkloadSpec::task_farm(2, 1.0, 0.0),
            WorkloadSpec::trace(jobs),
        ]);
        assert!(mixed.check_trace_selector(&TraceSelector::user(4)).unwrap());
        assert!(mixed.check_trace_selector(&TraceSelector::user(9)).is_err());
        assert!(!WorkloadSpec::task_farm(1, 1.0, 0.0)
            .check_trace_selector(&TraceSelector::all())
            .unwrap());
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::heavy_tailed(64, 1_000.0, 0.2, 20.0),
            ArrivalProcess::Poisson { mean_interarrival: 3.0 },
        );
        let a = materialize(&spec, 123);
        let b = materialize(&spec, 123);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.offset.to_bits(), y.offset.to_bits());
            assert_eq!(x.gridlet.length_mi.to_bits(), y.gridlet.length_mi.to_bits());
        }
    }
}
