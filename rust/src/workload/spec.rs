//! `WorkloadSpec` — the first-class application model (paper §4.2.1: "users
//! and application models", with "primitives for creation of application
//! tasks").
//!
//! A workload is a *value* describing how a user's Gridlets come into
//! existence and when they are released to the broker:
//!
//! * [`WorkloadSpec::TaskFarm`] — the paper's §5.2 uniform task farm
//!   (`n` jobs of at least `base` MI with a 0–`variation` positive random
//!   spread). The default, and byte-identical to the historical
//!   `ExperimentSpec` task-farm fields.
//! * [`WorkloadSpec::HeavyTailed`] — mostly-uniform jobs with a fraction
//!   stretched by up to a multiplier (exercises SJF/backfilling and broker
//!   re-planning under heterogeneous job lengths).
//! * [`WorkloadSpec::Explicit`] — a literal job list.
//! * [`WorkloadSpec::Trace`] — jobs replayed from an SWF-style trace file
//!   (`submit_time length_mi input_bytes output_bytes` per line, see
//!   [`crate::workload::trace`]); jobs with `submit_time > 0` arrive online.
//! * [`WorkloadSpec::OnlineArrivals`] — any of the above with release times
//!   reassigned by a Poisson or fixed-interval [`ArrivalProcess`]
//!   (Nimrod/G-style parameter-sweep jobs streaming in over time).
//!
//! [`WorkloadSpec::materialize`] turns the spec into a deterministic list of
//! [`Release`]s (offset from submission + Gridlet) using the caller's seeded
//! [`GridSimRandom`]; releases at offset 0 form the experiment's initial
//! batch and later ones are streamed to the broker as `GRIDLET_ARRIVAL`
//! events by the user entity.

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::random::GridSimRandom;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// One job of an [`WorkloadSpec::Explicit`] workload.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub length_mi: f64,
    pub input_bytes: u64,
    pub output_bytes: u64,
}

/// One job of an [`WorkloadSpec::Trace`] workload: an [`JobSpec`] plus the
/// submission offset (simulation time units after the experiment starts).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    pub submit_time: f64,
    pub length_mi: f64,
    pub input_bytes: u64,
    pub output_bytes: u64,
}

/// When online jobs are released to the broker, relative to experiment
/// submission.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process: exponential inter-arrival gaps with the given mean
    /// (the promoted `poisson_arrivals` helper). The first job arrives after
    /// the first gap.
    Poisson { mean_interarrival: f64 },
    /// Fixed-interval release: job `i` arrives at `i × interval` (the first
    /// job is part of the initial batch).
    Fixed { interval: f64 },
}

impl ArrivalProcess {
    /// Release offsets for `n` jobs, drawn from `rng` (Poisson) or computed
    /// (fixed). Monotonically non-decreasing.
    pub fn offsets(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { mean_interarrival } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(*mean_interarrival);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Fixed { interval } => (0..n).map(|i| i as f64 * interval).collect(),
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            ArrivalProcess::Poisson { mean_interarrival } => {
                if *mean_interarrival <= 0.0 || mean_interarrival.is_nan() {
                    bail!("poisson arrivals need mean_interarrival > 0, got {mean_interarrival}");
                }
            }
            ArrivalProcess::Fixed { interval } => {
                if *interval < 0.0 || interval.is_nan() {
                    bail!("fixed arrivals need interval >= 0, got {interval}");
                }
            }
        }
        Ok(())
    }
}

/// One materialized job release: the Gridlet plus its release offset from
/// experiment submission (0 = part of the initial batch).
#[derive(Debug, Clone)]
pub struct Release {
    pub offset: f64,
    pub gridlet: Gridlet,
}

/// Declarative application model — how a user's Gridlets are generated and
/// when they are released. See the module docs for the variants.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// Paper §5.2: `num_gridlets` jobs of `base_length_mi` MI with a
    /// 0–`length_variation` positive random variation.
    TaskFarm {
        num_gridlets: usize,
        base_length_mi: f64,
        length_variation: f64,
        input_bytes: u64,
        output_bytes: u64,
    },
    /// Most jobs within ±10% of `base_length_mi`; a `heavy_fraction` of them
    /// stretched by up to `heavy_multiplier`×.
    HeavyTailed {
        num_gridlets: usize,
        base_length_mi: f64,
        heavy_fraction: f64,
        heavy_multiplier: f64,
        input_bytes: u64,
        output_bytes: u64,
    },
    /// A literal job list, released as one batch.
    Explicit { jobs: Vec<JobSpec> },
    /// SWF-style trace replay: each job carries its own submission offset.
    Trace { jobs: Vec<TraceJob> },
    /// A generative wrapper: `workload`'s jobs with release times reassigned
    /// by `arrivals` (nesting another `OnlineArrivals` is rejected).
    OnlineArrivals { workload: Box<WorkloadSpec>, arrivals: ArrivalProcess },
}

impl WorkloadSpec {
    /// The paper's §5.2 task farm with its staging sizes (1000 B in, 500 B
    /// out).
    pub fn task_farm(n: usize, base_mi: f64, variation: f64) -> WorkloadSpec {
        WorkloadSpec::TaskFarm {
            num_gridlets: n,
            base_length_mi: base_mi,
            length_variation: variation,
            input_bytes: 1000,
            output_bytes: 500,
        }
    }

    /// A heavy-tailed farm with the paper's staging sizes.
    pub fn heavy_tailed(n: usize, base_mi: f64, fraction: f64, multiplier: f64) -> WorkloadSpec {
        WorkloadSpec::HeavyTailed {
            num_gridlets: n,
            base_length_mi: base_mi,
            heavy_fraction: fraction,
            heavy_multiplier: multiplier,
            input_bytes: 1000,
            output_bytes: 500,
        }
    }

    /// A literal job list.
    pub fn explicit(jobs: Vec<JobSpec>) -> WorkloadSpec {
        WorkloadSpec::Explicit { jobs }
    }

    /// A trace replay.
    pub fn trace(jobs: Vec<TraceJob>) -> WorkloadSpec {
        WorkloadSpec::Trace { jobs }
    }

    /// Wrap `workload` with an online arrival process.
    ///
    /// Panics when `workload` is itself `OnlineArrivals` (one arrival
    /// process per workload; the JSON loader rejects this too).
    pub fn online(workload: WorkloadSpec, arrivals: ArrivalProcess) -> WorkloadSpec {
        assert!(
            !matches!(workload, WorkloadSpec::OnlineArrivals { .. }),
            "online_arrivals cannot wrap another online_arrivals"
        );
        WorkloadSpec::OnlineArrivals { workload: Box::new(workload), arrivals }
    }

    /// Override the staging sizes on every job of the workload.
    pub fn with_staging(mut self, input: u64, output: u64) -> WorkloadSpec {
        self.set_staging(input, output);
        self
    }

    fn set_staging(&mut self, input: u64, output: u64) {
        match self {
            WorkloadSpec::TaskFarm { input_bytes, output_bytes, .. }
            | WorkloadSpec::HeavyTailed { input_bytes, output_bytes, .. } => {
                *input_bytes = input;
                *output_bytes = output;
            }
            WorkloadSpec::Explicit { jobs } => {
                for j in jobs {
                    j.input_bytes = input;
                    j.output_bytes = output;
                }
            }
            WorkloadSpec::Trace { jobs } => {
                for j in jobs {
                    j.input_bytes = input;
                    j.output_bytes = output;
                }
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.set_staging(input, output),
        }
    }

    /// Number of jobs the workload declares (independent of release times).
    pub fn declared_jobs(&self) -> usize {
        match self {
            WorkloadSpec::TaskFarm { num_gridlets, .. }
            | WorkloadSpec::HeavyTailed { num_gridlets, .. } => *num_gridlets,
            WorkloadSpec::Explicit { jobs } => jobs.len(),
            WorkloadSpec::Trace { jobs } => jobs.len(),
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.declared_jobs(),
        }
    }

    /// Does any job arrive after submission (trace offsets or an arrival
    /// process)?
    pub fn is_online(&self) -> bool {
        match self {
            WorkloadSpec::Trace { jobs } => jobs.iter().any(|j| j.submit_time > 0.0),
            WorkloadSpec::OnlineArrivals { .. } => true,
            _ => false,
        }
    }

    /// Is there an [`ArrivalProcess`] anywhere in the spec (sweepable via
    /// the `mean_interarrivals` axis)?
    pub fn has_arrival_process(&self) -> bool {
        matches!(self, WorkloadSpec::OnlineArrivals { .. })
    }

    /// Is there a heavy-tailed generator anywhere in the spec (sweepable via
    /// the `heavy_fractions` axis)?
    pub fn has_heavy_tail(&self) -> bool {
        match self {
            WorkloadSpec::HeavyTailed { .. } => true,
            WorkloadSpec::OnlineArrivals { workload, .. } => workload.has_heavy_tail(),
            _ => false,
        }
    }

    /// Override the arrival process's mean inter-arrival (Poisson mean or
    /// fixed interval). Returns whether anything was changed.
    pub fn set_arrival_mean(&mut self, mean: f64) -> bool {
        match self {
            WorkloadSpec::OnlineArrivals { arrivals, .. } => {
                match arrivals {
                    ArrivalProcess::Poisson { mean_interarrival } => *mean_interarrival = mean,
                    ArrivalProcess::Fixed { interval } => *interval = mean,
                }
                true
            }
            _ => false,
        }
    }

    /// Override the heavy-tail fraction. Returns whether anything was
    /// changed.
    pub fn set_heavy_fraction(&mut self, fraction: f64) -> bool {
        match self {
            WorkloadSpec::HeavyTailed { heavy_fraction, .. } => {
                *heavy_fraction = fraction;
                true
            }
            WorkloadSpec::OnlineArrivals { workload, .. } => {
                workload.set_heavy_fraction(fraction)
            }
            _ => false,
        }
    }

    /// Short label for reports and error messages.
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadSpec::TaskFarm { .. } => "task_farm",
            WorkloadSpec::HeavyTailed { .. } => "heavy_tailed",
            WorkloadSpec::Explicit { .. } => "explicit",
            WorkloadSpec::Trace { .. } => "trace",
            WorkloadSpec::OnlineArrivals { .. } => "online_arrivals",
        }
    }

    /// Reject impossible parameters with a readable error (the JSON loader
    /// and sweep validation call this; `materialize` asserts as a backstop).
    pub fn validate(&self) -> Result<()> {
        match self {
            WorkloadSpec::TaskFarm { base_length_mi, length_variation, .. } => {
                if *base_length_mi <= 0.0 || base_length_mi.is_nan() {
                    bail!("task_farm: length_mi must be > 0, got {base_length_mi}");
                }
                if !(0.0..=1.0).contains(length_variation) {
                    bail!("task_farm: variation must be in [0, 1], got {length_variation}");
                }
            }
            WorkloadSpec::HeavyTailed {
                base_length_mi, heavy_fraction, heavy_multiplier, ..
            } => {
                if *base_length_mi <= 0.0 || base_length_mi.is_nan() {
                    bail!("heavy_tailed: length_mi must be > 0, got {base_length_mi}");
                }
                if !(0.0..=1.0).contains(heavy_fraction) {
                    bail!("heavy_tailed: heavy_fraction must be in [0, 1], got {heavy_fraction}");
                }
                if *heavy_multiplier < 1.0 || heavy_multiplier.is_nan() {
                    bail!("heavy_tailed: heavy_multiplier must be >= 1, got {heavy_multiplier}");
                }
            }
            WorkloadSpec::Explicit { jobs } => {
                for (i, j) in jobs.iter().enumerate() {
                    if j.length_mi <= 0.0 || j.length_mi.is_nan() {
                        bail!("explicit job #{i}: length_mi must be > 0, got {}", j.length_mi);
                    }
                }
            }
            WorkloadSpec::Trace { jobs } => {
                for (i, j) in jobs.iter().enumerate() {
                    if j.length_mi <= 0.0 || j.length_mi.is_nan() {
                        bail!("trace job #{i}: length_mi must be > 0, got {}", j.length_mi);
                    }
                    if j.submit_time < 0.0 || j.submit_time.is_nan() {
                        bail!("trace job #{i}: submit_time must be >= 0, got {}", j.submit_time);
                    }
                }
            }
            WorkloadSpec::OnlineArrivals { workload, arrivals } => {
                if matches!(**workload, WorkloadSpec::OnlineArrivals { .. }) {
                    bail!("online_arrivals cannot wrap another online_arrivals");
                }
                arrivals.validate()?;
                workload.validate()?;
            }
        }
        Ok(())
    }

    /// Materialize the workload into release order, drawing every random
    /// quantity from `rand`: two materializations with equally-seeded
    /// generators are bit-identical. Gridlet ids are assigned in generation
    /// order (0..n); the returned list is stably sorted by release offset.
    ///
    /// The `TaskFarm` draw sequence (`real(base, 0, variation)` per job) is
    /// the historical `ExperimentSpec::materialize` stream, so pre-existing
    /// scenarios reproduce bit-for-bit.
    pub fn materialize(&self, rand: &mut GridSimRandom) -> Vec<Release> {
        let mut releases: Vec<Release> = match self {
            WorkloadSpec::TaskFarm {
                num_gridlets,
                base_length_mi,
                length_variation,
                input_bytes,
                output_bytes,
            } => (0..*num_gridlets)
                .map(|i| {
                    let len = rand.real(*base_length_mi, 0.0, *length_variation);
                    Release {
                        offset: 0.0,
                        gridlet: Gridlet::new(i, len, *input_bytes, *output_bytes),
                    }
                })
                .collect(),
            WorkloadSpec::HeavyTailed {
                num_gridlets,
                base_length_mi,
                heavy_fraction,
                heavy_multiplier,
                input_bytes,
                output_bytes,
            } => {
                assert!((0.0..=1.0).contains(heavy_fraction));
                assert!(*heavy_multiplier >= 1.0);
                let rng = rand.rng();
                (0..*num_gridlets)
                    .map(|i| {
                        let mut len = base_length_mi * rng.uniform(0.9, 1.1);
                        if rng.next_f64() < *heavy_fraction {
                            len *= rng.uniform(1.0, *heavy_multiplier);
                        }
                        Release {
                            offset: 0.0,
                            gridlet: Gridlet::new(i, len, *input_bytes, *output_bytes),
                        }
                    })
                    .collect()
            }
            WorkloadSpec::Explicit { jobs } => jobs
                .iter()
                .enumerate()
                .map(|(i, j)| Release {
                    offset: 0.0,
                    gridlet: Gridlet::new(i, j.length_mi, j.input_bytes, j.output_bytes),
                })
                .collect(),
            WorkloadSpec::Trace { jobs } => jobs
                .iter()
                .enumerate()
                .map(|(i, j)| Release {
                    offset: j.submit_time,
                    gridlet: Gridlet::new(i, j.length_mi, j.input_bytes, j.output_bytes),
                })
                .collect(),
            WorkloadSpec::OnlineArrivals { workload, arrivals } => {
                // Generate jobs first, then release times, so the inner
                // draw stream matches the unwrapped workload's.
                let mut releases = workload.materialize(rand);
                releases.sort_by_key(|r| r.gridlet.id);
                let offsets = arrivals.offsets(releases.len(), rand.rng());
                for (r, off) in releases.iter_mut().zip(offsets) {
                    r.offset = off;
                }
                releases
            }
        };
        // Stable: equal offsets keep generation (id) order.
        releases.sort_by(|a, b| a.offset.total_cmp(&b.offset));
        releases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn materialize(spec: &WorkloadSpec, seed: u64) -> Vec<Release> {
        spec.materialize(&mut GridSimRandom::new(seed))
    }

    #[test]
    fn task_farm_matches_legacy_stream() {
        // The pre-WorkloadSpec materialization: real(base, 0, var) per job.
        let mut legacy = GridSimRandom::new(41);
        let expected: Vec<f64> =
            (0..50).map(|_| legacy.real(10_000.0, 0.0, 0.10)).collect();
        let releases = materialize(&WorkloadSpec::task_farm(50, 10_000.0, 0.10), 41);
        assert_eq!(releases.len(), 50);
        for (i, r) in releases.iter().enumerate() {
            assert_eq!(r.gridlet.id, i);
            assert_eq!(r.offset, 0.0);
            assert_eq!(r.gridlet.length_mi.to_bits(), expected[i].to_bits());
            assert_eq!(r.gridlet.input_bytes, 1000);
            assert_eq!(r.gridlet.output_bytes, 500);
        }
    }

    #[test]
    fn heavy_tailed_matches_promoted_generator() {
        let releases = materialize(&WorkloadSpec::heavy_tailed(500, 1_000.0, 0.1, 50.0), 2);
        let legacy = crate::workload::heavy_tailed_farm(500, 1_000.0, 0.1, 50.0, 2);
        assert_eq!(releases.len(), legacy.len());
        for (r, g) in releases.iter().zip(&legacy) {
            assert_eq!(r.gridlet.length_mi.to_bits(), g.length_mi.to_bits());
        }
        let heavy = releases.iter().filter(|r| r.gridlet.length_mi > 2_000.0).count();
        assert!(heavy > 10 && heavy < 150, "{heavy} heavy jobs");
    }

    #[test]
    fn explicit_and_trace_materialize_literally() {
        let explicit = WorkloadSpec::explicit(vec![
            JobSpec { length_mi: 10.0, input_bytes: 1, output_bytes: 2 },
            JobSpec { length_mi: 20.0, input_bytes: 3, output_bytes: 4 },
        ]);
        let r = materialize(&explicit, 1);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].gridlet.length_mi, 10.0);
        assert_eq!(r[1].gridlet.input_bytes, 3);
        assert!(r.iter().all(|r| r.offset == 0.0));

        // Trace jobs keep their submit offsets and are sorted by them.
        let trace = WorkloadSpec::trace(vec![
            TraceJob { submit_time: 5.0, length_mi: 10.0, input_bytes: 1, output_bytes: 1 },
            TraceJob { submit_time: 0.0, length_mi: 20.0, input_bytes: 1, output_bytes: 1 },
        ]);
        let r = materialize(&trace, 1);
        assert_eq!(r[0].offset, 0.0);
        assert_eq!(r[0].gridlet.id, 1, "sorted by submit time, ids kept");
        assert_eq!(r[1].offset, 5.0);
        assert_eq!(r[1].gridlet.id, 0);
        assert!(trace.is_online());
    }

    #[test]
    fn online_poisson_offsets_are_monotone_and_reassign_times() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(100, 1_000.0, 0.10),
            ArrivalProcess::Poisson { mean_interarrival: 5.0 },
        );
        let r = materialize(&spec, 9);
        assert_eq!(r.len(), 100);
        assert!(r.windows(2).all(|w| w[0].offset <= w[1].offset));
        assert!(r[0].offset > 0.0, "poisson: first job arrives after a gap");
        // The job lengths are the inner farm's, untouched by the wrapper.
        let inner = materialize(&WorkloadSpec::task_farm(100, 1_000.0, 0.10), 9);
        for (a, b) in r.iter().zip(&inner) {
            assert_eq!(a.gridlet.length_mi.to_bits(), b.gridlet.length_mi.to_bits());
        }
    }

    #[test]
    fn fixed_interval_starts_at_zero() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::task_farm(4, 100.0, 0.0),
            ArrivalProcess::Fixed { interval: 7.0 },
        );
        let r = materialize(&spec, 1);
        let offsets: Vec<f64> = r.iter().map(|r| r.offset).collect();
        assert_eq!(offsets, vec![0.0, 7.0, 14.0, 21.0]);
    }

    #[test]
    fn staging_override_reaches_every_variant() {
        let specs = [
            WorkloadSpec::task_farm(3, 100.0, 0.0),
            WorkloadSpec::heavy_tailed(3, 100.0, 0.5, 2.0),
            WorkloadSpec::explicit(vec![JobSpec {
                length_mi: 1.0,
                input_bytes: 9,
                output_bytes: 9,
            }]),
            WorkloadSpec::trace(vec![TraceJob {
                submit_time: 0.0,
                length_mi: 1.0,
                input_bytes: 9,
                output_bytes: 9,
            }]),
            WorkloadSpec::online(
                WorkloadSpec::task_farm(3, 100.0, 0.0),
                ArrivalProcess::Fixed { interval: 1.0 },
            ),
        ];
        for spec in specs {
            let spec = spec.with_staging(42, 24);
            for r in materialize(&spec, 1) {
                assert_eq!(r.gridlet.input_bytes, 42, "{}", spec.label());
                assert_eq!(r.gridlet.output_bytes, 24, "{}", spec.label());
            }
        }
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        for (spec, needle) in [
            (WorkloadSpec::task_farm(1, 0.0, 0.1), "length_mi"),
            (WorkloadSpec::task_farm(1, 1.0, 1.5), "variation"),
            (WorkloadSpec::heavy_tailed(1, 1.0, 1.5, 2.0), "heavy_fraction"),
            (WorkloadSpec::heavy_tailed(1, 1.0, 0.5, 0.5), "heavy_multiplier"),
            (
                WorkloadSpec::explicit(vec![JobSpec {
                    length_mi: 0.0,
                    input_bytes: 0,
                    output_bytes: 0,
                }]),
                "length_mi",
            ),
            (
                WorkloadSpec::trace(vec![TraceJob {
                    submit_time: -1.0,
                    length_mi: 1.0,
                    input_bytes: 0,
                    output_bytes: 0,
                }]),
                "submit_time",
            ),
            (
                WorkloadSpec::online(
                    WorkloadSpec::task_farm(1, 1.0, 0.0),
                    ArrivalProcess::Poisson { mean_interarrival: 0.0 },
                ),
                "mean_interarrival",
            ),
        ] {
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
        assert!(WorkloadSpec::task_farm(0, 1.0, 0.0).validate().is_ok(), "empty farm is legal");
    }

    #[test]
    fn sweep_override_hooks() {
        let mut spec = WorkloadSpec::online(
            WorkloadSpec::heavy_tailed(10, 100.0, 0.1, 10.0),
            ArrivalProcess::Poisson { mean_interarrival: 5.0 },
        );
        assert!(spec.has_arrival_process());
        assert!(spec.has_heavy_tail());
        assert!(spec.set_arrival_mean(2.0));
        assert!(spec.set_heavy_fraction(0.9));
        let WorkloadSpec::OnlineArrivals { workload, arrivals } = &spec else { panic!() };
        assert_eq!(*arrivals, ArrivalProcess::Poisson { mean_interarrival: 2.0 });
        let WorkloadSpec::HeavyTailed { heavy_fraction, .. } = **workload else { panic!() };
        assert_eq!(heavy_fraction, 0.9);

        let mut farm = WorkloadSpec::task_farm(1, 1.0, 0.0);
        assert!(!farm.set_arrival_mean(1.0));
        assert!(!farm.set_heavy_fraction(0.5));
        assert!(!farm.has_arrival_process());
        assert!(!farm.is_online());
    }

    #[test]
    #[should_panic(expected = "cannot wrap")]
    fn nested_online_rejected() {
        let inner = WorkloadSpec::online(
            WorkloadSpec::task_farm(1, 1.0, 0.0),
            ArrivalProcess::Fixed { interval: 1.0 },
        );
        WorkloadSpec::online(inner, ArrivalProcess::Fixed { interval: 1.0 });
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let spec = WorkloadSpec::online(
            WorkloadSpec::heavy_tailed(64, 1_000.0, 0.2, 20.0),
            ArrivalProcess::Poisson { mean_interarrival: 3.0 },
        );
        let a = materialize(&spec, 123);
        let b = materialize(&spec, 123);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.offset.to_bits(), y.offset.to_bits());
            assert_eq!(x.gridlet.length_mi.to_bits(), y.gridlet.length_mi.to_bits());
        }
    }
}
