//! Application/workload models (paper §3.3/§4.2.1/§5.2): the first-class
//! [`WorkloadSpec`] API — generative task farms and heavy-tailed mixes,
//! explicit job lists, DAG workflows with precedence-gated release
//! ([`WorkloadSpec::Dag`]), trace replay (legacy 4-column and full
//! 18-column SWF, sliced per user by a [`TraceSelector`]), declarative
//! composition ([`WorkloadSpec::Concat`] / [`WorkloadSpec::Mix`]), and
//! online arrivals (Poisson, fixed-interval, or day/night rate-modulated)
//! — plus the original free-function generators, now thin wrappers over
//! the spec.

pub mod app;
pub mod dag;
pub mod spec;
pub mod trace;

pub use app::{heavy_tailed_farm, paper_task_farm, poisson_arrivals};
pub use dag::{parse_dot, DagNode};
pub use spec::{ArrivalProcess, JobSpec, RateEnvelope, Release, TraceJob, WorkloadSpec};
pub use trace::{
    detect_format, format_trace, load_trace_file, load_trace_file_shared, load_trace_file_with,
    parse_swf, parse_trace, SwfHeader, SwfJob, SwfLoadOptions, SwfTrace, TraceFormat,
    TraceSelector,
};
