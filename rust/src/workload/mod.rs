//! Application/workload models (paper §3.3/§4.2.1/§5.2): the first-class
//! [`WorkloadSpec`] API (generative task farms, heavy-tailed mixes, explicit
//! job lists, SWF-style trace replay, and online Poisson/fixed-interval
//! arrivals) plus the original free-function generators, now thin wrappers
//! over the spec.

pub mod app;
pub mod spec;
pub mod trace;

pub use app::{heavy_tailed_farm, paper_task_farm, poisson_arrivals};
pub use spec::{ArrivalProcess, JobSpec, Release, TraceJob, WorkloadSpec};
pub use trace::{format_trace, load_trace_file, parse_trace};
