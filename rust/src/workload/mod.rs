//! Synthetic application/workload generators (paper §3.3/§5.2): task-farming
//! parameter sweeps plus heavier-tailed mixes for stress testing.

pub mod app;

pub use app::{heavy_tailed_farm, paper_task_farm, poisson_arrivals};
