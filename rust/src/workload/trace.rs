//! Workload trace I/O: the legacy 4-column format and the full 18-column
//! Standard Workload Format (SWF).
//!
//! Two on-disk formats share one loading entry point
//! ([`load_trace_file`] auto-detects by column count):
//!
//! **Legacy 4-column** — the toolkit's original reduced format, one job per
//! line:
//!
//! ```text
//! ; comment (SWF convention) — '#' comments are accepted too
//! ; submit_time  length_mi  input_bytes  output_bytes
//!   0            10000      1000         500
//!   42.5         12000      1000         500
//! ```
//!
//! **18-column SWF** — the format published supercomputer logs use (and
//! trace-driven simulators like dslab replay): `;`-comment header
//! *directives* (`; MaxNodes: 128`, `; UnixStartTime: 845923442`, …)
//! followed by one 18-field record per job. `-1` marks a missing field.
//! [`parse_swf`] keeps the raw records ([`SwfJob`]) and directives
//! ([`SwfHeader`]); [`SwfTrace::to_trace_jobs`] converts them into
//! simulator jobs by
//!
//! 1. keeping only jobs whose status passes the filter (default: completed
//!    `1` and unknown `-1`),
//! 2. turning runtimes into MI: `length_mi = seconds × processors × mips`
//!    (`run_time`, falling back to `requested_time`; `allocated_procs`,
//!    falling back to `requested_procs`, falling back to 1) — jobs with no
//!    usable positive runtime are skipped,
//! 3. rebasing submit times so the earliest kept job is at offset 0 (logs
//!    count seconds from `UnixStartTime`, which would otherwise stall the
//!    experiment for the whole lead-in), and
//! 4. carrying `user_id`/`partition` through, so a [`TraceSelector`] can
//!    later split one log into per-user workloads *without* re-reading the
//!    file. Selection happens after the shared rebase, so per-user slices
//!    of one log stay mutually time-aligned.
//!
//! Loaded jobs are meant to be shared, not copied: [`load_trace_file_shared`]
//! returns an `Arc<[TraceJob]>` that any number of
//! [`crate::workload::WorkloadSpec::trace_selected_shared`] workloads (and
//! every cell of a parameter sweep) can reference. The shared list is
//! immutable — per-workload variation goes through the selector and the
//! materialization-time staging override, never through mutation of the jobs
//! themselves. The JSON scenario loader applies the same discipline: within
//! one file, every `"trace"` workload naming the same path (and SWF options)
//! receives a clone of one shared `Arc`.
//!
//! `submit_time` in a [`TraceJob`] is the release offset from experiment
//! submission (jobs with offset 0 form the initial batch; later ones arrive
//! online). [`format_trace`] and [`parse_trace`] round-trip the legacy
//! format exactly: floats are written in Rust's shortest-roundtrip form.

use super::spec::TraceJob;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Parse a legacy 4-column trace from text. Empty lines and lines starting
/// with `;` or `#` are skipped; every other line must hold exactly four
/// numeric fields (`submit_time length_mi input_bytes output_bytes`).
pub fn parse_trace(text: &str) -> Result<Vec<TraceJob>> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with(';') || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 4 {
            bail!(
                "trace line {}: expected 4 fields (submit_time length_mi input_bytes \
                 output_bytes), got {}",
                lineno + 1,
                fields.len()
            );
        }
        let num = |i: usize, what: &str| -> Result<f64> {
            let n = fields[i].parse::<f64>().map_err(|_| {
                anyhow!("trace line {}: {what} {:?} is not a number", lineno + 1, fields[i])
            })?;
            if !n.is_finite() {
                bail!("trace line {}: {what} must be finite, got {n}", lineno + 1);
            }
            Ok(n)
        };
        let bytes = |i: usize, what: &str| -> Result<u64> {
            let n = num(i, what)?;
            if n >= 0.0 && n.fract() == 0.0 && n < 9_007_199_254_740_992.0 {
                Ok(n as u64)
            } else {
                bail!("trace line {}: {what} must be a non-negative integer, got {n}", lineno + 1)
            }
        };
        let job = TraceJob::new(
            num(0, "submit_time")?,
            num(1, "length_mi")?,
            bytes(2, "input_bytes")?,
            bytes(3, "output_bytes")?,
        );
        if job.submit_time < 0.0 {
            bail!("trace line {}: submit_time must be >= 0, got {}", lineno + 1, job.submit_time);
        }
        if job.length_mi <= 0.0 {
            bail!("trace line {}: length_mi must be > 0, got {}", lineno + 1, job.length_mi);
        }
        jobs.push(job);
    }
    if jobs.is_empty() {
        bail!("trace holds no jobs");
    }
    Ok(jobs)
}

/// Serialize jobs into the legacy 4-column format (header comment + one
/// line per job). Floats use Rust's shortest-roundtrip formatting, so
/// `parse_trace(&format_trace(jobs))` reproduces `jobs` exactly — except
/// SWF-derived `user`/`partition` metadata, which the 4-column format
/// cannot carry.
pub fn format_trace(jobs: &[TraceJob]) -> String {
    let mut out = String::from("; submit_time length_mi input_bytes output_bytes\n");
    for j in jobs {
        out.push_str(&format!(
            "{} {} {} {}\n",
            j.submit_time, j.length_mi, j.input_bytes, j.output_bytes
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Standard Workload Format (18 columns)
// ---------------------------------------------------------------------------

/// The field count of a Standard Workload Format record.
pub const SWF_FIELDS: usize = 18;

/// Default job-status filter for SWF conversion: completed (`1`) plus
/// unknown (`-1`, for logs that do not record a status).
pub const SWF_DEFAULT_STATUSES: &[i64] = &[1, -1];

/// Header directives of an SWF file: every `; Key: value` comment line, in
/// file order, plus typed accessors for the directives the simulator cares
/// about. Unknown directives are kept verbatim (the SWF convention allows
/// site-specific keys), never rejected.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SwfHeader {
    /// All `(key, value)` directive pairs, in file order. Repeated keys
    /// (e.g. multiple `Note:` lines) are all kept.
    pub directives: Vec<(String, String)>,
}

impl SwfHeader {
    /// First value recorded for `key` (case-sensitive, the SWF convention).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.directives.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(|v| v.trim().parse::<i64>().ok())
    }

    /// `UnixStartTime` — epoch seconds of the log start (submit times count
    /// from it).
    pub fn unix_start_time(&self) -> Option<i64> {
        self.get_i64("UnixStartTime")
    }

    /// `MaxNodes` — number of nodes in the logged machine.
    pub fn max_nodes(&self) -> Option<i64> {
        self.get_i64("MaxNodes")
    }

    /// `MaxProcs` — number of processors in the logged machine.
    pub fn max_procs(&self) -> Option<i64> {
        self.get_i64("MaxProcs")
    }

    /// `MaxJobs` — number of jobs the log declares.
    pub fn max_jobs(&self) -> Option<i64> {
        self.get_i64("MaxJobs")
    }

    /// `Computer` — the logged machine's name.
    pub fn computer(&self) -> Option<&str> {
        self.get("Computer")
    }

    /// `Version` — SWF version of the file.
    pub fn version(&self) -> Option<&str> {
        self.get("Version")
    }
}

/// One raw 18-field SWF record, exactly as parsed. Integer fields keep the
/// SWF `-1` = "missing" sentinel; use the `*_opt` accessors for
/// `Option`-shaped reads.
#[derive(Debug, Clone, PartialEq)]
pub struct SwfJob {
    /// 1: job number (counting from 1 in the standard, but not enforced).
    pub job_id: i64,
    /// 2: submit time, seconds from the log start (`UnixStartTime`).
    pub submit_time: f64,
    /// 3: seconds the job waited in the queue (`-1` = missing).
    pub wait_time: f64,
    /// 4: wall-clock runtime in seconds (`-1` = missing).
    pub run_time: f64,
    /// 5: number of processors actually allocated (`-1` = missing).
    pub allocated_procs: i64,
    /// 6: average CPU time used per processor, seconds (`-1` = missing).
    pub avg_cpu_time: f64,
    /// 7: average used memory per processor, KB (`-1` = missing).
    pub used_memory_kb: f64,
    /// 8: number of processors requested (`-1` = missing).
    pub requested_procs: i64,
    /// 9: requested wall-clock runtime, seconds (`-1` = missing).
    pub requested_time: f64,
    /// 10: requested memory per processor, KB (`-1` = missing).
    pub requested_memory_kb: f64,
    /// 11: completion status — `1` completed, `0` failed, `5` cancelled,
    /// `2`–`4` partial-execution codes, `-1` unknown.
    pub status: i64,
    /// 12: user id (`-1` = missing).
    pub user_id: i64,
    /// 13: group id (`-1` = missing).
    pub group_id: i64,
    /// 14: executable (application) number (`-1` = missing).
    pub executable: i64,
    /// 15: queue number (`-1` = missing).
    pub queue: i64,
    /// 16: partition number (`-1` = missing).
    pub partition: i64,
    /// 17: preceding job number (`-1` = none).
    pub preceding_job: i64,
    /// 18: think time from the preceding job, seconds (`-1` = none).
    pub think_time: f64,
}

impl SwfJob {
    /// `user_id` without the `-1` sentinel.
    pub fn user_opt(&self) -> Option<i64> {
        (self.user_id >= 0).then_some(self.user_id)
    }

    /// `partition` without the `-1` sentinel.
    pub fn partition_opt(&self) -> Option<i64> {
        (self.partition >= 0).then_some(self.partition)
    }

    /// The runtime the simulator should bill, seconds: `run_time` when
    /// recorded, else the `requested_time` estimate; `None` when neither is
    /// a positive number (such a job cannot be replayed).
    pub fn usable_runtime(&self) -> Option<f64> {
        if self.run_time > 0.0 {
            Some(self.run_time)
        } else if self.requested_time > 0.0 {
            Some(self.requested_time)
        } else {
            None
        }
    }

    /// The processor count the MI conversion multiplies by:
    /// `allocated_procs`, else `requested_procs`, else 1.
    pub fn effective_procs(&self) -> i64 {
        if self.allocated_procs > 0 {
            self.allocated_procs
        } else if self.requested_procs > 0 {
            self.requested_procs
        } else {
            1
        }
    }
}

/// A parsed 18-column SWF file: header directives plus raw job records.
#[derive(Debug, Clone, PartialEq)]
pub struct SwfTrace {
    /// The `; Key: value` directive lines.
    pub header: SwfHeader,
    /// Every record, in file order (submit times may be out of order —
    /// published logs contain such glitches; materialization sorts by
    /// release offset).
    pub jobs: Vec<SwfJob>,
}

/// Conversion knobs for [`SwfTrace::to_trace_jobs`] / SWF-format
/// [`load_trace_file_with`].
#[derive(Debug, Clone, PartialEq)]
pub struct SwfLoadOptions {
    /// MIPS rating used to turn runtime seconds into MI
    /// (`length_mi = seconds × processors × mips`). 1.0 means "MI units are
    /// processor-seconds of the logged machine".
    pub mips: f64,
    /// Job statuses to keep; `None` = [`SWF_DEFAULT_STATUSES`] (completed +
    /// unknown).
    pub statuses: Option<Vec<i64>>,
    /// Uniform staging sizes applied to every job (SWF carries no file
    /// sizes).
    pub input_bytes: u64,
    /// See `input_bytes`.
    pub output_bytes: u64,
}

impl Default for SwfLoadOptions {
    fn default() -> SwfLoadOptions {
        SwfLoadOptions { mips: 1.0, statuses: None, input_bytes: 0, output_bytes: 0 }
    }
}

impl SwfTrace {
    /// Convert the raw records into simulator jobs: status-filter, map
    /// runtimes to MI, rebase submit offsets, and carry `user`/`partition`
    /// metadata (see the module docs for the exact rules). Errors when the
    /// filter leaves no replayable job.
    pub fn to_trace_jobs(&self, options: &SwfLoadOptions) -> Result<Vec<TraceJob>> {
        if options.mips <= 0.0 || !options.mips.is_finite() {
            bail!("swf: mips must be > 0, got {}", options.mips);
        }
        let statuses: &[i64] =
            options.statuses.as_deref().unwrap_or(SWF_DEFAULT_STATUSES);
        let kept: Vec<&SwfJob> = self
            .jobs
            .iter()
            .filter(|j| statuses.contains(&j.status))
            .filter(|j| j.usable_runtime().is_some())
            .collect();
        if kept.is_empty() {
            bail!(
                "swf trace: no replayable jobs remain of {} records (status filter {:?}, \
                 jobs without a positive run_time/requested_time are skipped)",
                self.jobs.len(),
                statuses
            );
        }
        let t0 = kept
            .iter()
            .map(|j| j.submit_time)
            .min_by(|a, b| a.total_cmp(b))
            .expect("kept is non-empty");
        Ok(kept
            .into_iter()
            .map(|j| {
                let seconds = j.usable_runtime().expect("filtered above");
                TraceJob {
                    submit_time: j.submit_time - t0,
                    length_mi: seconds * j.effective_procs() as f64 * options.mips,
                    input_bytes: options.input_bytes,
                    output_bytes: options.output_bytes,
                    user: j.user_opt(),
                    partition: j.partition_opt(),
                }
            })
            .collect())
    }
}

/// Parse an 18-column SWF file: `; Key: value` header directives, `;`/`#`
/// comments, and one 18-field record per remaining line.
pub fn parse_swf(text: &str) -> Result<SwfTrace> {
    let mut header = SwfHeader::default();
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(comment) = line.strip_prefix(';') {
            if let Some((key, value)) = comment.split_once(':') {
                let key = key.trim();
                if !key.is_empty() && key.chars().all(|c| c.is_ascii_alphanumeric()) {
                    header.directives.push((key.to_string(), value.trim().to_string()));
                }
            }
            continue;
        }
        jobs.push(
            parse_swf_record(line)
                .with_context(|| format!("swf line {}", lineno + 1))?,
        );
    }
    if jobs.is_empty() {
        bail!("swf trace holds no job records");
    }
    Ok(SwfTrace { header, jobs })
}

fn parse_swf_record(line: &str) -> Result<SwfJob> {
    let fields: Vec<&str> = line.split_whitespace().collect();
    if fields.len() != SWF_FIELDS {
        bail!("expected {SWF_FIELDS} fields, got {}", fields.len());
    }
    let num = |i: usize, what: &str| -> Result<f64> {
        let n = fields[i]
            .parse::<f64>()
            .map_err(|_| anyhow!("{what} {:?} is not a number", fields[i]))?;
        if !n.is_finite() {
            bail!("{what} must be finite, got {n}");
        }
        Ok(n)
    };
    // Integer fields: `-1` is the SWF missing-value sentinel; any other
    // negative or fractional value is a malformed record.
    let int = |i: usize, what: &str| -> Result<i64> {
        let n = num(i, what)?;
        if n.fract() != 0.0 || n < -1.0 || n >= 9_007_199_254_740_992.0 {
            bail!("{what} must be an integer >= -1, got {n}");
        }
        Ok(n as i64)
    };
    // Float duration/size fields: non-negative, or `-1` for missing.
    let dur = |i: usize, what: &str| -> Result<f64> {
        let n = num(i, what)?;
        if n < 0.0 && n != -1.0 {
            bail!("{what} must be >= 0 or the missing marker -1, got {n}");
        }
        Ok(n)
    };
    let job = SwfJob {
        job_id: int(0, "job_id")?,
        submit_time: dur(1, "submit_time")?,
        wait_time: dur(2, "wait_time")?,
        run_time: dur(3, "run_time")?,
        allocated_procs: int(4, "allocated_procs")?,
        avg_cpu_time: dur(5, "avg_cpu_time")?,
        used_memory_kb: dur(6, "used_memory_kb")?,
        requested_procs: int(7, "requested_procs")?,
        requested_time: dur(8, "requested_time")?,
        requested_memory_kb: dur(9, "requested_memory_kb")?,
        status: int(10, "status")?,
        user_id: int(11, "user_id")?,
        group_id: int(12, "group_id")?,
        executable: int(13, "executable")?,
        queue: int(14, "queue")?,
        partition: int(15, "partition")?,
        preceding_job: int(16, "preceding_job")?,
        think_time: {
            // Think time may legitimately be negative in some published
            // logs (clock skew); clamp the check to the parse level only.
            num(17, "think_time")?
        },
    };
    if job.submit_time < 0.0 {
        bail!("submit_time must be >= 0, got {}", job.submit_time);
    }
    Ok(job)
}

/// On-disk trace flavor, detected from the first data line's field count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// The toolkit's 4-column format.
    Legacy,
    /// The 18-column Standard Workload Format.
    Swf,
}

/// Detect the trace format from the first non-comment, non-empty line:
/// 4 fields → [`TraceFormat::Legacy`], 18 → [`TraceFormat::Swf`].
pub fn detect_format(text: &str) -> Result<TraceFormat> {
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with(';') || line.starts_with('#') {
            continue;
        }
        return match line.split_whitespace().count() {
            4 => Ok(TraceFormat::Legacy),
            SWF_FIELDS => Ok(TraceFormat::Swf),
            n => bail!(
                "trace data lines must have 4 fields (legacy: submit_time length_mi \
                 input_bytes output_bytes) or {SWF_FIELDS} (Standard Workload Format), \
                 got {n}"
            ),
        };
    }
    bail!("trace holds no jobs")
}

/// Load a trace file from disk, auto-detecting the format. Legacy 4-column
/// files load exactly as they always did; 18-column SWF files are converted
/// with default [`SwfLoadOptions`] (completed jobs, `mips = 1`, no
/// staging). Use [`load_trace_file_with`] to control the SWF conversion.
pub fn load_trace_file(path: impl AsRef<Path>) -> Result<Vec<TraceJob>> {
    load_trace_file_with(path, None)
}

/// [`load_trace_file`] returning the job list ready for sharing: load once,
/// then hand `Arc` clones to as many
/// [`crate::workload::WorkloadSpec::trace_selected_shared`] workloads as
/// replay the log (each with its own [`TraceSelector`] slice). For a
/// 10^5-record SWF log this is the difference between one allocation and
/// one copy per user per sweep cell.
pub fn load_trace_file_shared(path: impl AsRef<Path>) -> Result<std::sync::Arc<[TraceJob]>> {
    load_trace_file(path).map(Into::into)
}

/// [`load_trace_file`] with explicit SWF conversion options. `Some` means
/// the caller *stated* conversion knobs (even if their values match the
/// defaults): knobs only apply to 18-column files — a legacy file carries
/// per-job values for everything they control — so stated options against
/// a legacy file are rejected rather than silently ignored.
pub fn load_trace_file_with(
    path: impl AsRef<Path>,
    options: Option<&SwfLoadOptions>,
) -> Result<Vec<TraceJob>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read trace file {}: {e}", path.display()))?;
    let in_file = || format!("trace file {}", path.display());
    match detect_format(&text).with_context(in_file)? {
        TraceFormat::Legacy => {
            if options.is_some() {
                bail!(
                    "{}: mips/statuses/staging options only apply to 18-column SWF \
                     files; this legacy 4-column file carries per-job values",
                    in_file()
                );
            }
            parse_trace(&text).with_context(in_file)
        }
        TraceFormat::Swf => {
            let default = SwfLoadOptions::default();
            parse_swf(&text)
                .and_then(|swf| swf.to_trace_jobs(options.unwrap_or(&default)))
                .with_context(in_file)
        }
    }
}

// ---------------------------------------------------------------------------
// TraceSelector
// ---------------------------------------------------------------------------

/// A declarative slice of a trace: which jobs of a (typically SWF-derived)
/// job list one [`crate::workload::WorkloadSpec::Trace`] workload replays.
///
/// An empty selector keeps everything. `users`/`partitions` keep only jobs
/// whose SWF `user_id`/`partition` is listed (legacy 4-column jobs carry no
/// such metadata and never match a non-empty list — validation rejects that
/// combination loudly). `max_jobs` truncates after filtering, keeping file
/// order. Selection is pure filtering — deterministic, no RNG draws — so it
/// is sweepable (the `trace_selectors` sweep axis re-selects per cell).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSelector {
    /// Keep only these SWF user ids (empty = all users).
    pub users: Vec<i64>,
    /// Keep only these SWF partition numbers (empty = all partitions).
    pub partitions: Vec<i64>,
    /// Keep at most this many jobs, in file order, after filtering.
    pub max_jobs: Option<usize>,
}

impl TraceSelector {
    /// The everything-selector.
    pub fn all() -> TraceSelector {
        TraceSelector::default()
    }

    /// Convenience: select a single SWF user's jobs.
    pub fn user(id: i64) -> TraceSelector {
        TraceSelector { users: vec![id], ..TraceSelector::default() }
    }

    /// Convenience: select a single SWF partition's jobs.
    pub fn partition(id: i64) -> TraceSelector {
        TraceSelector { partitions: vec![id], ..TraceSelector::default() }
    }

    /// Builder: truncate to at most `n` jobs.
    pub fn with_max_jobs(mut self, n: usize) -> TraceSelector {
        self.max_jobs = Some(n);
        self
    }

    /// Does the selector keep every job unchanged?
    pub fn is_all(&self) -> bool {
        self.users.is_empty() && self.partitions.is_empty() && self.max_jobs.is_none()
    }

    /// Does `job` pass the user/partition filters?
    pub fn matches(&self, job: &TraceJob) -> bool {
        let user_ok = self.users.is_empty()
            || job.user.is_some_and(|u| self.users.contains(&u));
        let part_ok = self.partitions.is_empty()
            || job.partition.is_some_and(|p| self.partitions.contains(&p));
        user_ok && part_ok
    }

    /// The kept jobs, lazily: filter by user/partition, then truncate to
    /// `max_jobs`, preserving input order. The single source of the
    /// selection rule — [`apply`](Self::apply), [`count`](Self::count) and
    /// `WorkloadSpec::is_online` all consume this iterator, so they cannot
    /// drift apart.
    pub fn selected<'a>(
        &'a self,
        jobs: &'a [TraceJob],
    ) -> impl Iterator<Item = &'a TraceJob> + 'a {
        jobs.iter()
            .filter(move |j| self.matches(j))
            .take(self.max_jobs.unwrap_or(usize::MAX))
    }

    /// Apply the selector, cloning the kept jobs.
    pub fn apply(&self, jobs: &[TraceJob]) -> Vec<TraceJob> {
        self.selected(jobs).cloned().collect()
    }

    /// Number of jobs [`apply`](Self::apply) would keep.
    pub fn count(&self, jobs: &[TraceJob]) -> usize {
        self.selected(jobs).count()
    }

    /// Compact label for sweep CSV axis columns: `"all"`, or `·`-joined
    /// parts like `"u3"`, `"p1"`, `"max100"`.
    pub fn label(&self) -> String {
        if self.is_all() {
            return "all".to_string();
        }
        let mut parts = Vec::new();
        for u in &self.users {
            parts.push(format!("u{u}"));
        }
        for p in &self.partitions {
            parts.push(format!("p{p}"));
        }
        if let Some(n) = self.max_jobs {
            parts.push(format!("max{n}"));
        }
        parts.join("·")
    }

    /// Reject selectors that can never keep a job of `jobs` — a filter on
    /// metadata the trace does not carry, a zero truncation, or a
    /// combination that keeps nothing (the strict-loader discipline: fail
    /// at load time, not with a silently empty experiment).
    pub fn validate(&self, jobs: &[TraceJob]) -> Result<()> {
        if self.max_jobs == Some(0) {
            bail!("trace selector: max_jobs must be >= 1");
        }
        if !self.users.is_empty() && jobs.iter().all(|j| j.user.is_none()) {
            bail!(
                "trace selector names user ids {:?}, but the trace carries no user \
                 metadata (legacy 4-column traces cannot be split per user — use an \
                 18-column SWF file)",
                self.users
            );
        }
        if !self.partitions.is_empty() && jobs.iter().all(|j| j.partition.is_none()) {
            bail!(
                "trace selector names partitions {:?}, but the trace carries no \
                 partition metadata",
                self.partitions
            );
        }
        if self.count(jobs) == 0 {
            bail!(
                "trace selector {:?} keeps none of the trace's {} jobs",
                self.label(),
                jobs.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_blank_lines() {
        let text = "; SWF-ish header\n# hash comment\n\n0 10000 1000 500\n42.5 12000 0 0\n";
        let jobs = parse_trace(text).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].submit_time, 0.0);
        assert_eq!(jobs[1].submit_time, 42.5);
        assert_eq!(jobs[1].length_mi, 12_000.0);
        assert_eq!(jobs[1].input_bytes, 0);
        assert_eq!(jobs[0].user, None, "legacy jobs carry no SWF metadata");
    }

    #[test]
    fn round_trips_exactly() {
        let jobs = vec![
            TraceJob::new(0.0, 10_000.3, 1000, 500),
            TraceJob::new(17.25, 1.0 / 3.0 + 100.0, 7, 0),
        ];
        let text = format_trace(&jobs);
        let back = parse_trace(&text).unwrap();
        assert_eq!(jobs, back);
    }

    #[test]
    fn rejects_malformed_lines() {
        for (text, needle) in [
            ("1 2 3", "4 fields"),
            ("a 2 3 4", "not a number"),
            ("1 2 3.5 4", "integer"),
            ("-1 2 3 4", "submit_time"),
            ("1 0 3 4", "length_mi"),
            ("; only comments\n", "no jobs"),
        ] {
            let err = parse_trace(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn file_round_trip() {
        let jobs = vec![TraceJob::new(3.5, 500.0, 10, 20)];
        let dir = std::env::temp_dir().join("gridsim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.swf");
        std::fs::write(&path, format_trace(&jobs)).unwrap();
        assert_eq!(load_trace_file(&path).unwrap(), jobs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_error_names_path() {
        let err = load_trace_file("/no/such/trace.swf").unwrap_err();
        assert!(format!("{err:#}").contains("/no/such/trace.swf"));
    }

    // One hand-checked SWF snippet shared by the parser tests: 4 records,
    // two users, two partitions, one failed job, one with missing fields.
    const SWF: &str = "\
; Version: 2\n\
; Computer: Test Cluster\n\
; MaxNodes: 128\n\
; UnixStartTime: 845923442\n\
; Note: synthetic excerpt\n\
; free-text comment without a colon-key shape !!\n\
1 100 5 60 4 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1\n\
2 160 -1 30 -1 -1 -1 8 40 -1 1 7 1 -1 1 1 -1 -1\n\
3 200 0 45 2 -1 -1 2 -1 -1 0 3 1 -1 1 0 -1 -1\n\
4 250 1 -1 1 -1 -1 1 90 -1 -1 7 2 -1 2 1 -1 -1\n";

    #[test]
    fn swf_parses_directives_and_records() {
        let swf = parse_swf(SWF).unwrap();
        assert_eq!(swf.header.version(), Some("2"));
        assert_eq!(swf.header.computer(), Some("Test Cluster"));
        assert_eq!(swf.header.max_nodes(), Some(128));
        assert_eq!(swf.header.unix_start_time(), Some(845_923_442));
        assert_eq!(swf.header.max_procs(), None);
        assert_eq!(swf.jobs.len(), 4);
        let j = &swf.jobs[0];
        assert_eq!(j.job_id, 1);
        assert_eq!(j.submit_time, 100.0);
        assert_eq!(j.allocated_procs, 4);
        assert_eq!(j.user_opt(), Some(3));
        assert_eq!(j.partition_opt(), Some(0));
        // -1 sentinels survive parsing.
        assert_eq!(swf.jobs[1].wait_time, -1.0);
        assert_eq!(swf.jobs[1].allocated_procs, -1);
        assert_eq!(swf.jobs[3].status, -1);
    }

    #[test]
    fn swf_conversion_filters_scales_and_rebases() {
        let swf = parse_swf(SWF).unwrap();
        let jobs = swf.to_trace_jobs(&SwfLoadOptions::default()).unwrap();
        // Job 3 (status 0) is filtered; job 4 (status -1) falls back to
        // requested_time; earliest kept submit (100) rebases to 0.
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].submit_time, 0.0);
        assert_eq!(jobs[0].length_mi, 60.0 * 4.0, "run_time × allocated_procs");
        assert_eq!(jobs[1].submit_time, 60.0);
        assert_eq!(jobs[1].length_mi, 30.0 * 8.0, "missing alloc → requested_procs");
        assert_eq!(jobs[2].submit_time, 150.0);
        assert_eq!(jobs[2].length_mi, 90.0, "missing run_time → requested_time");
        assert_eq!(jobs[0].user, Some(3));
        assert_eq!(jobs[1].user, Some(7));
        assert_eq!(jobs[2].partition, Some(1));

        // mips scales MI; statuses override the default filter.
        let opts = SwfLoadOptions {
            mips: 10.0,
            statuses: Some(vec![0]),
            ..SwfLoadOptions::default()
        };
        let failed_only = swf.to_trace_jobs(&opts).unwrap();
        assert_eq!(failed_only.len(), 1);
        assert_eq!(failed_only[0].length_mi, 45.0 * 2.0 * 10.0);
        assert_eq!(failed_only[0].submit_time, 0.0, "rebased to its own earliest job");

        // Filtering everything out is a readable error, not an empty run.
        let opts = SwfLoadOptions { statuses: Some(vec![5]), ..SwfLoadOptions::default() };
        let err = swf.to_trace_jobs(&opts).unwrap_err().to_string();
        assert!(err.contains("no replayable jobs"), "{err}");
    }

    #[test]
    fn swf_rejects_malformed_records() {
        for (line, needle) in [
            ("1 2 3", "fields"),
            ("x 100 5 60 4 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1", "not a number"),
            ("1 -5 5 60 4 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1", "submit_time"),
            ("1 100 5 60 4.5 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1", "allocated_procs"),
            ("1 100 5 -2 4 -1 -1 4 120 -1 1 3 1 -1 1 0 -1 -1", "run_time"),
        ] {
            let err = parse_swf(line).unwrap_err();
            assert!(format!("{err:#}").contains(needle), "{line:?}: {err:#}");
        }
        assert!(parse_swf("; only directives\n").unwrap_err().to_string().contains("no job"));
    }

    #[test]
    fn format_detection_and_dispatch() {
        assert_eq!(detect_format("; c\n0 1 2 3\n").unwrap(), TraceFormat::Legacy);
        assert_eq!(detect_format(SWF).unwrap(), TraceFormat::Swf);
        let err = detect_format("1 2 3 4 5\n").unwrap_err().to_string();
        assert!(err.contains("4 fields") && err.contains("18"), "{err}");

        let dir = std::env::temp_dir().join("gridsim_swf_detect_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.swf");
        std::fs::write(&path, SWF).unwrap();
        let jobs = load_trace_file(&path).unwrap();
        assert_eq!(jobs.len(), 3, "auto-detected SWF conversion");
        // Stated options against a legacy file are rejected loudly — even
        // when their values happen to match the defaults (a caller who
        // wrote the knob asked for SWF conversion semantics).
        let legacy = dir.join("legacy.swf");
        std::fs::write(&legacy, "0 100 1 1\n").unwrap();
        let opts = SwfLoadOptions { mips: 2.0, ..SwfLoadOptions::default() };
        let err = load_trace_file_with(&legacy, Some(&opts)).unwrap_err().to_string();
        assert!(err.contains("legacy"), "{err}");
        let defaults = SwfLoadOptions::default();
        let err =
            load_trace_file_with(&legacy, Some(&defaults)).unwrap_err().to_string();
        assert!(err.contains("legacy"), "{err}");
        assert!(load_trace_file_with(&legacy, None).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn selector_filters_truncates_and_labels() {
        let swf = parse_swf(SWF).unwrap();
        let jobs = swf.to_trace_jobs(&SwfLoadOptions::default()).unwrap();
        assert_eq!(TraceSelector::all().apply(&jobs).len(), 3);
        assert_eq!(TraceSelector::user(3).apply(&jobs).len(), 1);
        let u7 = TraceSelector::user(7).apply(&jobs);
        assert_eq!(u7.len(), 2);
        assert_eq!(
            u7[0].submit_time, 60.0,
            "selection after the shared rebase keeps global alignment"
        );
        assert_eq!(TraceSelector::partition(1).apply(&jobs).len(), 2);
        assert_eq!(TraceSelector::user(7).with_max_jobs(1).apply(&jobs).len(), 1);
        assert_eq!(TraceSelector::user(7).count(&jobs), 2);
        assert_eq!(TraceSelector::all().label(), "all");
        assert_eq!(TraceSelector::user(7).with_max_jobs(1).label(), "u7·max1");

        // Validation: empty selections and metadata-free traces fail.
        assert!(TraceSelector::user(7).validate(&jobs).is_ok());
        let err = TraceSelector::user(99).validate(&jobs).unwrap_err().to_string();
        assert!(err.contains("keeps none"), "{err}");
        let legacy = vec![TraceJob::new(0.0, 10.0, 0, 0)];
        let err = TraceSelector::user(1).validate(&legacy).unwrap_err().to_string();
        assert!(err.contains("no user metadata"), "{err}");
        let err =
            TraceSelector::all().with_max_jobs(0).validate(&jobs).unwrap_err().to_string();
        assert!(err.contains("max_jobs"), "{err}");
    }
}
