//! SWF-style workload trace I/O.
//!
//! The format is a whitespace-separated text table, one job per line, in the
//! spirit of the Standard Workload Format (SWF) used by dslab-style
//! trace-driven simulators, reduced to the four columns this toolkit
//! simulates:
//!
//! ```text
//! ; comment (SWF convention) — '#' comments are accepted too
//! ; submit_time  length_mi  input_bytes  output_bytes
//!   0            10000      1000         500
//!   42.5         12000      1000         500
//! ```
//!
//! `submit_time` is the release offset from experiment submission (jobs with
//! offset 0 form the initial batch; later ones arrive online).
//! [`format_trace`] and [`parse_trace`] round-trip exactly: floats are
//! written in Rust's shortest-roundtrip form.

use super::spec::TraceJob;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Parse a trace from text. Empty lines and lines starting with `;` or `#`
/// are skipped; every other line must hold exactly four numeric fields.
pub fn parse_trace(text: &str) -> Result<Vec<TraceJob>> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with(';') || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 4 {
            bail!(
                "trace line {}: expected 4 fields (submit_time length_mi input_bytes \
                 output_bytes), got {}",
                lineno + 1,
                fields.len()
            );
        }
        let num = |i: usize, what: &str| -> Result<f64> {
            let n = fields[i].parse::<f64>().map_err(|_| {
                anyhow!("trace line {}: {what} {:?} is not a number", lineno + 1, fields[i])
            })?;
            if !n.is_finite() {
                bail!("trace line {}: {what} must be finite, got {n}", lineno + 1);
            }
            Ok(n)
        };
        let bytes = |i: usize, what: &str| -> Result<u64> {
            let n = num(i, what)?;
            if n >= 0.0 && n.fract() == 0.0 && n < 9_007_199_254_740_992.0 {
                Ok(n as u64)
            } else {
                bail!("trace line {}: {what} must be a non-negative integer, got {n}", lineno + 1)
            }
        };
        let job = TraceJob {
            submit_time: num(0, "submit_time")?,
            length_mi: num(1, "length_mi")?,
            input_bytes: bytes(2, "input_bytes")?,
            output_bytes: bytes(3, "output_bytes")?,
        };
        if job.submit_time < 0.0 {
            bail!("trace line {}: submit_time must be >= 0, got {}", lineno + 1, job.submit_time);
        }
        if job.length_mi <= 0.0 {
            bail!("trace line {}: length_mi must be > 0, got {}", lineno + 1, job.length_mi);
        }
        jobs.push(job);
    }
    if jobs.is_empty() {
        bail!("trace holds no jobs");
    }
    Ok(jobs)
}

/// Serialize jobs into the trace format (header comment + one line per job).
/// Floats use Rust's shortest-roundtrip formatting, so
/// `parse_trace(&format_trace(jobs))` reproduces `jobs` exactly.
pub fn format_trace(jobs: &[TraceJob]) -> String {
    let mut out = String::from("; submit_time length_mi input_bytes output_bytes\n");
    for j in jobs {
        out.push_str(&format!(
            "{} {} {} {}\n",
            j.submit_time, j.length_mi, j.input_bytes, j.output_bytes
        ));
    }
    out
}

/// Load a trace file from disk.
pub fn load_trace_file(path: impl AsRef<Path>) -> Result<Vec<TraceJob>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read trace file {}: {e}", path.display()))?;
    parse_trace(&text).with_context(|| format!("trace file {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_blank_lines() {
        let text = "; SWF-ish header\n# hash comment\n\n0 10000 1000 500\n42.5 12000 0 0\n";
        let jobs = parse_trace(text).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].submit_time, 0.0);
        assert_eq!(jobs[1].submit_time, 42.5);
        assert_eq!(jobs[1].length_mi, 12_000.0);
        assert_eq!(jobs[1].input_bytes, 0);
    }

    #[test]
    fn round_trips_exactly() {
        let jobs = vec![
            TraceJob { submit_time: 0.0, length_mi: 10_000.3, input_bytes: 1000, output_bytes: 500 },
            TraceJob {
                submit_time: 17.25,
                length_mi: 1.0 / 3.0 + 100.0,
                input_bytes: 7,
                output_bytes: 0,
            },
        ];
        let text = format_trace(&jobs);
        let back = parse_trace(&text).unwrap();
        assert_eq!(jobs, back);
    }

    #[test]
    fn rejects_malformed_lines() {
        for (text, needle) in [
            ("1 2 3", "4 fields"),
            ("a 2 3 4", "not a number"),
            ("1 2 3.5 4", "integer"),
            ("-1 2 3 4", "submit_time"),
            ("1 0 3 4", "length_mi"),
            ("; only comments\n", "no jobs"),
        ] {
            let err = parse_trace(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn file_round_trip() {
        let jobs = vec![TraceJob {
            submit_time: 3.5,
            length_mi: 500.0,
            input_bytes: 10,
            output_bytes: 20,
        }];
        let dir = std::env::temp_dir().join("gridsim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.swf");
        std::fs::write(&path, format_trace(&jobs)).unwrap();
        assert_eq!(load_trace_file(&path).unwrap(), jobs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_error_names_path() {
        let err = load_trace_file("/no/such/trace.swf").unwrap_err();
        assert!(format!("{err:#}").contains("/no/such/trace.swf"));
    }
}
