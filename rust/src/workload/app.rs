//! Workload generators.

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::random::GridSimRandom;
use crate::util::rng::Rng;

/// The paper's §5.2 application: `n` Gridlets of `base` MI with a 0–10%
/// positive random variation (default n=200, base=10 000).
pub fn paper_task_farm(n: usize, base_mi: f64, variation: f64, seed: u64) -> Vec<Gridlet> {
    let mut rand = GridSimRandom::new(seed);
    (0..n)
        .map(|i| Gridlet::new(i, rand.real(base_mi, 0.0, variation), 1000, 500))
        .collect()
}

/// A heavier-tailed mix: most jobs near `base`, a fraction `heavy_frac`
/// stretched by up to `heavy_mult`× — exercises SJF/backfilling and the
/// broker's re-planning under heterogeneous job lengths.
pub fn heavy_tailed_farm(
    n: usize,
    base_mi: f64,
    heavy_frac: f64,
    heavy_mult: f64,
    seed: u64,
) -> Vec<Gridlet> {
    assert!((0.0..=1.0).contains(&heavy_frac));
    assert!(heavy_mult >= 1.0);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut len = base_mi * rng.uniform(0.9, 1.1);
            if rng.next_f64() < heavy_frac {
                len *= rng.uniform(1.0, heavy_mult);
            }
            Gridlet::new(i, len, 1000, 500)
        })
        .collect()
}

/// Poisson arrival offsets with the given mean inter-arrival time — for
/// online (non-batch) user activity models.
pub fn poisson_arrivals(n: usize, mean_interarrival: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(mean_interarrival);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_farm_matches_spec() {
        let g = paper_task_farm(200, 10_000.0, 0.10, 1);
        assert_eq!(g.len(), 200);
        assert!(g.iter().all(|g| (10_000.0..11_000.0).contains(&g.length_mi)));
        let total: f64 = g.iter().map(|g| g.length_mi).sum();
        // Mean should sit near +5%.
        assert!((total / 200.0 - 10_500.0).abs() < 200.0);
    }

    #[test]
    fn heavy_tail_stretches_some() {
        let g = heavy_tailed_farm(500, 1_000.0, 0.1, 50.0, 2);
        let heavy = g.iter().filter(|g| g.length_mi > 2_000.0).count();
        assert!(heavy > 10, "{heavy} heavy jobs");
        assert!(heavy < 150, "{heavy} heavy jobs");
    }

    #[test]
    fn poisson_monotone_and_scaled() {
        let arr = poisson_arrivals(10_000, 5.0, 3);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let mean = arr.last().unwrap() / 10_000.0;
        assert!((mean - 5.0).abs() < 0.2, "mean gap {mean}");
    }

    #[test]
    fn deterministic_workloads() {
        let a = paper_task_farm(10, 100.0, 0.1, 9);
        let b = paper_task_farm(10, 100.0, 0.1, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.length_mi, y.length_mi);
        }
    }
}
