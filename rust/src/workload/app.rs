//! Free-function workload generators — convenience wrappers over
//! [`WorkloadSpec`](super::WorkloadSpec) for callers that want a plain
//! `Vec<Gridlet>` (or arrival offsets) without building a spec. The draw
//! streams are identical to the corresponding spec variants materialized
//! with a `GridSimRandom::new(seed)`.

use super::spec::{ArrivalProcess, WorkloadSpec};
use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::random::GridSimRandom;

/// The paper's §5.2 application: `n` Gridlets of `base` MI with a 0–10%
/// positive random variation (default n=200, base=10 000).
pub fn paper_task_farm(n: usize, base_mi: f64, variation: f64, seed: u64) -> Vec<Gridlet> {
    let mut rand = GridSimRandom::new(seed);
    WorkloadSpec::task_farm(n, base_mi, variation)
        .materialize(&mut rand)
        .into_iter()
        .map(|r| r.gridlet)
        .collect()
}

/// A heavier-tailed mix: most jobs near `base`, a fraction `heavy_frac`
/// stretched by up to `heavy_mult`× — exercises SJF/backfilling and the
/// broker's re-planning under heterogeneous job lengths.
pub fn heavy_tailed_farm(
    n: usize,
    base_mi: f64,
    heavy_frac: f64,
    heavy_mult: f64,
    seed: u64,
) -> Vec<Gridlet> {
    let mut rand = GridSimRandom::new(seed);
    WorkloadSpec::heavy_tailed(n, base_mi, heavy_frac, heavy_mult)
        .materialize(&mut rand)
        .into_iter()
        .map(|r| r.gridlet)
        .collect()
}

/// Poisson arrival offsets with the given mean inter-arrival time — for
/// online (non-batch) user activity models
/// ([`WorkloadSpec::OnlineArrivals`] wires this into a full scenario).
pub fn poisson_arrivals(n: usize, mean_interarrival: f64, seed: u64) -> Vec<f64> {
    let mut rand = GridSimRandom::new(seed);
    ArrivalProcess::Poisson { mean_interarrival }.offsets(n, rand.rng())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_farm_matches_spec() {
        let g = paper_task_farm(200, 10_000.0, 0.10, 1);
        assert_eq!(g.len(), 200);
        assert!(g.iter().all(|g| (10_000.0..11_000.0).contains(&g.length_mi)));
        let total: f64 = g.iter().map(|g| g.length_mi).sum();
        // Mean should sit near +5%.
        assert!((total / 200.0 - 10_500.0).abs() < 200.0);
    }

    #[test]
    fn heavy_tail_stretches_some() {
        let g = heavy_tailed_farm(500, 1_000.0, 0.1, 50.0, 2);
        let heavy = g.iter().filter(|g| g.length_mi > 2_000.0).count();
        assert!(heavy > 10, "{heavy} heavy jobs");
        assert!(heavy < 150, "{heavy} heavy jobs");
    }

    #[test]
    fn poisson_monotone_and_scaled() {
        let arr = poisson_arrivals(10_000, 5.0, 3);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let mean = arr.last().unwrap() / 10_000.0;
        assert!((mean - 5.0).abs() < 0.2, "mean gap {mean}");
    }

    #[test]
    fn deterministic_workloads() {
        let a = paper_task_farm(10, 100.0, 0.1, 9);
        let b = paper_task_farm(10, 100.0, 0.1, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.length_mi, y.length_mi);
        }
    }
}
