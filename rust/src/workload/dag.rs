//! DAG workflow workloads (ROADMAP item 3): a directed acyclic graph of
//! jobs where a child becomes eligible only once every parent's Gridlet has
//! completed — the scientific-workflow application model the task-farm
//! world of paper §5.2 cannot express.
//!
//! The graph is a *value*: named [`DagNode`]s plus `(parent, child)` edges
//! over those names. [`WorkloadSpec::Dag`](super::WorkloadSpec::Dag)
//! validation rejects cycles (Kahn's algorithm), duplicate node ids, and
//! dangling edge endpoints (with a did-you-mean over the declared ids)
//! before any simulation runs.
//!
//! Materialization assigns Gridlet ids `0..n` in **descending upward-rank
//! order** (HEFT's priority list, computed against the reference
//! [`RANK_MEAN_MIPS`]/[`RANK_MEAN_BANDWIDTH`] platform). Because every node
//! has positive length, a parent's rank strictly exceeds its children's, so
//! the id order is also a topological order: the broker's FIFO dispatch of
//! eligible jobs *is* list scheduling by rank, whichever
//! [`Optimization`](crate::broker::experiment::Optimization) places them.
//!
//! Release gating is cooperative (see `docs/ARCHITECTURE.md`, "Workflow
//! layer"): the user entity withholds every release that still has
//! uncompleted parents, the broker sends a 16-byte completion notice per
//! finished Gridlet, and newly eligible children travel back over the
//! contended network as ordinary `GRIDLET_ARRIVAL` events — precedence
//! rides the existing streaming path unchanged.

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::tags::DEFAULT_BAUD_RATE;
use anyhow::{bail, Result};
use std::collections::HashMap;

use super::spec::Release;

/// Reference machine rating (MIPS) used to normalize compute cost in the
/// upward-rank formula — the order of the paper's WWG testbed mean. Ranks
/// only order nodes, so the constant's scale cancels; it is fixed (rather
/// than derived from the testbed at hand) to keep materialization, and with
/// it every Gridlet id, independent of the resource set.
pub const RANK_MEAN_MIPS: f64 = 400.0;

/// Reference link bandwidth (B/s) used to normalize communication cost in
/// the upward-rank formula; the kernel's [`DEFAULT_BAUD_RATE`].
pub const RANK_MEAN_BANDWIDTH: f64 = DEFAULT_BAUD_RATE;

/// One job (node) of a [`WorkloadSpec::Dag`](super::WorkloadSpec::Dag)
/// workflow, addressed by a workflow-unique string id.
#[derive(Debug, Clone, PartialEq)]
pub struct DagNode {
    /// Workflow-unique node id (what edges reference).
    pub id: String,
    /// Processing requirement in MI.
    pub length_mi: f64,
    /// Input staging size in bytes.
    pub input_bytes: u64,
    /// Output staging size in bytes.
    pub output_bytes: u64,
}

impl DagNode {
    /// A node with the paper's staging sizes (1000 B in, 500 B out).
    pub fn new(id: impl Into<String>, length_mi: f64) -> DagNode {
        DagNode { id: id.into(), length_mi, input_bytes: 1000, output_bytes: 500 }
    }

    /// Builder: override the staging sizes.
    pub fn with_staging(mut self, input: u64, output: u64) -> DagNode {
        self.input_bytes = input;
        self.output_bytes = output;
        self
    }
}

/// Levenshtein distance (full matrix; ids are short).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

/// Did-you-mean over declared node ids (edit distance ≤ 2, ties broken by
/// declaration order).
fn nearest_id<'a>(id: &str, nodes: &'a [DagNode]) -> Option<&'a str> {
    nodes
        .iter()
        .map(|n| (edit_distance(id, &n.id), n.id.as_str()))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, s)| s)
}

/// Map node ids to their declaration index, rejecting duplicates.
fn index_of(nodes: &[DagNode]) -> Result<HashMap<&str, usize>> {
    let mut idx = HashMap::with_capacity(nodes.len());
    for (i, n) in nodes.iter().enumerate() {
        if n.id.is_empty() {
            bail!("dag node #{i}: id must not be empty");
        }
        if idx.insert(n.id.as_str(), i).is_some() {
            bail!("dag: duplicate node id {:?}", n.id);
        }
    }
    Ok(idx)
}

/// Resolve string edges to declaration-index pairs, rejecting dangling
/// endpoints (with a did-you-mean), self-loops, and duplicate edges.
fn resolve_edges(nodes: &[DagNode], edges: &[(String, String)]) -> Result<Vec<(usize, usize)>> {
    let idx = index_of(nodes)?;
    let mut resolved = Vec::with_capacity(edges.len());
    let mut seen = std::collections::HashSet::with_capacity(edges.len());
    for (parent, child) in edges {
        let lookup = |id: &str| {
            idx.get(id).copied().ok_or_else(|| match nearest_id(id, nodes) {
                Some(hint) => {
                    anyhow::anyhow!("dag edge references unknown node {id:?} (did you mean {hint:?}?)")
                }
                None => anyhow::anyhow!("dag edge references unknown node {id:?}"),
            })
        };
        let (p, c) = (lookup(parent)?, lookup(child)?);
        if p == c {
            bail!("dag: self-loop on node {parent:?}");
        }
        if !seen.insert((p, c)) {
            bail!("dag: duplicate edge {parent:?} -> {child:?}");
        }
        resolved.push((p, c));
    }
    Ok(resolved)
}

/// Kahn's algorithm over declaration indices. `Ok` is a topological order
/// (ready nodes taken in ascending declaration index, so the order is
/// deterministic); `Err` is the declaration indices left on a cycle.
fn topological_order(n: usize, edges: &[(usize, usize)]) -> std::result::Result<Vec<usize>, Vec<usize>> {
    let mut indegree = vec![0usize; n];
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(p, c) in edges {
        indegree[c] += 1;
        children[p].push(c);
    }
    let mut ready = std::collections::BinaryHeap::new();
    for (i, &d) in indegree.iter().enumerate() {
        if d == 0 {
            ready.push(std::cmp::Reverse(i));
        }
    }
    let mut order = Vec::with_capacity(n);
    while let Some(std::cmp::Reverse(i)) = ready.pop() {
        order.push(i);
        for &c in &children[i] {
            indegree[c] -= 1;
            if indegree[c] == 0 {
                ready.push(std::cmp::Reverse(c));
            }
        }
    }
    if order.len() == n {
        Ok(order)
    } else {
        Err((0..n).filter(|&i| indegree[i] > 0).collect())
    }
}

/// Validate a node/edge list: non-empty, positive lengths, unique ids,
/// resolvable edges, acyclic. Called by
/// [`WorkloadSpec::validate`](super::WorkloadSpec::validate).
pub(crate) fn validate_dag(nodes: &[DagNode], edges: &[(String, String)]) -> Result<()> {
    if nodes.is_empty() {
        bail!("dag: needs at least one node");
    }
    for n in nodes {
        if n.length_mi <= 0.0 || n.length_mi.is_nan() {
            bail!("dag node {:?}: length_mi must be > 0, got {}", n.id, n.length_mi);
        }
    }
    let resolved = resolve_edges(nodes, edges)?;
    if let Err(on_cycle) = topological_order(nodes.len(), &resolved) {
        let names: Vec<&str> = on_cycle.iter().map(|&i| nodes[i].id.as_str()).collect();
        bail!("dag: cycle through nodes {names:?}");
    }
    Ok(())
}

/// HEFT upward ranks against the reference platform, indexed by
/// declaration order:
///
/// ```text
/// rank(i) = length_mi(i)/RANK_MEAN_MIPS
///         + max over children c of
///             (output_bytes(i) + input_bytes(c))/RANK_MEAN_BANDWIDTH + rank(c)
/// ```
///
/// (exit nodes take the max over an empty set as 0). `edges` must already
/// be resolved to declaration indices and acyclic.
pub fn upward_ranks(nodes: &[DagNode], edges: &[(usize, usize)]) -> Vec<f64> {
    let order = topological_order(nodes.len(), edges).expect("ranks need an acyclic graph");
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for &(p, c) in edges {
        children[p].push(c);
    }
    let mut rank = vec![0.0f64; nodes.len()];
    for &i in order.iter().rev() {
        let tail = children[i]
            .iter()
            .map(|&c| {
                (nodes[i].output_bytes + nodes[c].input_bytes) as f64 / RANK_MEAN_BANDWIDTH
                    + rank[c]
            })
            .fold(0.0f64, f64::max);
        rank[i] = nodes[i].length_mi / RANK_MEAN_MIPS + tail;
    }
    rank
}

/// Materialize a validated workflow: Gridlet ids `0..n` in descending
/// upward-rank order (ties broken by declaration order), every release at
/// offset 0 with its `parents` rewritten to the new ids. Draws nothing from
/// the RNG stream. Panics (debug-grade backstop) on graphs
/// [`validate_dag`] would reject.
pub(crate) fn materialize_dag(nodes: &[DagNode], edges: &[(String, String)]) -> Vec<Release> {
    let resolved = resolve_edges(nodes, edges).expect("materialize after validate");
    let ranks = upward_ranks(nodes, &resolved);
    let mut order: Vec<usize> = (0..nodes.len()).collect();
    order.sort_by(|&a, &b| ranks[b].total_cmp(&ranks[a]).then(a.cmp(&b)));
    // new_id[declaration index] = rank position = Gridlet id.
    let mut new_id = vec![0usize; nodes.len()];
    for (pos, &i) in order.iter().enumerate() {
        new_id[i] = pos;
    }
    let mut parents: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for &(p, c) in &resolved {
        parents[c].push(new_id[p]);
    }
    order
        .iter()
        .map(|&i| {
            let n = &nodes[i];
            let mut ps = parents[i].clone();
            ps.sort_unstable();
            Release {
                offset: 0.0,
                parents: ps,
                gridlet: Gridlet::new(new_id[i], n.length_mi, n.input_bytes, n.output_bytes),
            }
        })
        .collect()
}

/// Parse the DOT-like workflow format the JSON loader accepts via
/// `"file"`:
///
/// ```text
/// digraph wf {
///   // node: id [length_mi=10000, input_bytes=2000, output_bytes=500]
///   stage_in [length_mi=5000];
///   a [length_mi=12000, output_bytes=4000];
///   stage_in -> a;          // edge (chains allowed: a -> b -> c)
/// }
/// ```
///
/// `length_mi` is required per node; staging sizes default to the paper's
/// 1000/500 B. `//` and `#` start line comments. The `digraph ... {`/`}`
/// wrapper is optional. Unknown attributes are rejected with a
/// did-you-mean. The graph itself is *not* validated here — callers run
/// [`WorkloadSpec::validate`](super::WorkloadSpec::validate) next, exactly
/// as for inline nodes/edges.
pub fn parse_dot(text: &str) -> Result<(Vec<DagNode>, Vec<(String, String)>)> {
    const ATTRS: [&str; 3] = ["length_mi", "input_bytes", "output_bytes"];
    let mut body = String::new();
    for line in text.lines() {
        let line = match line.find("//").into_iter().chain(line.find('#')).min() {
            Some(cut) => &line[..cut],
            None => line,
        };
        body.push_str(line);
        body.push('\n');
    }
    let body = body.trim();
    let body = match body.find('{') {
        Some(open) => {
            let head = body[..open].trim();
            if !head.is_empty() && !head.starts_with("digraph") {
                bail!("dag file: expected `digraph <name> {{`, got {head:?}");
            }
            let Some(inner) = body[open + 1..].strip_suffix('}') else {
                bail!("dag file: missing closing `}}`");
            };
            inner
        }
        None => body,
    };

    let valid_id = |s: &str| {
        !s.is_empty()
            && s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'))
    };
    let mut nodes: Vec<DagNode> = Vec::new();
    let mut edges: Vec<(String, String)> = Vec::new();
    for stmt in body.split([';', '\n']) {
        let stmt = stmt.trim();
        if stmt.is_empty() {
            continue;
        }
        if stmt.contains("->") {
            let hops: Vec<&str> = stmt.split("->").map(str::trim).collect();
            for hop in &hops {
                if !valid_id(hop) {
                    bail!("dag file: bad node id {hop:?} in edge {stmt:?}");
                }
            }
            for pair in hops.windows(2) {
                edges.push((pair[0].to_string(), pair[1].to_string()));
            }
            continue;
        }
        // Node statement: `id [k=v, ...]`.
        let (id, attrs) = match stmt.find('[') {
            Some(open) => {
                let Some(inner) = stmt[open..].strip_prefix('[').and_then(|s| s.strip_suffix(']'))
                else {
                    bail!("dag file: malformed attribute list in {stmt:?}");
                };
                (stmt[..open].trim(), inner)
            }
            None => (stmt, ""),
        };
        if !valid_id(id) {
            bail!("dag file: bad node id {id:?}");
        }
        let mut node = DagNode::new(id, 0.0);
        let mut has_length = false;
        for attr in attrs.split(',') {
            let attr = attr.trim();
            if attr.is_empty() {
                continue;
            }
            let Some((key, value)) = attr.split_once('=') else {
                bail!("dag file: node {id:?}: expected key=value, got {attr:?}");
            };
            let (key, value) = (key.trim(), value.trim());
            let num = |v: &str| {
                v.parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("dag file: node {id:?}: {key} must be a number, got {v:?}")
                })
            };
            match key {
                "length_mi" => {
                    node.length_mi = num(value)?;
                    has_length = true;
                }
                "input_bytes" => node.input_bytes = num(value)? as u64,
                "output_bytes" => node.output_bytes = num(value)? as u64,
                other => {
                    let hint = ATTRS
                        .iter()
                        .find(|a| edit_distance(other, a) <= 2)
                        .map(|a| format!(" (did you mean {a:?}?)"))
                        .unwrap_or_default();
                    bail!("dag file: node {id:?}: unknown attribute {other:?}{hint}");
                }
            }
        }
        if !has_length {
            bail!("dag file: node {id:?}: missing required length_mi attribute");
        }
        nodes.push(node);
    }
    Ok((nodes, edges))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    fn diamond() -> WorkloadSpec {
        WorkloadSpec::dag(
            vec![
                DagNode::new("a", 1000.0),
                DagNode::new("b", 2000.0),
                DagNode::new("c", 3000.0),
                DagNode::new("d", 4000.0),
            ],
            vec![
                ("a".into(), "b".into()),
                ("a".into(), "c".into()),
                ("b".into(), "d".into()),
                ("c".into(), "d".into()),
            ],
        )
    }

    #[test]
    fn diamond_validates_and_materializes_in_rank_order() {
        let spec = diamond();
        spec.validate().unwrap();
        let mut rand = crate::gridsim::random::GridSimRandom::new(7);
        let releases = spec.materialize(&mut rand);
        assert_eq!(releases.len(), 4);
        // a dominates (it heads every path); c outranks b (longer); d last.
        let ids: Vec<(usize, f64)> =
            releases.iter().map(|r| (r.gridlet.id, r.gridlet.length_mi)).collect();
        assert_eq!(
            ids,
            vec![(0, 1000.0), (1, 3000.0), (2, 2000.0), (3, 4000.0)],
            "rank order a, c, b, d"
        );
        assert_eq!(releases[0].parents, Vec::<usize>::new());
        assert_eq!(releases[1].parents, vec![0]);
        assert_eq!(releases[2].parents, vec![0]);
        assert_eq!(releases[3].parents, vec![1, 2]);
        assert!(releases.iter().all(|r| r.offset == 0.0));
    }

    #[test]
    fn materialize_draws_nothing_from_the_rng() {
        let mut a = crate::gridsim::random::GridSimRandom::new(42);
        let mut b = crate::gridsim::random::GridSimRandom::new(42);
        diamond().materialize(&mut a);
        assert_eq!(a.real(100.0, 0.0, 0.5), b.real(100.0, 0.0, 0.5));
    }

    #[test]
    fn cycle_is_rejected_with_member_names() {
        let spec = WorkloadSpec::dag(
            vec![DagNode::new("x", 1.0), DagNode::new("y", 1.0), DagNode::new("z", 1.0)],
            vec![("x".into(), "y".into()), ("y".into(), "x".into())],
        );
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
        assert!(err.contains('x') && err.contains('y'), "{err}");
        assert!(!err.contains('z'), "z is not on the cycle: {err}");
    }

    #[test]
    fn dangling_edge_gets_did_you_mean() {
        let spec = WorkloadSpec::dag(
            vec![DagNode::new("stage_in", 1.0), DagNode::new("render", 1.0)],
            vec![("stage_in".into(), "rendr".into())],
        );
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("unknown node \"rendr\""), "{err}");
        assert!(err.contains("did you mean \"render\""), "{err}");
    }

    #[test]
    fn duplicate_ids_and_edges_rejected() {
        let dup_node = WorkloadSpec::dag(
            vec![DagNode::new("a", 1.0), DagNode::new("a", 2.0)],
            vec![],
        );
        assert!(dup_node.validate().unwrap_err().to_string().contains("duplicate node id"));
        let dup_edge = WorkloadSpec::dag(
            vec![DagNode::new("a", 1.0), DagNode::new("b", 1.0)],
            vec![("a".into(), "b".into()), ("a".into(), "b".into())],
        );
        assert!(dup_edge.validate().unwrap_err().to_string().contains("duplicate edge"));
    }

    #[test]
    fn upward_ranks_follow_the_heft_recurrence() {
        // chain a -> b: rank(b) = len_b/MIPS; rank(a) = len_a/MIPS +
        // (out_a + in_b)/BW + rank(b).
        let nodes =
            vec![DagNode::new("a", 4000.0).with_staging(100, 960), DagNode::new("b", 8000.0)];
        let ranks = upward_ranks(&nodes, &[(0, 1)]);
        let rank_b = 8000.0 / RANK_MEAN_MIPS;
        let rank_a = 4000.0 / RANK_MEAN_MIPS + (960.0 + 1000.0) / RANK_MEAN_BANDWIDTH + rank_b;
        assert!((ranks[1] - rank_b).abs() < 1e-12);
        assert!((ranks[0] - rank_a).abs() < 1e-12);
    }

    #[test]
    fn dot_parser_round_trips_nodes_edges_and_comments() {
        let text = "digraph wf {\n\
                    // workflow head\n\
                    stage_in [length_mi=5000, input_bytes=2000];\n\
                    a [length_mi=12000]; b [length_mi=9000, output_bytes=4000];\n\
                    stage_in -> a -> b; # chain\n\
                    }";
        let (nodes, edges) = parse_dot(text).unwrap();
        assert_eq!(nodes.len(), 3);
        assert_eq!(nodes[0], DagNode::new("stage_in", 5000.0).with_staging(2000, 500));
        assert_eq!(nodes[2].output_bytes, 4000);
        assert_eq!(
            edges,
            vec![
                ("stage_in".to_string(), "a".to_string()),
                ("a".to_string(), "b".to_string())
            ]
        );
    }

    #[test]
    fn dot_parser_rejects_unknown_attributes_with_hint() {
        let err = parse_dot("a [lenth_mi=5]").unwrap_err().to_string();
        assert!(err.contains("unknown attribute \"lenth_mi\""), "{err}");
        assert!(err.contains("did you mean \"length_mi\""), "{err}");
        let err = parse_dot("a []").unwrap_err().to_string();
        assert!(err.contains("missing required length_mi"), "{err}");
    }
}
