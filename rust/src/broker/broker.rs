//! The broker entity — the paper's Fig 18 architecture as an event-driven
//! state machine:
//!
//! 1. experiment interface (user hands over an [`Experiment`]; online
//!    workloads extend it mid-run with `GRIDLET_ARRIVAL` events — the
//!    declared totals let Eqs 1–2 and termination account for jobs that
//!    have not arrived yet);
//! 2. resource discovery (GIS query) and trading (characteristics queries);
//! 3. scheduling flow manager: per tick, the policy produces desired job
//!    totals per resource and the broker rebalances assignments toward them
//!    (Fig 20 steps c.i/c.ii);
//! 4. dispatcher: stages Gridlets to resources, at most
//!    `MaxGridletPerPE × PEs` in flight per resource;
//! 5. receptor: accounts returned Gridlets, feeding the measured
//!    consumption rates back into step 3 ("measure and extrapolation").
//!
//! The loop ends when all Gridlets are processed or deadline/budget is
//! exceeded; like the paper's broker it then *waits* for in-flight Gridlets
//! (which is why termination can overshoot a tight deadline — Fig 34).

use super::experiment::{
    budget_from_factor, deadline_from_factor, BudgetSpec, DeadlineSpec, Experiment,
    ExperimentResult, ResourceOutcome,
};
use super::policy::{PolicyInput, SchedulingPolicy};
use super::resource_view::BrokerResource;
use super::trace::{TracePoint, TraceRecorder};
use crate::gridsim::gridlet::{Gridlet, GridletStatus};
use crate::gridsim::messages::Msg;
use crate::gridsim::pool;
use crate::gridsim::tags;
use crate::des::{Ctx, Entity, EntityId, Event};
use std::collections::{HashMap, HashSet, VecDeque};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Waiting for an experiment.
    Idle,
    /// GIS queried, waiting for the resource list.
    Discovering,
    /// Waiting for resource characteristics replies.
    Trading,
    /// Scheduling loop running.
    Scheduling,
    /// Deadline/budget exceeded: no new dispatches, waiting for in-flight
    /// Gridlets to return.
    Draining,
    /// Experiment finished and reported.
    Done,
}

/// What the broker does with a Gridlet that comes back
/// [`GridletStatus::Lost`] — in flight on a resource when it failed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ResubmissionPolicy {
    /// Return the job to the unassigned pool for another attempt, backing
    /// off from the failed resource (its [`BrokerResource`] `down_until`
    /// gate) so the zero-delay redispatch livelock on a dead resource is
    /// broken.
    RetryWithBackoff {
        /// Resubmissions allowed per Gridlet; `0` = unbounded. A job lost
        /// more than `max_attempts` times is abandoned.
        max_attempts: usize,
        /// Fixed backoff duration before the failed resource is considered
        /// again; `0.0` selects the adaptive default
        /// (`5% of remaining deadline`, clamped to `[1, 100]`).
        backoff: f64,
    },
    /// Give the job up immediately: it counts as abandoned and the
    /// experiment can terminate without it.
    Abandon,
}

impl ResubmissionPolicy {
    /// The default: retry forever with adaptive backoff (the pre-reliability
    /// broker behavior).
    pub fn default_retry() -> ResubmissionPolicy {
        ResubmissionPolicy::RetryWithBackoff { max_attempts: 0, backoff: 0.0 }
    }
}

/// Tunables for the scheduling loop.
#[derive(Debug, Clone)]
pub struct BrokerConfig {
    /// Fraction of remaining deadline used as the tick period (the paper's
    /// `GridSimHold(max(deadline_left*0.01, 1.0))` heuristic).
    pub tick_fraction: f64,
    /// Minimum tick period.
    pub min_tick: f64,
    /// Trace sampling interval (0 records every tick).
    pub trace_interval: f64,
    /// `MaxGridletPerPE` (Fig 17 uses 2).
    pub max_gridlets_per_pe: usize,
    /// What to do with Gridlets lost to resource failures.
    pub resubmission: ResubmissionPolicy,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        BrokerConfig {
            tick_fraction: 0.01,
            min_tick: 1.0,
            trace_interval: 0.0,
            max_gridlets_per_pe: 2,
            resubmission: ResubmissionPolicy::default_retry(),
        }
    }
}

/// Mid-run, pull-based view of one broker — what `GridSession::snapshot`
/// exposes to observers without downcasting or waiting for termination.
#[derive(Debug, Clone)]
pub struct BrokerProgress {
    /// Lifecycle phase label: `idle|discovering|trading|scheduling|draining|done`.
    pub state: &'static str,
    /// Gridlets finished successfully so far.
    pub gridlets_completed: usize,
    /// Total gridlets in the experiment (0 before the experiment arrives).
    pub gridlets_total: usize,
    /// G$ spent so far.
    pub budget_spent: f64,
    /// Absolute budget in effect (`f64::INFINITY` until trading completes).
    pub budget: f64,
    /// Absolute deadline in effect (`f64::INFINITY` until trading completes).
    pub deadline: f64,
    /// Gridlets dispatched and awaiting return.
    pub outstanding: usize,
    /// Gridlets not yet assigned to any resource.
    pub unassigned: usize,
    /// Per-resource load as this broker sees it.
    pub per_resource: Vec<ResourceLoad>,
}

/// Per-resource slice of a [`BrokerProgress`].
#[derive(Debug, Clone)]
pub struct ResourceLoad {
    /// Resource name as the scenario declared it.
    pub name: String,
    /// Gridlets committed (assigned + in flight) to the resource right now.
    pub committed: usize,
    /// Gridlets completed on the resource.
    pub completed: usize,
    /// G$ spent on the resource.
    pub spent: f64,
}

/// The grid resource broker entity (one per user).
pub struct Broker {
    name: String,
    gis: EntityId,
    policy: Box<dyn SchedulingPolicy>,
    config: BrokerConfig,

    state: State,
    user: EntityId,
    experiment: Option<Experiment>,
    started_at: f64,
    deadline_abs: f64,
    budget_abs: f64,

    views: Vec<BrokerResource>,
    pending_chars: usize,
    unassigned: VecDeque<Gridlet>,
    finished: Vec<Gridlet>,
    total_jobs: usize,
    total_mi: f64,
    done_mi: f64,

    /// Per-gridlet loss count (resubmission-policy bookkeeping).
    loss_counts: HashMap<usize, usize>,
    /// Gridlets returned [`GridletStatus::Lost`] (each loss counts).
    lost: usize,
    /// Lost Gridlets put back into the unassigned pool.
    resubmitted: usize,
    /// Lost Gridlets given up on (policy said stop retrying).
    abandoned: usize,
    /// Gridlets returned [`GridletStatus::Preempted`] from a spot tier.
    preempted: usize,
    /// Spot-tier resources in the scenario, as `(name, discount)` pairs —
    /// matched against characteristics replies by name.
    spot_resources: Vec<(String, f64)>,
    /// The user's spot bid in G$ per PE per time unit. `None` means the
    /// user rents on demand only (spot tiers then charge full price and
    /// never preempt this user's jobs).
    max_spot_price: Option<f64>,
    /// Gridlets preempted once: they retry on the on-demand tier only.
    spot_banned: HashSet<usize>,
    /// The experiment asked for per-Gridlet terminal notices (DAG
    /// workflows): the user is withholding precedence-gated jobs and
    /// releases/prunes them on `GRIDLET_COMPLETED`/`GRIDLET_ABANDONED`.
    /// Never set for non-DAG workloads, so those send no extra events.
    notify_completions: bool,

    last_tick: Option<u64>,
    /// Time the pending tick was scheduled *for* (dedupes the re-advise
    /// bursts caused by many Gridlets returning at one simulation instant).
    tick_at: f64,
    trace: TraceRecorder,
    /// Result kept for post-run inspection (also sent to the user).
    pub result: Option<ExperimentResult>,
}

impl Broker {
    /// Build an idle broker that will discover resources through `gis` and
    /// schedule with `policy` once its user submits an experiment.
    pub fn new(
        name: impl Into<String>,
        gis: EntityId,
        policy: Box<dyn SchedulingPolicy>,
        config: BrokerConfig,
    ) -> Broker {
        let trace = TraceRecorder::new(config.trace_interval);
        Broker {
            name: name.into(),
            gis,
            policy,
            config,
            state: State::Idle,
            user: 0,
            experiment: None,
            started_at: 0.0,
            deadline_abs: f64::INFINITY,
            budget_abs: f64::INFINITY,
            views: Vec::new(),
            pending_chars: 0,
            unassigned: VecDeque::new(),
            finished: Vec::new(),
            total_jobs: 0,
            total_mi: 0.0,
            done_mi: 0.0,
            loss_counts: HashMap::new(),
            lost: 0,
            resubmitted: 0,
            abandoned: 0,
            preempted: 0,
            spot_resources: Vec::new(),
            max_spot_price: None,
            spot_banned: HashSet::new(),
            notify_completions: false,
            last_tick: None,
            tick_at: f64::NAN,
            trace,
            result: None,
        }
    }

    /// Market wiring: which resources rent a spot tier (`(name, discount)`
    /// pairs from the scenario) and this user's spot bid. With a bid, spot
    /// views are costed at the discounted price, gated on the bid covering
    /// the current spot price, and their preempted jobs retry on demand.
    pub fn with_market(
        mut self,
        spot_resources: Vec<(String, f64)>,
        max_spot_price: Option<f64>,
    ) -> Broker {
        self.spot_resources = spot_resources;
        self.max_spot_price = max_spot_price;
        self
    }

    fn spent(&self) -> f64 {
        self.views.iter().map(|v| v.spent).sum()
    }

    fn outstanding(&self) -> usize {
        self.views.iter().map(|v| v.outstanding).sum()
    }

    fn assigned(&self) -> usize {
        self.views.iter().map(|v| v.assigned.len()).sum()
    }

    /// Mean MI of unfinished jobs (the advisor's capacity quantum).
    fn avg_job_mi(&self) -> f64 {
        let left =
            self.total_jobs.saturating_sub(self.finished.len() + self.abandoned);
        if left == 0 {
            return 1.0;
        }
        ((self.total_mi - self.done_mi) / left as f64).max(1e-9)
    }

    /// Begin the scheduling phase once trading completes (Fig 20 steps 1–4).
    fn start_scheduling(&mut self, ctx: &mut Ctx<Msg>) {
        let exp = self.experiment.as_ref().expect("experiment set");
        // Step 4: sort resources by increasing cost (G$/MI).
        self.views.sort_by(|a, b| a.cost_per_mi().total_cmp(&b.cost_per_mi()));
        for v in &mut self.views {
            v.max_gridlets_per_pe = self.config.max_gridlets_per_pe;
        }
        let infos: Vec<_> = self.views.iter().map(|v| v.info.clone()).collect();
        // Step 3: D/B factors → absolute deadline and budget (Eqs 1–2).
        self.deadline_abs = match exp.deadline {
            DeadlineSpec::Absolute(d) => self.started_at + d,
            DeadlineSpec::Factor(f) => {
                self.started_at + deadline_from_factor(f, self.total_mi, &infos)
            }
        };
        self.budget_abs = match exp.budget {
            BudgetSpec::Absolute(b) => b,
            BudgetSpec::Factor(f) => budget_from_factor(f, self.total_mi, &infos),
        };
        self.state = State::Scheduling;
        self.schedule_tick(ctx, 0.0);
    }

    fn schedule_tick(&mut self, ctx: &mut Ctx<Msg>, delay: f64) {
        self.tick_at = ctx.now() + delay;
        self.last_tick = Some(ctx.schedule_self(delay, tags::BROKER_TICK, None));
    }

    /// Re-advise promptly on new information, but at most once per
    /// simulation instant (bursts of returns share one scheduling pass).
    fn schedule_tick_now(&mut self, ctx: &mut Ctx<Msg>) {
        if self.last_tick.is_some() && self.tick_at == ctx.now() {
            return;
        }
        self.schedule_tick(ctx, 0.0);
    }

    /// One pass of the scheduling flow manager + dispatcher.
    fn run_scheduler(&mut self, ctx: &mut Ctx<Msg>) {
        let now = ctx.now();
        let over_limit = now >= self.deadline_abs || self.spent() >= self.budget_abs;
        if over_limit {
            self.enter_drain(ctx);
            return;
        }
        // SCHEDULE ADVISOR (policy): desired totals per resource. In-flight
        // Gridlets are pinned where they run — they are excluded from the
        // plan pool and their estimated cost is reserved against the budget,
        // which keeps the hard budget bound (spent ≤ budget) airtight.
        let jobs = self.unassigned.len() + self.assigned();
        let committed_cost: f64 = self.views.iter().map(|v| v.committed_cost).sum();
        let input = PolicyInput {
            views: &self.views,
            now,
            deadline: self.deadline_abs,
            budget_left: self.budget_abs - self.spent() - committed_cost,
            avg_job_mi: self.avg_job_mi(),
            jobs,
        };
        let desired = self.policy.allocate(&input);
        // Step c.ii: pull back over-assigned (not yet dispatched) jobs.
        for (r, &want) in desired.iter().enumerate() {
            let target = want.saturating_sub(self.views[r].outstanding);
            while self.views[r].assigned.len() > target {
                let g = self.views[r].assigned.pop_back().unwrap();
                self.unassigned.push_front(g);
            }
        }
        // Step c.i: feed under-assigned resources, cheapest first (views are
        // cost-sorted).
        for (r, &want) in desired.iter().enumerate() {
            let target = want.saturating_sub(self.views[r].outstanding);
            while self.views[r].assigned.len() < target {
                match self.unassigned.pop_front() {
                    Some(g) => self.views[r].assigned.push_back(g),
                    None => break,
                }
            }
        }
        // DISPATCHER: stage Gridlets, bounded per resource.
        self.dispatch(ctx);
        self.record_trace(now);
        // Infeasibility: nothing in flight, nothing assignable, jobs remain,
        // and no resource is merely in failure backoff (those may recover).
        if self.outstanding() == 0
            && self.assigned() == 0
            && !self.unassigned.is_empty()
            && desired.iter().all(|&d| d == 0)
            && self.views.iter().all(|v| v.available(now))
        {
            self.finish(ctx);
            return;
        }
        if self.check_done(ctx) {
            return;
        }
        // Paper's hold heuristic: max(deadline_left · fraction, min_tick).
        let left = (self.deadline_abs - now).max(0.0);
        let delay = (left * self.config.tick_fraction).max(self.config.min_tick);
        self.schedule_tick(ctx, delay);
    }

    fn dispatch(&mut self, ctx: &mut Ctx<Msg>) {
        let now = ctx.now();
        if now >= self.deadline_abs {
            return;
        }
        let me = ctx.me();
        let spent = self.spent();
        let mut committed: f64 = self.views.iter().map(|v| v.committed_cost).sum();
        for r in 0..self.views.len() {
            if !self.views[r].available(now) {
                continue; // failure backoff
            }
            // Spot-tier gate (only set on views when this user bid): the
            // tier is rentable only while the bid covers the current
            // discounted price, and jobs preempted once stay on demand.
            if let Some(d) = self.views[r].spot_discount {
                let spot_price = d * self.views[r].current_price;
                if self.max_spot_price.map_or(true, |bid| bid < spot_price) {
                    // Outbid: recall undispatched assignments for re-planning.
                    while let Some(g) = self.views[r].assigned.pop_back() {
                        self.unassigned.push_front(g);
                    }
                    continue;
                }
                while let Some(i) = self.views[r]
                    .assigned
                    .iter()
                    .position(|g| self.spot_banned.contains(&g.id))
                {
                    let g = self.views[r].assigned.remove(i).unwrap();
                    self.unassigned.push_front(g);
                }
            }
            let limit = self.views[r].dispatch_limit();
            while self.views[r].outstanding < limit {
                let v = &mut self.views[r];
                // Hard budget gate: never commit work whose estimated cost
                // would push actual+reserved spending past the budget.
                let next_cost = v
                    .assigned
                    .front()
                    .map(|g| v.cost_per_mi() * g.length_mi)
                    .unwrap_or(f64::INFINITY);
                if spent + committed + next_cost > self.budget_abs + 1e-9 {
                    break;
                }
                let Some(mut g) = v.assigned.pop_front() else { break };
                g.owner = me;
                g.status = GridletStatus::Created;
                // Spot jobs carry the bid so the resource can preempt them;
                // NaN marks an on-demand dispatch.
                g.max_spot_price = match (v.spot_discount, self.max_spot_price) {
                    (Some(_), Some(bid)) => bid,
                    _ => f64::NAN,
                };
                v.on_dispatched(&g, now);
                committed += next_cost;
                let dst = v.info.id;
                let msg = Msg::Gridlet(pool::boxed(g));
                let bytes = msg.wire_bytes(true);
                ctx.send(dst, tags::GRIDLET_SUBMIT, Some(msg), bytes);
            }
        }
    }

    /// How long to stay away from a resource that failed or bounced a job:
    /// the policy's fixed backoff when configured, else the adaptive default
    /// (5% of remaining deadline, clamped to `[1, 100]`).
    fn fault_backoff(&self, now: f64) -> f64 {
        match self.config.resubmission {
            ResubmissionPolicy::RetryWithBackoff { backoff, .. } if backoff > 0.0 => backoff,
            _ => ((self.deadline_abs - now) * 0.05).clamp(1.0, 100.0),
        }
    }

    /// Receptor: account a returned Gridlet (Fig 18 step 6).
    fn on_gridlet_return(&mut self, ctx: &mut Ctx<Msg>, mut g: Gridlet) {
        let rid = g.resource.expect("returned gridlet has a resource");
        let Some(r) = self.views.iter().position(|v| v.info.id == rid) else {
            panic!("return from unknown resource {rid}");
        };
        // Charge: price per PE-time × consumed PE time — at the rate in
        // effect while the work ran (market resources stamp it on the
        // Gridlet); the static traded price otherwise.
        g.cost = if g.paid_rate.is_finite() {
            g.paid_rate * g.cpu_time
        } else {
            self.views[r].info.cost_per_pe_time * g.cpu_time
        };
        match g.status {
            GridletStatus::Success => {
                self.done_mi += g.length_mi;
                self.views[r].on_completed(&g, ctx.now());
                if self.notify_completions {
                    // Workflow gating: tell the user this job is done so it
                    // can release children whose parents are all complete.
                    let id = Msg::GridletId(g.id);
                    ctx.send(self.user, tags::GRIDLET_COMPLETED, Some(id), 16);
                }
                self.finished.push(g);
            }
            GridletStatus::Lost => {
                // The resource crashed under the job: the work is gone and
                // nothing is charged. Back off from the resource (it *is*
                // down) and let the resubmission policy decide the job's
                // fate.
                self.lost += 1;
                g.cost = 0.0;
                let backoff = self.fault_backoff(ctx.now());
                self.views[r].mark_down(ctx.now(), backoff);
                self.views[r].on_returned_unfinished(&g);
                let losses = self.loss_counts.entry(g.id).or_insert(0);
                *losses += 1;
                let retry = match self.config.resubmission {
                    ResubmissionPolicy::Abandon => false,
                    ResubmissionPolicy::RetryWithBackoff { max_attempts, .. } => {
                        max_attempts == 0 || *losses <= max_attempts
                    }
                };
                if retry {
                    self.resubmitted += 1;
                    g.status = GridletStatus::Created;
                    g.resource = None;
                    self.unassigned.push_back(g);
                } else {
                    self.abandoned += 1;
                    if self.notify_completions {
                        // Workflow gating: the user prunes this job's
                        // withheld descendants and reports the count back.
                        let id = Msg::GridletId(g.id);
                        ctx.send(self.user, tags::GRIDLET_ABANDONED, Some(id), 16);
                    }
                }
            }
            GridletStatus::Failed | GridletStatus::Canceled => {
                // Fault handling: the job returns to the pool for retry on
                // another resource (partial cost of cancelled work is kept).
                if g.status == GridletStatus::Failed {
                    // Back off from the failed resource for a while (also
                    // breaks the zero-delay redispatch livelock on a dead
                    // resource under an instantaneous network).
                    let backoff = self.fault_backoff(ctx.now());
                    self.views[r].mark_down(ctx.now(), backoff);
                }
                self.views[r].on_returned_unfinished(&g);
                g.status = GridletStatus::Created;
                g.resource = None;
                g.cost = 0.0;
                self.unassigned.push_back(g);
            }
            GridletStatus::Preempted => {
                // The spot price crossed this user's bid mid-run: the partial
                // work is charged at the rate actually paid (kept in `g.cost`
                // and in the view's `spent`), the job never returns to the
                // spot tier, and the resubmission policy decides its fate on
                // the on-demand tier.
                self.preempted += 1;
                let backoff = self.fault_backoff(ctx.now());
                self.views[r].mark_down(ctx.now(), backoff);
                self.views[r].on_returned_unfinished(&g);
                self.spot_banned.insert(g.id);
                let losses = self.loss_counts.entry(g.id).or_insert(0);
                *losses += 1;
                let retry = match self.config.resubmission {
                    ResubmissionPolicy::Abandon => false,
                    ResubmissionPolicy::RetryWithBackoff { max_attempts, .. } => {
                        max_attempts == 0 || *losses <= max_attempts
                    }
                };
                if retry {
                    self.resubmitted += 1;
                    g.status = GridletStatus::Created;
                    g.resource = None;
                    g.max_spot_price = f64::NAN;
                    g.paid_rate = f64::NAN;
                    self.unassigned.push_back(g);
                } else {
                    self.abandoned += 1;
                    if self.notify_completions {
                        let id = Msg::GridletId(g.id);
                        ctx.send(self.user, tags::GRIDLET_ABANDONED, Some(id), 16);
                    }
                }
            }
            other => panic!("unexpected returned gridlet status {other:?}"),
        }
        if self.check_done(ctx) {
            return;
        }
        if self.state == State::Scheduling {
            self.schedule_tick_now(ctx);
        }
    }

    fn enter_drain(&mut self, ctx: &mut Ctx<Msg>) {
        // Stop dispatching; recall undispatched assignments.
        for r in 0..self.views.len() {
            while let Some(g) = self.views[r].assigned.pop_back() {
                self.unassigned.push_front(g);
            }
        }
        self.state = State::Draining;
        self.record_trace(ctx.now());
        self.check_done(ctx);
    }

    fn check_done(&mut self, ctx: &mut Ctx<Msg>) -> bool {
        // Abandoned Gridlets terminate with the experiment: they will never
        // finish, so waiting for them would hang the run.
        let all_done = self.finished.len() + self.abandoned == self.total_jobs;
        let drained = self.state == State::Draining && self.outstanding() == 0;
        if all_done || drained {
            self.finish(ctx);
            return true;
        }
        false
    }

    fn record_trace(&mut self, now: f64) {
        for v in &self.views {
            self.trace.record_fields(&v.info.name, now, v.completed, v.committed(), v.spent);
        }
    }

    fn resource_outcomes(&self) -> Vec<ResourceOutcome> {
        self.views
            .iter()
            .map(|v| ResourceOutcome {
                name: v.info.name.to_string(),
                gridlets_completed: v.completed,
                budget_spent: v.spent,
            })
            .collect()
    }

    fn build_result(&self, finish_time: f64) -> ExperimentResult {
        ExperimentResult {
            gridlets_completed: self.finished.len(),
            gridlets_total: self.total_jobs,
            budget_spent: self.spent(),
            finish_time,
            start_time: self.started_at,
            deadline: self.deadline_abs - self.started_at,
            budget: self.budget_abs,
            gridlets_lost: self.lost,
            gridlets_resubmitted: self.resubmitted,
            gridlets_abandoned: self.abandoned,
            gridlets_preempted: self.preempted,
            per_resource: self.resource_outcomes(),
            trace: self.trace.points().to_vec(),
        }
    }

    /// Lifecycle phase label (see [`BrokerProgress::state`]).
    pub fn state_label(&self) -> &'static str {
        match self.state {
            State::Idle => "idle",
            State::Discovering => "discovering",
            State::Trading => "trading",
            State::Scheduling => "scheduling",
            State::Draining => "draining",
            State::Done => "done",
        }
    }

    /// Has the experiment terminated (result computed and reported)?
    pub fn is_done(&self) -> bool {
        self.state == State::Done
    }

    /// Mid-run progress snapshot — safe to call at any point of the
    /// lifecycle; all numbers are the broker's real current accounting.
    pub fn progress(&self) -> BrokerProgress {
        BrokerProgress {
            state: self.state_label(),
            gridlets_completed: self.finished.len(),
            gridlets_total: self.total_jobs,
            budget_spent: self.spent(),
            budget: self.budget_abs,
            deadline: self.deadline_abs,
            outstanding: self.outstanding(),
            unassigned: self.unassigned.len(),
            per_resource: self
                .views
                .iter()
                .map(|v| ResourceLoad {
                    name: v.info.name.to_string(),
                    committed: v.committed(),
                    completed: v.completed,
                    spent: v.spent,
                })
                .collect(),
        }
    }

    /// Honest partial outcome for a run that ended (kernel limit hit) before
    /// this broker finished: real completed/spent accounting, not fabricated
    /// zeros. `finish_time` is the simulation end time; deadline/budget are
    /// 0 when trading never completed (no absolute values were derived).
    pub fn partial_result(&self, end_time: f64) -> ExperimentResult {
        let mut r = self.build_result(end_time);
        if !self.deadline_abs.is_finite() {
            r.deadline = 0.0;
        }
        if !self.budget_abs.is_finite() {
            r.budget = 0.0;
        }
        r
    }

    fn finish(&mut self, ctx: &mut Ctx<Msg>) {
        if self.state == State::Done {
            return;
        }
        self.state = State::Done;
        let now = ctx.now();
        for v in &self.views {
            self.trace.record_final(TracePoint {
                time: now,
                resource: v.info.name.to_string(),
                completed: v.completed,
                committed: v.committed(),
                spent: v.spent,
            });
        }
        let result = self.build_result(now);
        self.result = Some(result.clone());
        ctx.send(
            self.user,
            tags::EXPERIMENT_DONE,
            Some(Msg::ExperimentResult(Box::new(result))),
            512,
        );
    }
}

impl Entity<Msg> for Broker {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::EXPERIMENT => {
                assert_eq!(self.state, State::Idle, "broker already has an experiment");
                let Msg::Experiment(exp) = ev.take_data() else {
                    panic!("EXPERIMENT without payload")
                };
                self.user = ev.src;
                self.started_at = ctx.now();
                // Terminate and plan (Eqs 1–2) against the *declared* totals
                // — for an online workload these cover jobs that have not
                // arrived yet.
                self.total_jobs = exp.total_jobs;
                self.total_mi = exp.total_mi;
                self.notify_completions = exp.notify_completions;
                let mut pool: VecDeque<Gridlet> = exp.gridlets.iter().cloned().collect();
                // Online arrivals that overtook the (larger, slower on the
                // wire) experiment message were parked in `unassigned`.
                pool.extend(self.unassigned.drain(..));
                self.unassigned = pool;
                self.experiment = Some(*exp);
                self.state = State::Discovering;
                // RESOURCE DISCOVERY (Fig 20 step 1).
                ctx.send(self.gis, tags::RESOURCE_LIST, None, 16);
            }
            tags::GRIDLET_ARRIVAL => {
                let Msg::Gridlet(g) = ev.take_data() else {
                    panic!("GRIDLET_ARRIVAL without payload")
                };
                match self.state {
                    // Experiment already terminated (deadline/budget hit and
                    // drained): the job can no longer be scheduled.
                    State::Done => {}
                    // Arrival raced the experiment message on the network:
                    // park it; the EXPERIMENT handler merges the pool.
                    State::Idle => self.unassigned.push_back(pool::unbox(g)),
                    _ => {
                        self.unassigned.push_back(pool::unbox(g));
                        // Extend the plan mid-flight: re-advise promptly
                        // with the new work (Draining brokers no longer
                        // dispatch — the job just counts as unfinished).
                        if self.state == State::Scheduling {
                            self.schedule_tick_now(ctx);
                        }
                    }
                }
            }
            tags::RESOURCE_LIST => {
                let Msg::ResourceIds(ids) = ev.take_data() else {
                    panic!("RESOURCE_LIST without payload")
                };
                assert_eq!(self.state, State::Discovering);
                if ids.is_empty() {
                    // No resources in the grid: report an empty run.
                    self.deadline_abs = self.started_at;
                    self.budget_abs = 0.0;
                    self.finish(ctx);
                    return;
                }
                self.pending_chars = ids.len();
                self.state = State::Trading;
                // RESOURCE TRADING (Fig 20 step 2).
                for id in ids {
                    ctx.send(id, tags::RESOURCE_CHARACTERISTICS, None, 16);
                }
            }
            tags::RESOURCE_CHARACTERISTICS => {
                let Msg::Characteristics(info) = ev.take_data() else {
                    panic!("RESOURCE_CHARACTERISTICS without payload")
                };
                assert_eq!(self.state, State::Trading);
                let mut view = BrokerResource::new(info);
                // The spot view (discounted price, preemptible) exists only
                // for users that bid; everyone else rents on demand.
                if self.max_spot_price.is_some() {
                    if let Some((_, d)) = self
                        .spot_resources
                        .iter()
                        .find(|(n, _)| n.as_str() == &*view.info.name)
                    {
                        view.spot_discount = Some(*d);
                    }
                }
                self.views.push(view);
                self.pending_chars -= 1;
                if self.pending_chars == 0 {
                    self.start_scheduling(ctx);
                }
            }
            tags::BROKER_TICK => {
                if self.last_tick != Some(ev.seq) {
                    return; // stale tick
                }
                match self.state {
                    State::Scheduling => self.run_scheduler(ctx),
                    State::Draining => {
                        self.check_done(ctx);
                    }
                    _ => {}
                }
            }
            tags::GRIDLET_RETURN => {
                let Msg::Gridlet(g) = ev.take_data() else {
                    panic!("GRIDLET_RETURN without payload")
                };
                if self.state == State::Done {
                    return; // straggler after an empty-grid finish
                }
                self.on_gridlet_return(ctx, pool::unbox(g));
            }
            tags::GRIDLET_CANCEL_REPLY => match ev.take_data() {
                Msg::Gridlet(g) => self.on_gridlet_return(ctx, pool::unbox(g)),
                Msg::GridletId(_) => {} // already finished; return in flight
                other => panic!("unexpected cancel reply {other:?}"),
            },
            tags::PRICE_UPDATE => {
                let Msg::Price(p) = ev.take_data() else {
                    panic!("PRICE_UPDATE without payload")
                };
                if let Some(v) = self.views.iter_mut().find(|v| v.info.id == ev.src) {
                    v.current_price = p;
                    // Re-plan against the new price promptly (dedup keeps
                    // bursts of updates at one instant to a single pass).
                    if self.state == State::Scheduling {
                        self.schedule_tick_now(ctx);
                    }
                }
            }
            tags::DAG_CASCADE => {
                let Msg::Control(n) = ev.take_data() else {
                    panic!("DAG_CASCADE without a count")
                };
                if self.state == State::Done {
                    return;
                }
                // The user pruned `n` withheld descendants of an abandoned
                // workflow job: they will never arrive, so termination must
                // stop waiting for them.
                self.abandoned += n as usize;
                self.check_done(ctx);
            }
            tags::INSIGNIFICANT => {}
            other => panic!("broker {} got unexpected tag {other}", self.name),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
