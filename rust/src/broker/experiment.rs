//! `Experiment` — the user's contract with its broker (paper §4.2.1 class
//! diagram): the application (a [`WorkloadSpec`]), the optimization
//! strategy, and deadline/budget constraints given either absolutely or as
//! D-/B-factors (Eqs 1–2).

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::messages::ResourceInfo;
use crate::workload::WorkloadSpec;

/// Scheduling optimization strategy (paper §4.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimization {
    /// DBC cost-optimization: as cheap as possible within deadline+budget.
    Cost,
    /// DBC time-optimization: as fast as possible within deadline+budget.
    Time,
    /// DBC cost-time optimization [23]: cost-ordered, but resources with the
    /// same price are used in parallel like time-optimization.
    CostTime,
    /// No optimization: spread work across all resources.
    NoOpt,
    /// HEFT-style list scheduling: jobs are taken in priority order (for
    /// DAG workflows that order is the descending upward rank baked into
    /// Gridlet ids at materialization) and each is placed on the resource
    /// with the earliest estimated finish time, within deadline+budget.
    /// For non-DAG workloads this degrades gracefully to load-aware
    /// earliest-finish-time placement.
    Heft,
}

impl Optimization {
    /// Parse a policy name as the CLI/JSON spell it (`cost`, `time`,
    /// `cost-time`/`costtime`/`cost_time`, `none`/`noopt`, `heft`); `None`
    /// for anything else.
    pub fn parse(s: &str) -> Option<Optimization> {
        match s.to_ascii_lowercase().as_str() {
            "cost" => Some(Optimization::Cost),
            "time" => Some(Optimization::Time),
            "costtime" | "cost-time" | "cost_time" => Some(Optimization::CostTime),
            "none" | "noopt" => Some(Optimization::NoOpt),
            "heft" => Some(Optimization::Heft),
            _ => None,
        }
    }

    /// Canonical display name (`parse(label())` round-trips).
    pub fn label(&self) -> &'static str {
        match self {
            Optimization::Cost => "cost",
            Optimization::Time => "time",
            Optimization::CostTime => "cost-time",
            Optimization::NoOpt => "none",
            Optimization::Heft => "heft",
        }
    }
}

impl std::str::FromStr for Optimization {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Optimization::parse(s)
            .ok_or_else(|| format!("unknown policy {s:?} (cost|time|cost-time|none|heft)"))
    }
}

/// Deadline given directly or via a D-factor (Eq 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeadlineSpec {
    /// Absolute deadline in simulation time units.
    Absolute(f64),
    /// D-factor in [0, 1], resolved against the discovered resources by
    /// [`deadline_from_factor`].
    Factor(f64),
}

/// Budget given directly or via a B-factor (Eq 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetSpec {
    /// Absolute budget in G$.
    Absolute(f64),
    /// B-factor in [0, 1], resolved against the discovered resources by
    /// [`budget_from_factor`].
    Factor(f64),
}

/// Declarative experiment description (what the scenario config carries):
/// the application model plus the user's constraints.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// The application this user runs (what jobs, when they are released).
    pub workload: WorkloadSpec,
    /// Deadline constraint, absolute or as a D-factor.
    pub deadline: DeadlineSpec,
    /// Budget constraint, absolute or as a B-factor.
    pub budget: BudgetSpec,
    /// Which DBC scheduling policy the broker runs.
    pub optimization: Optimization,
}

impl ExperimentSpec {
    /// An experiment over an arbitrary workload, with D=1/B=1 factor
    /// constraints and cost optimization as the defaults.
    pub fn new(workload: WorkloadSpec) -> ExperimentSpec {
        ExperimentSpec {
            workload,
            deadline: DeadlineSpec::Factor(1.0),
            budget: BudgetSpec::Factor(1.0),
            optimization: Optimization::Cost,
        }
    }

    /// The paper's workload: `n` Gridlets of at least `base` MI with a 0–10%
    /// positive variation (§5.2).
    pub fn task_farm(n: usize, base: f64, variation: f64) -> ExperimentSpec {
        ExperimentSpec::new(WorkloadSpec::task_farm(n, base, variation))
    }

    /// Replace the workload, keeping the constraints.
    pub fn workload(mut self, workload: WorkloadSpec) -> ExperimentSpec {
        self.workload = workload;
        self
    }

    /// Override the per-job staging sizes across the whole workload.
    pub fn staging(mut self, input_bytes: u64, output_bytes: u64) -> ExperimentSpec {
        self.workload = self.workload.with_staging(input_bytes, output_bytes);
        self
    }

    /// Set an absolute deadline (simulation time units).
    pub fn deadline(mut self, d: f64) -> ExperimentSpec {
        self.deadline = DeadlineSpec::Absolute(d);
        self
    }

    /// Set an absolute budget (G$).
    pub fn budget(mut self, b: f64) -> ExperimentSpec {
        self.budget = BudgetSpec::Absolute(b);
        self
    }

    /// Set the deadline as a D-factor (Eq 1).
    pub fn d_factor(mut self, f: f64) -> ExperimentSpec {
        self.deadline = DeadlineSpec::Factor(f);
        self
    }

    /// Set the budget as a B-factor (Eq 2).
    pub fn b_factor(mut self, f: f64) -> ExperimentSpec {
        self.budget = BudgetSpec::Factor(f);
        self
    }

    /// Set the DBC scheduling policy.
    pub fn optimization(mut self, o: Optimization) -> ExperimentSpec {
        self.optimization = o;
        self
    }

    /// Number of jobs the workload declares.
    pub fn num_gridlets(&self) -> usize {
        self.workload.declared_jobs()
    }
}

/// A materialized experiment handed from the user entity to its broker.
///
/// `gridlets` holds the jobs available at submission time; under an online
/// workload more jobs follow as `GRIDLET_ARRIVAL` events. The declared
/// totals cover the *full* workload — the broker resolves D-/B-factors
/// (Eqs 1–2) and termination against them, not against the initial batch.
#[derive(Debug, Clone)]
pub struct Experiment {
    /// Jobs released at submission time (the initial batch).
    pub gridlets: Vec<Gridlet>,
    /// Total jobs across the declared workload (batch + future arrivals).
    pub total_jobs: usize,
    /// Total MI across the declared workload (the Eq 1–2 input).
    pub total_mi: f64,
    /// Deadline constraint, resolved by the broker at discovery time.
    pub deadline: DeadlineSpec,
    /// Budget constraint, resolved by the broker at discovery time.
    pub budget: BudgetSpec,
    /// Which DBC scheduling policy the broker runs.
    pub optimization: Optimization,
    /// The workload is a precedence-gated DAG workflow: the user entity is
    /// withholding child jobs, so the broker must send a
    /// [`GRIDLET_COMPLETED`](crate::gridsim::tags::GRIDLET_COMPLETED) /
    /// [`GRIDLET_ABANDONED`](crate::gridsim::tags::GRIDLET_ABANDONED)
    /// notice per terminal Gridlet. False for every non-DAG workload, and
    /// then no notice is ever sent — pre-workflow scenarios replay
    /// byte-identically.
    pub notify_completions: bool,
}

/// Per-resource outcome line (Figures 25–32 series).
#[derive(Debug, Clone)]
pub struct ResourceOutcome {
    /// Resource name as the scenario declared it.
    pub name: String,
    /// Gridlets this resource completed for the user.
    pub gridlets_completed: usize,
    /// G$ the user spent on this resource.
    pub budget_spent: f64,
}

/// What the broker returns to the user when the experiment terminates.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Gridlets that finished successfully.
    pub gridlets_completed: usize,
    /// Total gridlets in the experiment.
    pub gridlets_total: usize,
    /// G$ actually spent.
    pub budget_spent: f64,
    /// Simulation time when the experiment terminated.
    pub finish_time: f64,
    /// Time the broker received the experiment.
    pub start_time: f64,
    /// Absolute deadline in effect (after Eq 1 if a factor was given).
    pub deadline: f64,
    /// Absolute budget in effect (after Eq 2 if a factor was given).
    pub budget: f64,
    /// Gridlets returned `Lost` after a resource failed under them (each
    /// loss counts, so one job lost twice contributes 2).
    pub gridlets_lost: usize,
    /// Lost Gridlets the broker's resubmission policy put back in the pool.
    pub gridlets_resubmitted: usize,
    /// Lost Gridlets the policy gave up on (they terminate the experiment
    /// as permanently unfinished work).
    pub gridlets_abandoned: usize,
    /// Gridlets evicted from a spot tier when its price crossed the user's
    /// bid (their partial work *is* charged, unlike `gridlets_lost`).
    pub gridlets_preempted: usize,
    /// Per-resource breakdown.
    pub per_resource: Vec<ResourceOutcome>,
    /// Time-series trace (Figures 28–32).
    pub trace: Vec<super::trace::TracePoint>,
}

impl ExperimentResult {
    /// Fraction of the deadline consumed (paper Fig 23 "deadline time
    /// utilized" normalised).
    pub fn time_utilization(&self) -> f64 {
        (self.finish_time - self.start_time) / self.deadline.max(1e-12)
    }

    /// Fraction of budget consumed (Fig 24).
    pub fn budget_utilization(&self) -> f64 {
        self.budget_spent / self.budget.max(1e-12)
    }

    /// Fraction of Gridlets completed.
    pub fn completion_factor(&self) -> f64 {
        self.gridlets_completed as f64 / self.gridlets_total.max(1) as f64
    }
}

/// Eq 1: `deadline = T_min + D_factor (T_max − T_min)`.
///
/// * `T_min` — all jobs processed in parallel across every discovered
///   resource, fastest first: the aggregate-rate lower bound
///   `total_MI / Σ_r MIPS_r`.
/// * `T_max` — all jobs processed serially on the slowest resource:
///   `total_MI / min_r(per-PE MIPS_r)`.
pub fn deadline_from_factor(factor: f64, total_mi: f64, resources: &[ResourceInfo]) -> f64 {
    assert!(!resources.is_empty());
    let agg: f64 = resources.iter().map(|r| r.total_mips()).sum();
    let slowest = resources
        .iter()
        .map(|r| r.mips_per_pe)
        .min_by(|a, b| a.total_cmp(b))
        .unwrap();
    let t_min = total_mi / agg;
    let t_max = total_mi / slowest;
    t_min + factor * (t_max - t_min)
}

/// Eq 2: `budget = C_min + B_factor (C_max − C_min)`.
///
/// * `C_min` — everything on the cheapest resource: `total_MI · min_r(G$/MI)`.
/// * `C_max` — everything on the costliest resource: `total_MI · max_r(G$/MI)`.
pub fn budget_from_factor(factor: f64, total_mi: f64, resources: &[ResourceInfo]) -> f64 {
    assert!(!resources.is_empty());
    let cheapest = resources
        .iter()
        .map(|r| r.cost_per_mi())
        .min_by(|a, b| a.total_cmp(b))
        .unwrap();
    let costliest = resources
        .iter()
        .map(|r| r.cost_per_mi())
        .max_by(|a, b| a.total_cmp(b))
        .unwrap();
    let c_min = total_mi * cheapest;
    let c_max = total_mi * costliest;
    c_min + factor * (c_max - c_min)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(id: usize, pes: usize, mips: f64, price: f64) -> ResourceInfo {
        ResourceInfo {
            id,
            name: format!("R{id}").into(),
            num_pe: pes,
            mips_per_pe: mips,
            cost_per_pe_time: price,
            time_shared: true,
            time_zone: 0.0,
        }
    }

    #[test]
    fn spec_materializes_seeded_workload() {
        use crate::gridsim::random::GridSimRandom;
        let spec = ExperimentSpec::task_farm(200, 10_000.0, 0.10);
        assert_eq!(spec.num_gridlets(), 200);
        let mut r1 = GridSimRandom::new(7);
        let mut r2 = GridSimRandom::new(7);
        let g1 = spec.workload.materialize(&mut r1);
        let g2 = spec.workload.materialize(&mut r2);
        assert_eq!(g1.len(), 200);
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.gridlet.length_mi, b.gridlet.length_mi, "same seed, same workload");
        }
        // §5.2: at least 10_000 MI, up to +10%.
        assert!(g1.iter().all(|r| (10_000.0..11_000.0).contains(&r.gridlet.length_mi)));
        // And actually varied.
        assert!(g1.iter().any(|r| r.gridlet.length_mi != g1[0].gridlet.length_mi));
    }

    #[test]
    fn spec_staging_and_workload_builders() {
        let spec = ExperimentSpec::task_farm(5, 100.0, 0.0).staging(7, 8);
        let WorkloadSpec::TaskFarm { input_bytes, output_bytes, .. } = spec.workload else {
            panic!("task farm expected")
        };
        assert_eq!((input_bytes, output_bytes), (7, 8));
        let spec = ExperimentSpec::task_farm(5, 100.0, 0.0)
            .workload(WorkloadSpec::heavy_tailed(9, 100.0, 0.1, 10.0));
        assert_eq!(spec.num_gridlets(), 9);
        assert_eq!(spec.workload.label(), "heavy_tailed");
    }

    #[test]
    fn eq1_deadline_endpoints() {
        let rs = vec![info(0, 2, 100.0, 1.0), info(1, 1, 50.0, 2.0)];
        let total = 1000.0;
        // D=0 → T_min = 1000/250 = 4 ; D=1 → T_max = 1000/50 = 20.
        assert!((deadline_from_factor(0.0, total, &rs) - 4.0).abs() < 1e-12);
        assert!((deadline_from_factor(1.0, total, &rs) - 20.0).abs() < 1e-12);
        assert!((deadline_from_factor(0.5, total, &rs) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn eq2_budget_endpoints() {
        let rs = vec![info(0, 2, 100.0, 1.0), info(1, 1, 50.0, 2.0)];
        // cost/MI: 0.01 and 0.04 → C_min = 10, C_max = 40.
        let total = 1000.0;
        assert!((budget_from_factor(0.0, total, &rs) - 10.0).abs() < 1e-12);
        assert!((budget_from_factor(1.0, total, &rs) - 40.0).abs() < 1e-12);
    }

    #[test]
    fn optimization_parse_labels() {
        for (s, o) in [
            ("cost", Optimization::Cost),
            ("TIME", Optimization::Time),
            ("cost-time", Optimization::CostTime),
            ("none", Optimization::NoOpt),
            ("heft", Optimization::Heft),
        ] {
            assert_eq!(Optimization::parse(s), Some(o));
            assert_eq!(Optimization::parse(o.label()), Some(o));
        }
        assert_eq!(Optimization::parse("bogus"), None);
    }

    #[test]
    fn result_utilizations() {
        let r = ExperimentResult {
            gridlets_completed: 150,
            gridlets_total: 200,
            budget_spent: 5_000.0,
            finish_time: 1_100.0,
            start_time: 100.0,
            deadline: 2_000.0,
            budget: 10_000.0,
            gridlets_lost: 0,
            gridlets_resubmitted: 0,
            gridlets_abandoned: 0,
            gridlets_preempted: 0,
            per_resource: vec![],
            trace: vec![],
        };
        assert!((r.time_utilization() - 0.5).abs() < 1e-12);
        assert!((r.budget_utilization() - 0.5).abs() < 1e-12);
        assert!((r.completion_factor() - 0.75).abs() < 1e-12);
    }
}
