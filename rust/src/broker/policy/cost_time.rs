//! DBC **cost-time optimization** (paper [23]): like cost-optimization, but
//! resources with the *same* price are treated as one pool and used in
//! parallel (time-optimized within the group). When many resources share a
//! price this finishes sooner than pure cost-optimization at the same cost.

use super::{PolicyInput, SchedulingPolicy};

/// Cost-time optimization: cost-ordered groups, time-optimized within each.
pub struct CostTimePolicy;

impl SchedulingPolicy for CostTimePolicy {
    fn label(&self) -> &'static str {
        "cost-time"
    }

    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize> {
        let rates = input.rates();
        let job_costs = input.job_costs();
        let capacities = input.capacities();
        let avg = input.avg_job_mi.max(1e-9);
        let n = input.views.len();
        let mut counts = vec![0usize; n];
        let mut budget = input.budget_left.max(0.0);
        let mut remaining = input.jobs;

        // Group consecutive equal-cost resources (views are cost-sorted).
        let mut group_start = 0;
        while group_start < n && remaining > 0 {
            let cost0 = input.views[group_start].cost_per_mi();
            let mut group_end = group_start + 1;
            while group_end < n
                && (input.views[group_end].cost_per_mi() - cost0).abs() <= 1e-12 * (1.0 + cost0)
            {
                group_end += 1;
            }
            // Time-optimized fill inside the group.
            loop {
                if remaining == 0 {
                    break;
                }
                let mut best: Option<(usize, f64)> = None;
                for r in group_start..group_end {
                    if counts[r] >= capacities[r] || job_costs[r] > budget * (1.0 + 1e-12) + 1e-9 || rates[r] <= 0.0 {
                        continue;
                    }
                    let finish = (counts[r] + 1) as f64 * avg / rates[r];
                    if best.map(|(_, t)| finish < t).unwrap_or(true) {
                        best = Some((r, finish));
                    }
                }
                match best {
                    Some((r, _)) => {
                        counts[r] += 1;
                        budget -= job_costs[r];
                        remaining -= 1;
                    }
                    None => break,
                }
            }
            group_start = group_end;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::views;
    use super::*;

    #[test]
    fn equal_price_group_fills_in_parallel() {
        // Two same-price resources (rates 200, 100) and one expensive.
        // Pure cost-opt would pack R0 to capacity first; cost-time splits
        // the group 2:1 by rate.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 1.0), (100.0, 4, 5.0)]);
        let mut p = CostTimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 30,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc, vec![20, 10, 0], "balanced inside group, none on expensive");
    }

    #[test]
    fn spills_to_next_group_when_capacity_hit() {
        let vs = views(&[(100.0, 1, 1.0), (100.0, 1, 1.0), (100.0, 4, 5.0)]);
        let mut p = CostTimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 100.0, // group capacity: 10 + 10
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 25,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc[0] + alloc[1], 20);
        assert_eq!(alloc[2], 5);
    }

    #[test]
    fn budget_respected_across_groups() {
        let vs = views(&[(100.0, 1, 1.0), (100.0, 1, 2.0)]); // 10, 20 G$/job
        let mut p = CostTimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 100.0,
            budget_left: 110.0,
            avg_job_mi: 1000.0,
            jobs: 50,
        };
        let alloc = p.allocate(&input);
        // 10 jobs on cheap (100 G$) then budget affords nothing on expensive
        // (10 left < 20)... capacity of cheap is 10.
        assert_eq!(alloc, vec![10, 0]);
    }
}
