//! **None-optimization**: no cost or time preference — jobs are spread
//! round-robin over every discovered resource, still honouring the hard
//! deadline capacities and the budget (the "DBC constrained" part).

use super::{PolicyInput, SchedulingPolicy};

/// None-optimization: round-robin over all resources within the constraints.
pub struct NoOptPolicy;

impl SchedulingPolicy for NoOptPolicy {
    fn label(&self) -> &'static str {
        "none"
    }

    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize> {
        let capacities = input.capacities();
        let job_costs = input.job_costs();
        let n = input.views.len();
        let mut counts = vec![0usize; n];
        let mut budget = input.budget_left.max(0.0);
        let mut remaining = input.jobs;
        let mut progressed = true;
        while remaining > 0 && progressed {
            progressed = false;
            for r in 0..n {
                if remaining == 0 {
                    break;
                }
                if counts[r] < capacities[r] && job_costs[r] <= budget * (1.0 + 1e-12) + 1e-9 {
                    counts[r] += 1;
                    budget -= job_costs[r];
                    remaining -= 1;
                    progressed = true;
                }
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::views;
    use super::*;

    #[test]
    fn round_robin_even_spread() {
        let vs = views(&[(100.0, 1, 1.0), (100.0, 1, 2.0), (100.0, 1, 3.0)]);
        let mut p = NoOptPolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 9,
        };
        assert_eq!(p.allocate(&input), vec![3, 3, 3]);
    }

    #[test]
    fn capacity_and_budget_still_bind() {
        let vs = views(&[(100.0, 1, 1.0), (100.0, 1, 2.0)]); // 10, 20 G$/job
        let mut p = NoOptPolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 30.0, // capacity 3 each
            budget_left: 40.0,
            avg_job_mi: 1000.0,
            jobs: 10,
        };
        // RR: r0 (10) → r1 (20) → r0 (10) → r1 unaffordable (0 left) → stop.
        assert_eq!(p.allocate(&input), vec![2, 1]);
    }
}
