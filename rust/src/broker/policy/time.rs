//! DBC **time-optimization**: finish as early as possible within the budget —
//! spread jobs across all resources proportionally to their measured rates
//! (each job goes to the resource that would finish it soonest), instead of
//! packing the cheapest resource first.

use super::{PolicyInput, SchedulingPolicy};

/// Time-optimization: earliest predicted finish within the budget.
pub struct TimePolicy;

impl SchedulingPolicy for TimePolicy {
    fn label(&self) -> &'static str {
        "time"
    }

    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize> {
        let rates = input.rates();
        let job_costs = input.job_costs();
        let capacities = input.capacities();
        let avg = input.avg_job_mi.max(1e-9);
        let mut counts = vec![0usize; input.views.len()];
        let mut budget = input.budget_left.max(0.0);
        for _ in 0..input.jobs {
            // Pick the feasible resource with the earliest predicted finish
            // of one more job: (n_r + 1) · avg / rate_r.
            let mut best: Option<(usize, f64)> = None;
            for r in 0..counts.len() {
                if counts[r] >= capacities[r] || job_costs[r] > budget * (1.0 + 1e-12) + 1e-9 || rates[r] <= 0.0 {
                    continue;
                }
                let finish = (counts[r] + 1) as f64 * avg / rates[r];
                let better = match best {
                    None => true,
                    Some((_, t)) => {
                        finish < t - 1e-12
                            || (finish < t + 1e-12 && job_costs[r] < job_costs[best.unwrap().0])
                    }
                };
                if better {
                    best = Some((r, finish));
                }
            }
            match best {
                Some((r, _)) => {
                    counts[r] += 1;
                    budget -= job_costs[r];
                }
                None => break, // nothing feasible (deadline or budget)
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::views;
    use super::*;

    #[test]
    fn spreads_proportionally_to_rate() {
        // Rates 200 and 100 → jobs split 2:1.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let mut p = TimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 30,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc, vec![20, 10]);
    }

    #[test]
    fn uses_expensive_resources_unlike_cost_opt() {
        // Even with a relaxed deadline, time-opt uses the fast expensive
        // resource — that's the cost/time trade-off of the two policies.
        let vs = views(&[(100.0, 1, 1.0), (500.0, 4, 10.0)]);
        let mut p = TimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 21,
        };
        let alloc = p.allocate(&input);
        assert!(alloc[1] > alloc[0], "fast resource takes more: {alloc:?}");
        assert_eq!(alloc.iter().sum::<usize>(), 21);
    }

    #[test]
    fn budget_stops_allocation() {
        let vs = views(&[(100.0, 1, 1.0)]); // 10 G$/job
        let mut p = TimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 35.0,
            avg_job_mi: 1000.0,
            jobs: 10,
        };
        assert_eq!(p.allocate(&input), vec![3]);
    }

    #[test]
    fn deadline_capacity_respected() {
        let vs = views(&[(100.0, 1, 1.0)]);
        let mut p = TimePolicy;
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 50.0, // capacity = 100*50/1000 = 5
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 10,
        };
        assert_eq!(p.allocate(&input), vec![5]);
    }
}
