//! DBC scheduling policies (paper §4.2.2): cost-, time-, cost-time- and
//! none-optimization, plus HEFT-style earliest-finish-time list scheduling
//! for DAG workflows. Each policy maps broker state to *desired committed
//! job totals per resource*; the broker's scheduling flow manager then
//! rebalances assignments toward those totals and the dispatcher stages
//! Gridlets out (Fig 18 / Fig 20).

pub mod cost;
pub mod cost_time;
pub mod heft;
pub mod none;
pub mod time;

use super::experiment::Optimization;
use super::resource_view::BrokerResource;
use crate::runtime::Advisor;

/// Inputs common to every policy decision, assembled by the broker per tick.
#[derive(Debug)]
pub struct PolicyInput<'a> {
    /// Broker-side resource views, sorted by ascending G$/MI.
    pub views: &'a [BrokerResource],
    /// Current simulation time.
    pub now: f64,
    /// Absolute deadline.
    pub deadline: f64,
    /// Budget remaining after actual and committed spending.
    pub budget_left: f64,
    /// Mean MI of unfinished jobs.
    pub avg_job_mi: f64,
    /// Jobs to plan (unassigned + committed; full re-plan every tick).
    pub jobs: usize,
}

impl<'a> PolicyInput<'a> {
    /// Time remaining until the deadline (never negative).
    pub fn time_left(&self) -> f64 {
        (self.deadline - self.now).max(0.0)
    }

    /// Per-resource measured rates (Fig 20 step a).
    pub fn rates(&self) -> Vec<f64> {
        self.views.iter().map(|v| v.rate_estimate(self.now)).collect()
    }

    /// Per-resource deadline capacities in jobs (Fig 20 step b).
    pub fn capacities(&self) -> Vec<usize> {
        let t = self.time_left();
        let avg = self.avg_job_mi.max(1e-9);
        self.views
            .iter()
            .map(|v| ((v.rate_estimate(self.now) * t) / avg * (1.0 + 1e-12) + 1e-9).floor() as usize)
            .collect()
    }

    /// Per-resource estimated cost of one job in G$.
    pub fn job_costs(&self) -> Vec<f64> {
        self.views.iter().map(|v| v.cost_per_mi() * self.avg_job_mi).collect()
    }
}

/// A scheduling policy: desired committed totals per resource. `Send` so a
/// broker can migrate between the sweep engine's worker threads.
pub trait SchedulingPolicy: Send {
    /// Short policy name for reports and CSV columns.
    fn label(&self) -> &'static str;
    /// Desired committed job total per resource, indexed like `input.views`.
    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize>;
}

/// Instantiate the policy for an optimization strategy. Cost-optimization
/// takes the advisor engine (native or the AOT JAX/Pallas artifact).
pub fn make_policy(
    optimization: Optimization,
    advisor: Box<dyn Advisor>,
) -> Box<dyn SchedulingPolicy> {
    match optimization {
        Optimization::Cost => Box::new(cost::CostPolicy::new(advisor)),
        Optimization::Time => Box::new(time::TimePolicy),
        Optimization::CostTime => Box::new(cost_time::CostTimePolicy),
        Optimization::NoOpt => Box::new(none::NoOptPolicy),
        Optimization::Heft => Box::new(heft::HeftPolicy),
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::gridsim::messages::ResourceInfo;

    /// Build cost-sorted broker views from (mips_per_pe, pes, price) triples.
    pub fn views(specs: &[(f64, usize, f64)]) -> Vec<BrokerResource> {
        let mut vs: Vec<BrokerResource> = specs
            .iter()
            .enumerate()
            .map(|(i, &(mips, pes, price))| {
                BrokerResource::new(ResourceInfo {
                    id: i,
                    name: format!("R{i}").into(),
                    num_pe: pes,
                    mips_per_pe: mips,
                    cost_per_pe_time: price,
                    time_shared: true,
                    time_zone: 0.0,
                })
            })
            .collect();
        vs.sort_by(|a, b| a.cost_per_mi().total_cmp(&b.cost_per_mi()));
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::views;
    use super::*;

    #[test]
    fn input_helpers() {
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let input = PolicyInput {
            views: &vs,
            now: 10.0,
            deadline: 110.0,
            budget_left: 1000.0,
            avg_job_mi: 1000.0,
            jobs: 10,
        };
        assert_eq!(input.time_left(), 100.0);
        // Optimistic rates = total MIPS.
        assert_eq!(input.rates(), vec![200.0, 100.0]);
        // Capacities: 200*100/1000=20, 100*100/1000=10.
        assert_eq!(input.capacities(), vec![20, 10]);
        // Job costs: (1/100)*1000=10, (2/100)*1000=20.
        assert_eq!(input.job_costs(), vec![10.0, 20.0]);
    }

    #[test]
    fn factory_builds_each_policy() {
        use crate::runtime::NativeAdvisor;
        for (o, label) in [
            (Optimization::Cost, "cost"),
            (Optimization::Time, "time"),
            (Optimization::CostTime, "cost-time"),
            (Optimization::NoOpt, "none"),
            (Optimization::Heft, "heft"),
        ] {
            let p = make_policy(o, Box::new(NativeAdvisor::new()));
            assert_eq!(p.label(), label);
        }
    }
}
