//! DBC **cost-optimization** (paper Fig 20): process jobs as economically as
//! possible within the deadline and budget — fill the cheapest resources to
//! their deadline capacity first.
//!
//! The numeric allocation is delegated to an [`Advisor`]: either the
//! pure-Rust sequential greedy or the AOT-compiled JAX/Pallas artifact
//! running through PJRT (`--advisor xla`). Both produce identical
//! allocations (see `rust/tests/xla_advisor.rs`).

use super::{PolicyInput, SchedulingPolicy};
use crate::runtime::{Advisor, AdvisorInput, ResourceSnapshot};

/// Cost-optimization: cheapest resources filled to deadline capacity first.
pub struct CostPolicy {
    advisor: Box<dyn Advisor>,
}

impl CostPolicy {
    /// Cost policy backed by the given allocation engine.
    pub fn new(advisor: Box<dyn Advisor>) -> CostPolicy {
        CostPolicy { advisor }
    }
}

impl SchedulingPolicy for CostPolicy {
    fn label(&self) -> &'static str {
        "cost"
    }

    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize> {
        let snapshots: Vec<ResourceSnapshot> = input
            .views
            .iter()
            .map(|v| ResourceSnapshot {
                rate_mi: v.rate_estimate(input.now),
                cost_per_mi: v.cost_per_mi(),
            })
            .collect();
        let adv_input = AdvisorInput {
            resources: snapshots,
            time_left: input.time_left(),
            budget_left: input.budget_left,
            avg_job_mi: input.avg_job_mi,
            jobs: input.jobs,
        };
        self.advisor.advise(&adv_input)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::views;
    use super::*;
    use crate::runtime::NativeAdvisor;

    #[test]
    fn fills_cheapest_first() {
        // R0 (sorted first): 200 MIPS aggregate at 0.01 G$/MI, capacity 20.
        // R1: 100 MIPS at 0.02 G$/MI.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let mut p = CostPolicy::new(Box::new(NativeAdvisor::new()));
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 100.0,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 25,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc, vec![20, 5], "cheapest to capacity, spill to next");
    }

    #[test]
    fn relaxed_deadline_uses_only_cheapest() {
        // Paper Fig 27: with a very relaxed deadline the cheapest resource
        // absorbs everything.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let mut p = CostPolicy::new(Box::new(NativeAdvisor::new()));
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs: 200,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc, vec![200, 0]);
    }

    #[test]
    fn budget_limits_expensive_spill() {
        // Cheap capacity 2 jobs at 10 G$; expensive at 20 G$/job.
        // Budget 45 → 2 cheap (20) + 1 expensive (20) = 40; next would be 60.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let mut p = CostPolicy::new(Box::new(NativeAdvisor::new()));
        let input = PolicyInput {
            views: &vs,
            now: 0.0,
            deadline: 10.0, // capacity: 2000/1000=2 cheap, 1000/1000=1 expensive
            budget_left: 45.0,
            avg_job_mi: 1000.0,
            jobs: 50,
        };
        let alloc = p.allocate(&input);
        assert_eq!(alloc, vec![2, 1]);
    }
}
