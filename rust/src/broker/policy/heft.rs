//! HEFT-style list scheduling: take jobs in priority order and place each
//! on the resource with the **earliest estimated finish time**, within the
//! deadline and budget.
//!
//! The classic HEFT split lives in two places here. The *priority list*
//! (descending upward rank) is baked into Gridlet ids when a DAG workflow
//! materializes ([`crate::workload::dag`]), and the broker's FIFO pool
//! preserves it — so by the time this policy runs, "next job" already means
//! "highest-ranked eligible job". The *processor selection* happens here:
//! unlike [`TimePolicy`](super::time::TimePolicy), the finish estimate
//! starts from the work already in flight on each resource (its
//! [`outstanding`](crate::broker::resource_view::BrokerResource::outstanding)
//! count), so a resource busy with a long parent is passed over even when
//! its raw rate wins. For non-DAG workloads nothing refers to ranks at all
//! and the policy degrades gracefully to load-aware earliest-finish-time
//! placement.

use super::{PolicyInput, SchedulingPolicy};

/// HEFT-style earliest-finish-time placement (see the module docs).
pub struct HeftPolicy;

impl SchedulingPolicy for HeftPolicy {
    fn label(&self) -> &'static str {
        "heft"
    }

    fn allocate(&mut self, input: &PolicyInput) -> Vec<usize> {
        let rates = input.rates();
        let job_costs = input.job_costs();
        let capacities = input.capacities();
        let avg = input.avg_job_mi.max(1e-9);
        // Desired totals are *committed* totals (the broker subtracts
        // outstanding when it rebalances assigned queues), so the load on
        // each resource starts at its in-flight count — that's the
        // "earliest start time" half of the EFT estimate.
        let mut counts: Vec<usize> = input.views.iter().map(|v| v.outstanding).collect();
        let mut budget = input.budget_left.max(0.0);
        for _ in 0..input.jobs {
            // EFT of one more job on r: (n_r + 1) · avg / rate_r with n_r
            // counting both planned and in-flight work; ties go cheaper.
            let mut best: Option<(usize, f64)> = None;
            for r in 0..counts.len() {
                if counts[r] >= capacities[r]
                    || job_costs[r] > budget * (1.0 + 1e-12) + 1e-9
                    || rates[r] <= 0.0
                {
                    continue;
                }
                let finish = (counts[r] + 1) as f64 * avg / rates[r];
                let better = match best {
                    None => true,
                    Some((b, t)) => {
                        finish < t - 1e-12 || (finish < t + 1e-12 && job_costs[r] < job_costs[b])
                    }
                };
                if better {
                    best = Some((r, finish));
                }
            }
            match best {
                Some((r, _)) => {
                    counts[r] += 1;
                    budget -= job_costs[r];
                }
                None => break, // nothing feasible (deadline or budget)
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::views;
    use super::*;

    fn input<'a>(
        views: &'a [crate::broker::resource_view::BrokerResource],
        jobs: usize,
    ) -> PolicyInput<'a> {
        PolicyInput {
            views,
            now: 0.0,
            deadline: 1e6,
            budget_left: 1e9,
            avg_job_mi: 1000.0,
            jobs,
        }
    }

    #[test]
    fn spreads_by_earliest_finish_when_idle() {
        // Idle and equal-priced: behaves like time-opt, 2:1 by rate.
        let vs = views(&[(100.0, 2, 1.0), (100.0, 1, 2.0)]);
        let alloc = HeftPolicy.allocate(&input(&vs, 30));
        assert_eq!(alloc, vec![20, 10]);
    }

    #[test]
    fn inflight_work_delays_a_resource() {
        // Equal rates, but the cheap resource already runs 4 jobs: the
        // first new placements go to the idle one, and the returned totals
        // include the in-flight load.
        let mut vs = views(&[(100.0, 1, 1.0), (100.0, 1, 2.0)]);
        vs[0].outstanding = 4;
        let alloc = HeftPolicy.allocate(&input(&vs, 4));
        assert_eq!(alloc, vec![4 + 0, 4], "all 4 new jobs go to the idle resource");
    }

    #[test]
    fn ties_prefer_the_cheaper_resource() {
        let vs = views(&[(100.0, 1, 1.0), (100.0, 1, 2.0)]);
        let alloc = HeftPolicy.allocate(&input(&vs, 1));
        assert_eq!(alloc, vec![1, 0]);
    }

    #[test]
    fn budget_and_capacity_gates_hold() {
        let vs = views(&[(100.0, 1, 1.0)]); // 10 G$/job
        let mut i = input(&vs, 10);
        i.budget_left = 35.0;
        assert_eq!(HeftPolicy.allocate(&i), vec![3]);
        let mut i = input(&vs, 10);
        i.deadline = 50.0; // capacity = 100*50/1000 = 5
        assert_eq!(HeftPolicy.allocate(&i), vec![5]);
    }
}
