//! Time-series trace of broker activity — the raw series behind the paper's
//! Figures 28–32 (Gridlets completed / budget spent / Gridlets committed per
//! resource over time).

/// One sampled point of broker state for one resource.
#[derive(Debug, Clone)]
pub struct TracePoint {
    /// Simulation time of the sample.
    pub time: f64,
    /// Resource name (Table 2 ids: "R0".."R10").
    pub resource: String,
    /// Gridlets completed on this resource so far (Figs 28, 30).
    pub completed: usize,
    /// Gridlets currently committed (assigned + dispatched, not returned) —
    /// the paper's "Gridlets committed" series (Figs 31–32).
    pub committed: usize,
    /// Budget spent on this resource so far in G$ (Fig 29).
    pub spent: f64,
}

/// Trace recorder with change-detection and uniform down-sampling to bound
/// memory (and hot-loop cost: the broker ticks far more often than its
/// per-resource state changes).
#[derive(Debug, Clone, Default)]
pub struct TraceRecorder {
    points: Vec<TracePoint>,
    /// Minimum spacing between samples of the same resource (0 = every
    /// *change*).
    min_interval: f64,
    /// Per-resource (last-sample-time, completed, committed, spent).
    last_sample: std::collections::HashMap<String, (f64, usize, usize, f64)>,
}

impl TraceRecorder {
    /// Recorder sampling at most once per `min_interval` per resource
    /// (0 records every state change).
    pub fn new(min_interval: f64) -> TraceRecorder {
        TraceRecorder {
            points: Vec::new(),
            min_interval,
            last_sample: std::collections::HashMap::new(),
        }
    }

    /// Offer a sample; kept only if the state changed and the resource's
    /// sampling interval has elapsed.
    pub fn record(&mut self, point: TracePoint) {
        self.record_fields(&point.resource, point.time, point.completed, point.committed, point.spent);
    }

    /// Allocation-free fast path: the hot loop passes borrowed fields and a
    /// `TracePoint` (with its `String`) is only built when a sample is
    /// actually kept.
    pub fn record_fields(
        &mut self,
        resource: &str,
        time: f64,
        completed: usize,
        committed: usize,
        spent: f64,
    ) {
        if let Some(&(last_t, c0, k0, s0)) = self.last_sample.get(resource) {
            // Unchanged state never produces a new point; changed state is
            // further rate-limited by `min_interval`.
            if completed == c0 && committed == k0 && (spent - s0).abs() < 1e-12 {
                return;
            }
            if time - last_t < self.min_interval {
                return;
            }
        }
        self.last_sample.insert(resource.to_string(), (time, completed, committed, spent));
        self.points.push(TracePoint {
            time,
            resource: resource.to_string(),
            completed,
            committed,
            spent,
        });
    }

    /// Force-record (final state) regardless of the sampling interval.
    pub fn record_final(&mut self, point: TracePoint) {
        self.points.push(point);
    }

    /// The kept samples, in record order.
    pub fn points(&self) -> &[TracePoint] {
        &self.points
    }

    /// Consume the recorder, returning the kept samples.
    pub fn into_points(self) -> Vec<TracePoint> {
        self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(time: f64, res: &str, completed: usize) -> TracePoint {
        TracePoint { time, resource: res.into(), completed, committed: 0, spent: 0.0 }
    }

    #[test]
    fn downsamples_per_resource() {
        let mut t = TraceRecorder::new(10.0);
        t.record(pt(0.0, "R0", 0));
        t.record(pt(5.0, "R0", 1)); // dropped: changed but too close
        t.record(pt(5.0, "R1", 0)); // kept: different resource
        t.record(pt(12.0, "R0", 2)); // kept
        assert_eq!(t.points().len(), 3);
    }

    #[test]
    fn unchanged_state_not_recorded() {
        let mut t = TraceRecorder::new(0.0);
        t.record(pt(0.0, "R0", 0));
        for i in 1..50 {
            t.record(pt(i as f64, "R0", 0)); // no change → dropped
        }
        t.record(pt(50.0, "R0", 3));
        assert_eq!(t.points().len(), 2);
    }

    #[test]
    fn final_always_kept() {
        let mut t = TraceRecorder::new(100.0);
        t.record(pt(0.0, "R0", 0));
        t.record_final(pt(1.0, "R0", 0));
        assert_eq!(t.points().len(), 2);
    }

    #[test]
    fn zero_interval_keeps_every_change() {
        let mut t = TraceRecorder::new(0.0);
        for i in 0..50 {
            t.record(pt(i as f64 * 0.001, "R0", i));
        }
        assert_eq!(t.points().len(), 50);
    }
}
