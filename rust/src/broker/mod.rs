//! The economic grid resource broker (paper §4.2, Fig 18): a Nimrod-G-like,
//! per-user scheduling entity implementing deadline-and-budget-constrained
//! (DBC) scheduling with cost-, time-, cost-time- and none-optimization
//! policies.

pub mod broker;
pub mod experiment;
pub mod policy;
pub mod resource_view;
pub mod trace;
pub mod user;

pub use broker::{Broker, BrokerConfig, BrokerProgress, ResourceLoad, ResubmissionPolicy};
pub use experiment::{
    BudgetSpec, DeadlineSpec, Experiment, ExperimentResult, ExperimentSpec, Optimization,
};
pub use resource_view::BrokerResource;
pub use trace::TracePoint;
pub use user::UserEntity;
