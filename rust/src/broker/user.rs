//! `UserEntity` (paper §4.2.1): owns an experiment, hands it to its private
//! broker, records statistics when the results come back, and notifies the
//! shutdown entity when it has no more processing requirements.
//!
//! The user is also the *release point* of online application models: a
//! workload whose jobs carry positive release offsets (trace replay, Poisson
//! or fixed-interval arrivals) is materialized up front, but only the
//! offset-0 batch ships with the experiment. The rest are held by the user
//! and streamed to the broker as `GRIDLET_ARRIVAL` events when their release
//! time comes (internal `USER_TICK` wake-ups), so the broker re-plans
//! mid-flight instead of assuming a closed batch.
//!
//! DAG workflows ride the same streaming path, gated by *precedence* rather
//! than time: a release whose [`Release::parents`](crate::workload::Release)
//! list is non-empty is withheld here, the broker sends a
//! `GRIDLET_COMPLETED` notice per finished workflow Gridlet, and children
//! whose last parent just completed travel back as ordinary
//! `GRIDLET_ARRIVAL` events — through the contended network, like any other
//! online job. When the broker abandons a job (`GRIDLET_ABANDONED`), its
//! withheld descendants can never become eligible: they are pruned and the
//! count reported back (`DAG_CASCADE`) so termination accounting stays
//! exact.

use super::experiment::{Experiment, ExperimentResult, ExperimentSpec};
use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::messages::Msg;
use crate::gridsim::pool;
use crate::gridsim::random::GridSimRandom;
use crate::gridsim::statistics::StatRecord;
use crate::gridsim::tags;
use crate::des::{Ctx, Entity, EntityId, Event};
use std::collections::{HashMap, VecDeque};

/// Wire size of one online job-arrival message (job metadata; input staging
/// is charged on broker→resource dispatch, as for batch jobs).
const ARRIVAL_BYTES: u64 = 128;

/// A grid user with one experiment.
pub struct UserEntity {
    name: String,
    broker: EntityId,
    shutdown: EntityId,
    stats: Option<EntityId>,
    spec: ExperimentSpec,
    seed: u64,
    /// Activity model: delay before the experiment is submitted (paper:
    /// users differ in activity rate / time zone).
    submit_delay: f64,
    /// Jobs not yet released, as (absolute release time, gridlet) in
    /// release order. A single outstanding `USER_TICK` is armed for the
    /// front entry and re-armed after each pop — O(1) queued ticks no
    /// matter how large the online workload is.
    pending: VecDeque<(f64, Gridlet)>,
    /// Precedence-withheld workflow jobs: Gridlet id → (job, number of
    /// parents not yet reported complete). Released when the count hits 0.
    held: HashMap<usize, (Gridlet, usize)>,
    /// Forward workflow edges over withheld jobs: parent Gridlet id → child
    /// ids, in ascending child-id (= descending upward-rank) order.
    children: HashMap<usize, Vec<usize>>,
    /// Outcome, for post-run inspection.
    pub result: Option<ExperimentResult>,
}

impl UserEntity {
    /// Build a user that materializes `spec` with `seed` and drives the
    /// given broker, reporting to `shutdown` when its experiment ends.
    pub fn new(
        name: impl Into<String>,
        broker: EntityId,
        shutdown: EntityId,
        spec: ExperimentSpec,
        seed: u64,
    ) -> UserEntity {
        UserEntity {
            name: name.into(),
            broker,
            shutdown,
            stats: None,
            spec,
            seed,
            submit_delay: 0.0,
            pending: VecDeque::new(),
            held: HashMap::new(),
            children: HashMap::new(),
            result: None,
        }
    }

    /// Report the paper's Fig 15 statistics categories to `stats` when the
    /// experiment finishes.
    pub fn with_stats(mut self, stats: EntityId) -> UserEntity {
        self.stats = Some(stats);
        self
    }

    /// Delay the experiment submission (the paper's user activity model).
    pub fn with_submit_delay(mut self, delay: f64) -> UserEntity {
        assert!(delay >= 0.0);
        self.submit_delay = delay;
        self
    }

    /// Jobs materialized but not yet released to the broker (time-pending
    /// online jobs plus precedence-withheld workflow jobs).
    pub fn pending_releases(&self) -> usize {
        self.pending.len() + self.held.len()
    }
}

impl Entity<Msg> for UserEntity {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        // Materialize the application (seeded per user: "seed*997*(1+i)+1"
        // in the paper's Fig 15 — any per-user derivation works; ours is the
        // user seed itself, derived by the scenario builder).
        let mut rand = GridSimRandom::new(self.seed);
        let releases = self.spec.workload.materialize(&mut rand);
        let total_jobs = releases.len();
        let total_mi: f64 = releases.iter().map(|r| r.gridlet.length_mi).sum();
        let notify_completions = releases.iter().any(|r| !r.parents.is_empty());
        let mut batch = Vec::new();
        for r in releases {
            if !r.parents.is_empty() {
                // Precedence-gated: withheld until every parent's Gridlet
                // is reported complete, whatever the offset says.
                for &p in &r.parents {
                    self.children.entry(p).or_default().push(r.gridlet.id);
                }
                self.held.insert(r.gridlet.id, (r.gridlet, r.parents.len()));
            } else if r.offset <= 0.0 {
                batch.push(r.gridlet);
            } else {
                // Releases are offset-sorted, so pending stays front-first
                // in release order (on_start runs at t=0, so the stored
                // time is absolute).
                self.pending.push_back((self.submit_delay + r.offset, r.gridlet));
            }
        }
        if let Some(&(t, _)) = self.pending.front() {
            ctx.schedule_self(t, tags::USER_TICK, None);
        }
        let experiment = Experiment {
            gridlets: batch,
            total_jobs,
            total_mi,
            deadline: self.spec.deadline,
            budget: self.spec.budget,
            optimization: self.spec.optimization,
            notify_completions,
        };
        let msg = Msg::Experiment(Box::new(experiment));
        let bytes = msg.wire_bytes(true);
        if self.submit_delay > 0.0 {
            ctx.send_delayed(self.broker, self.submit_delay, tags::EXPERIMENT, Some(msg));
        } else {
            ctx.send(self.broker, tags::EXPERIMENT, Some(msg), bytes);
        }
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::EXPERIMENT_DONE => {
                let Msg::ExperimentResult(result) = ev.take_data() else {
                    panic!("EXPERIMENT_DONE without payload")
                };
                // Record the paper's report-writer categories (Fig 15).
                if let Some(stats) = self.stats {
                    for (cat, value) in [
                        ("USER.TimeUtilization", result.time_utilization()),
                        ("USER.GridletCompletionFactor", result.completion_factor()),
                        ("USER.BudgetUtilization", result.budget_utilization()),
                    ] {
                        let rec = StatRecord {
                            time: ctx.now(),
                            category: format!("{}.{cat}", self.name).into(),
                            label: self.name.clone(),
                            value,
                        };
                        ctx.send(stats, tags::RECORD_STATISTICS, Some(Msg::Stat(rec)), 48);
                    }
                }
                self.result = Some(*result);
                // The broker reported (deadline/budget hit); unreleased jobs
                // have nowhere to go.
                self.pending.clear();
                self.held.clear();
                self.children.clear();
                // No more processing requirements → tell the shutdown entity.
                ctx.send(self.shutdown, tags::END_OF_SIMULATION, None, 16);
            }
            tags::USER_TICK => {
                // Release the next online job, then re-arm the timer for the
                // one after it. The experiment may already be over (pending
                // cleared) — the at-most-one stale tick is a no-op.
                if let Some((_, g)) = self.pending.pop_front() {
                    let msg = Msg::Gridlet(pool::boxed(g));
                    ctx.send(self.broker, tags::GRIDLET_ARRIVAL, Some(msg), ARRIVAL_BYTES);
                    if let Some(&(t, _)) = self.pending.front() {
                        ctx.schedule_self((t - ctx.now()).max(0.0), tags::USER_TICK, None);
                    }
                }
            }
            tags::GRIDLET_COMPLETED => {
                let Msg::GridletId(id) = ev.take_data() else {
                    panic!("GRIDLET_COMPLETED without a Gridlet id")
                };
                // One parent done: decrement its children's unmet counts and
                // release the now-eligible ones in ascending-id (descending
                // upward-rank) order — the deterministic list order.
                let mut ready = Vec::new();
                if let Some(kids) = self.children.remove(&id) {
                    for k in kids {
                        // A child pruned by an earlier abandonment cascade
                        // is gone from `held`; skip it.
                        if let Some(entry) = self.held.get_mut(&k) {
                            entry.1 -= 1;
                            if entry.1 == 0 {
                                ready.push(k);
                            }
                        }
                    }
                }
                ready.sort_unstable();
                for k in ready {
                    let (g, _) = self.held.remove(&k).expect("ready child is held");
                    let msg = Msg::Gridlet(pool::boxed(g));
                    ctx.send(self.broker, tags::GRIDLET_ARRIVAL, Some(msg), ARRIVAL_BYTES);
                }
            }
            tags::GRIDLET_ABANDONED => {
                let Msg::GridletId(id) = ev.take_data() else {
                    panic!("GRIDLET_ABANDONED without a Gridlet id")
                };
                // The job will never complete, so no withheld descendant can
                // ever become eligible: prune them all (transitively, each
                // at most once) and tell the broker how many jobs it should
                // stop waiting for.
                let mut stack = vec![id];
                let mut pruned: u64 = 0;
                while let Some(p) = stack.pop() {
                    if let Some(kids) = self.children.remove(&p) {
                        for k in kids {
                            if self.held.remove(&k).is_some() {
                                pruned += 1;
                                stack.push(k);
                            }
                        }
                    }
                }
                if pruned > 0 {
                    ctx.send(self.broker, tags::DAG_CASCADE, Some(Msg::Control(pruned)), 16);
                }
            }
            tags::INSIGNIFICANT => {}
            other => panic!("user {} got unexpected tag {other}", self.name),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
