//! `UserEntity` (paper §4.2.1): owns an experiment, hands it to its private
//! broker, records statistics when the results come back, and notifies the
//! shutdown entity when it has no more processing requirements.

use super::experiment::{Experiment, ExperimentResult, ExperimentSpec};
use crate::gridsim::messages::Msg;
use crate::gridsim::random::GridSimRandom;
use crate::gridsim::statistics::StatRecord;
use crate::gridsim::tags;
use crate::des::{Ctx, Entity, EntityId, Event};

/// A grid user with one experiment.
pub struct UserEntity {
    name: String,
    broker: EntityId,
    shutdown: EntityId,
    stats: Option<EntityId>,
    spec: ExperimentSpec,
    seed: u64,
    /// Activity model: delay before the experiment is submitted (paper:
    /// users differ in activity rate / time zone).
    submit_delay: f64,
    /// Outcome, for post-run inspection.
    pub result: Option<ExperimentResult>,
}

impl UserEntity {
    pub fn new(
        name: impl Into<String>,
        broker: EntityId,
        shutdown: EntityId,
        spec: ExperimentSpec,
        seed: u64,
    ) -> UserEntity {
        UserEntity {
            name: name.into(),
            broker,
            shutdown,
            stats: None,
            spec,
            seed,
            submit_delay: 0.0,
            result: None,
        }
    }

    pub fn with_stats(mut self, stats: EntityId) -> UserEntity {
        self.stats = Some(stats);
        self
    }

    pub fn with_submit_delay(mut self, delay: f64) -> UserEntity {
        assert!(delay >= 0.0);
        self.submit_delay = delay;
        self
    }
}

impl Entity<Msg> for UserEntity {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        // Materialize the application (seeded per user: "seed*997*(1+i)+1"
        // in the paper's Fig 15 — any per-user derivation works; ours is the
        // user seed itself, derived by the scenario builder).
        let mut rand = GridSimRandom::new(self.seed);
        let gridlets = self.spec.materialize(&mut rand);
        let experiment = Experiment {
            gridlets,
            deadline: self.spec.deadline,
            budget: self.spec.budget,
            optimization: self.spec.optimization,
        };
        let msg = Msg::Experiment(Box::new(experiment));
        let bytes = msg.wire_bytes(true);
        if self.submit_delay > 0.0 {
            ctx.send_delayed(self.broker, self.submit_delay, tags::EXPERIMENT, Some(msg));
        } else {
            ctx.send(self.broker, tags::EXPERIMENT, Some(msg), bytes);
        }
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::EXPERIMENT_DONE => {
                let Msg::ExperimentResult(result) = ev.take_data() else {
                    panic!("EXPERIMENT_DONE without payload")
                };
                // Record the paper's report-writer categories (Fig 15).
                if let Some(stats) = self.stats {
                    for (cat, value) in [
                        ("USER.TimeUtilization", result.time_utilization()),
                        ("USER.GridletCompletionFactor", result.completion_factor()),
                        ("USER.BudgetUtilization", result.budget_utilization()),
                    ] {
                        let rec = StatRecord {
                            time: ctx.now(),
                            category: format!("{}.{cat}", self.name),
                            label: self.name.clone(),
                            value,
                        };
                        ctx.send(stats, tags::RECORD_STATISTICS, Some(Msg::Stat(rec)), 48);
                    }
                }
                self.result = Some(*result);
                // No more processing requirements → tell the shutdown entity.
                ctx.send(self.shutdown, tags::END_OF_SIMULATION, None, 16);
            }
            tags::INSIGNIFICANT => {}
            other => panic!("user {} got unexpected tag {other}", self.name),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
