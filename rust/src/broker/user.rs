//! `UserEntity` (paper §4.2.1): owns an experiment, hands it to its private
//! broker, records statistics when the results come back, and notifies the
//! shutdown entity when it has no more processing requirements.
//!
//! The user is also the *release point* of online application models: a
//! workload whose jobs carry positive release offsets (trace replay, Poisson
//! or fixed-interval arrivals) is materialized up front, but only the
//! offset-0 batch ships with the experiment. The rest are held by the user
//! and streamed to the broker as `GRIDLET_ARRIVAL` events when their release
//! time comes (internal `USER_TICK` wake-ups), so the broker re-plans
//! mid-flight instead of assuming a closed batch.

use super::experiment::{Experiment, ExperimentResult, ExperimentSpec};
use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::messages::Msg;
use crate::gridsim::pool;
use crate::gridsim::random::GridSimRandom;
use crate::gridsim::statistics::StatRecord;
use crate::gridsim::tags;
use crate::des::{Ctx, Entity, EntityId, Event};
use std::collections::VecDeque;

/// Wire size of one online job-arrival message (job metadata; input staging
/// is charged on broker→resource dispatch, as for batch jobs).
const ARRIVAL_BYTES: u64 = 128;

/// A grid user with one experiment.
pub struct UserEntity {
    name: String,
    broker: EntityId,
    shutdown: EntityId,
    stats: Option<EntityId>,
    spec: ExperimentSpec,
    seed: u64,
    /// Activity model: delay before the experiment is submitted (paper:
    /// users differ in activity rate / time zone).
    submit_delay: f64,
    /// Jobs not yet released, as (absolute release time, gridlet) in
    /// release order. A single outstanding `USER_TICK` is armed for the
    /// front entry and re-armed after each pop — O(1) queued ticks no
    /// matter how large the online workload is.
    pending: VecDeque<(f64, Gridlet)>,
    /// Outcome, for post-run inspection.
    pub result: Option<ExperimentResult>,
}

impl UserEntity {
    /// Build a user that materializes `spec` with `seed` and drives the
    /// given broker, reporting to `shutdown` when its experiment ends.
    pub fn new(
        name: impl Into<String>,
        broker: EntityId,
        shutdown: EntityId,
        spec: ExperimentSpec,
        seed: u64,
    ) -> UserEntity {
        UserEntity {
            name: name.into(),
            broker,
            shutdown,
            stats: None,
            spec,
            seed,
            submit_delay: 0.0,
            pending: VecDeque::new(),
            result: None,
        }
    }

    /// Report the paper's Fig 15 statistics categories to `stats` when the
    /// experiment finishes.
    pub fn with_stats(mut self, stats: EntityId) -> UserEntity {
        self.stats = Some(stats);
        self
    }

    /// Delay the experiment submission (the paper's user activity model).
    pub fn with_submit_delay(mut self, delay: f64) -> UserEntity {
        assert!(delay >= 0.0);
        self.submit_delay = delay;
        self
    }

    /// Jobs materialized but not yet released to the broker.
    pub fn pending_releases(&self) -> usize {
        self.pending.len()
    }
}

impl Entity<Msg> for UserEntity {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        // Materialize the application (seeded per user: "seed*997*(1+i)+1"
        // in the paper's Fig 15 — any per-user derivation works; ours is the
        // user seed itself, derived by the scenario builder).
        let mut rand = GridSimRandom::new(self.seed);
        let releases = self.spec.workload.materialize(&mut rand);
        let total_jobs = releases.len();
        let total_mi: f64 = releases.iter().map(|r| r.gridlet.length_mi).sum();
        let mut batch = Vec::new();
        for r in releases {
            if r.offset <= 0.0 {
                batch.push(r.gridlet);
            } else {
                // Releases are offset-sorted, so pending stays front-first
                // in release order (on_start runs at t=0, so the stored
                // time is absolute).
                self.pending.push_back((self.submit_delay + r.offset, r.gridlet));
            }
        }
        if let Some(&(t, _)) = self.pending.front() {
            ctx.schedule_self(t, tags::USER_TICK, None);
        }
        let experiment = Experiment {
            gridlets: batch,
            total_jobs,
            total_mi,
            deadline: self.spec.deadline,
            budget: self.spec.budget,
            optimization: self.spec.optimization,
        };
        let msg = Msg::Experiment(Box::new(experiment));
        let bytes = msg.wire_bytes(true);
        if self.submit_delay > 0.0 {
            ctx.send_delayed(self.broker, self.submit_delay, tags::EXPERIMENT, Some(msg));
        } else {
            ctx.send(self.broker, tags::EXPERIMENT, Some(msg), bytes);
        }
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::EXPERIMENT_DONE => {
                let Msg::ExperimentResult(result) = ev.take_data() else {
                    panic!("EXPERIMENT_DONE without payload")
                };
                // Record the paper's report-writer categories (Fig 15).
                if let Some(stats) = self.stats {
                    for (cat, value) in [
                        ("USER.TimeUtilization", result.time_utilization()),
                        ("USER.GridletCompletionFactor", result.completion_factor()),
                        ("USER.BudgetUtilization", result.budget_utilization()),
                    ] {
                        let rec = StatRecord {
                            time: ctx.now(),
                            category: format!("{}.{cat}", self.name).into(),
                            label: self.name.clone(),
                            value,
                        };
                        ctx.send(stats, tags::RECORD_STATISTICS, Some(Msg::Stat(rec)), 48);
                    }
                }
                self.result = Some(*result);
                // The broker reported (deadline/budget hit); unreleased jobs
                // have nowhere to go.
                self.pending.clear();
                // No more processing requirements → tell the shutdown entity.
                ctx.send(self.shutdown, tags::END_OF_SIMULATION, None, 16);
            }
            tags::USER_TICK => {
                // Release the next online job, then re-arm the timer for the
                // one after it. The experiment may already be over (pending
                // cleared) — the at-most-one stale tick is a no-op.
                if let Some((_, g)) = self.pending.pop_front() {
                    let msg = Msg::Gridlet(pool::boxed(g));
                    ctx.send(self.broker, tags::GRIDLET_ARRIVAL, Some(msg), ARRIVAL_BYTES);
                    if let Some(&(t, _)) = self.pending.front() {
                        ctx.schedule_self((t - ctx.now()).max(0.0), tags::USER_TICK, None);
                    }
                }
            }
            tags::INSIGNIFICANT => {}
            other => panic!("user {} got unexpected tag {other}", self.name),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
