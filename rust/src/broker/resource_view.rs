//! `BrokerResource` — the broker-side record of one grid resource
//! (paper §4.2.1): its characteristics, the Gridlets committed to it, and
//! the measured performance ("the actual amount of MIPS available to the
//! user") used to extrapolate consumption rates for scheduling.

use crate::gridsim::gridlet::Gridlet;
use crate::gridsim::messages::ResourceInfo;
use std::collections::{HashMap, VecDeque};

/// EWMA smoothing for the per-slot rate measurement.
const RATE_EWMA_ALPHA: f64 = 0.3;

/// Broker-side view of one resource.
#[derive(Debug, Clone)]
pub struct BrokerResource {
    /// Characteristics reported by the resource during trading.
    pub info: ResourceInfo,
    /// Gridlets committed to this resource but not yet dispatched.
    pub assigned: VecDeque<Gridlet>,
    /// Gridlets dispatched and awaiting return.
    pub outstanding: usize,
    /// Estimated cost of in-flight Gridlets (reserved against the budget so
    /// the hard budget bound holds even while jobs are away).
    pub committed_cost: f64,
    /// Successfully completed Gridlets.
    pub completed: usize,
    /// MI successfully processed (measurement input).
    pub mi_done: f64,
    /// G$ spent on this resource.
    pub spent: f64,
    /// Time of first dispatch (measurement window start).
    pub first_dispatch: Option<f64>,
    /// Time of the latest successful return.
    pub last_return: Option<f64>,
    /// Dispatch time per in-flight Gridlet id (turnaround measurement).
    dispatch_times: HashMap<usize, f64>,
    /// EWMA of the measured per-slot rate `length / turnaround` (MI per
    /// time unit one dispatch slot delivers to this user).
    per_slot_rate: Option<f64>,
    /// Dispatch cap per tick: paper's `MaxGridletPerPE` (2 in Fig 17).
    pub max_gridlets_per_pe: usize,
    /// Failure adaptation: after a Gridlet comes back `Failed`, the broker
    /// treats this resource as down until this time (retry backoff) — this
    /// both models the paper's "adapting to resource failures" and breaks
    /// the zero-delay livelock of re-dispatching to a dead resource.
    pub down_until: f64,
    /// The resource's price currently in effect (market layer): starts at
    /// the traded characteristics price and follows `PRICE_UPDATE` events.
    /// Without a market it never moves, so all cost arithmetic stays
    /// byte-identical to the static-price broker.
    pub current_price: f64,
    /// Spot-tier discount this user rents at (set only when the scenario
    /// marks the resource as spot *and* the user placed a bid). Costing
    /// and ranking then use `discount × current_price`.
    pub spot_discount: Option<f64>,
    /// G$ reserved per in-flight Gridlet id at dispatch time — released at
    /// return at exactly the reserved amount, so `committed_cost` stays
    /// consistent even when the price moves while jobs are away.
    reserved: HashMap<usize, f64>,
}

impl BrokerResource {
    /// Fresh view of a just-discovered resource: nothing committed, no
    /// measurements, optimistic rate until the first Gridlet returns.
    pub fn new(info: ResourceInfo) -> BrokerResource {
        let current_price = info.cost_per_pe_time;
        BrokerResource {
            info,
            assigned: VecDeque::new(),
            outstanding: 0,
            committed_cost: 0.0,
            completed: 0,
            mi_done: 0.0,
            spent: 0.0,
            first_dispatch: None,
            last_return: None,
            dispatch_times: HashMap::new(),
            per_slot_rate: None,
            max_gridlets_per_pe: 2,
            down_until: f64::NEG_INFINITY,
            current_price,
            spot_discount: None,
            reserved: HashMap::new(),
        }
    }

    /// Price per PE-time this user pays right now: the dynamic current
    /// price, spot-discounted when renting the spot tier.
    pub fn effective_price(&self) -> f64 {
        match self.spot_discount {
            Some(d) => d * self.current_price,
            None => self.current_price,
        }
    }

    /// G$ per MI (ranking key; Table 2 translation) at the price currently
    /// in effect.
    pub fn cost_per_mi(&self) -> f64 {
        self.effective_price() / self.info.mips_per_pe
    }

    /// Jobs committed to this resource right now (assigned + in flight).
    pub fn committed(&self) -> usize {
        self.assigned.len() + self.outstanding
    }

    /// Measured-and-extrapolated MI consumption rate available to this user
    /// (paper Fig 20 step a). Before any result returns, the broker is
    /// optimistic and assumes the full resource: `Σ MIPS`. Afterwards the
    /// estimate is `dispatch_limit × EWMA(length / turnaround)` — each
    /// returned Gridlet's turnaround measures what one dispatch slot
    /// delivers, so the estimate is unbiased at any instant (a cumulative
    /// `MI done / elapsed` average would undercount in-flight work and make
    /// the resource look slower right before each batch returns). Under
    /// competition turnaround inflates and the broker adapts — the paper's
    /// "recalibration". Capped at the resource's aggregate MIPS.
    pub fn rate_estimate(&self, now: f64) -> f64 {
        if !self.available(now) {
            return 0.0;
        }
        match self.per_slot_rate {
            Some(r) => (r * self.dispatch_limit() as f64).min(self.info.total_mips()),
            None => self.info.total_mips(),
        }
    }

    /// Is the resource currently considered usable (failure backoff)?
    pub fn available(&self, now: f64) -> bool {
        now >= self.down_until
    }

    /// Predicted turnaround of one more job of `avg_mi` on this resource
    /// (measured per-slot rate; optimistic one-PE estimate before data).
    /// Exposed for what-if analyses; the broker deliberately does *not*
    /// refuse late dispatches based on this — the paper's broker keeps
    /// in-flight jobs past the (soft) deadline rather than cancelling them
    /// (§5.4.1), which is exactly what makes Fig 34's termination times
    /// overshoot under competition.
    pub fn predicted_turnaround(&self, avg_mi: f64) -> f64 {
        let per_slot = self.per_slot_rate.unwrap_or(self.info.mips_per_pe);
        avg_mi / per_slot.max(1e-9)
    }

    /// Enter failure backoff for `backoff` time units.
    pub fn mark_down(&mut self, now: f64, backoff: f64) {
        self.down_until = now + backoff.max(1e-9);
    }

    /// Max Gridlets allowed in flight at once (the dispatcher's staging
    /// policy, Fig 18 step 4: "avoid overloading resources").
    pub fn dispatch_limit(&self) -> usize {
        self.max_gridlets_per_pe * self.info.num_pe
    }

    /// Reserve the estimated cost of a Gridlet being dispatched.
    pub fn on_dispatched(&mut self, g: &Gridlet, now: f64) {
        self.outstanding += 1;
        let reserve = self.cost_per_mi() * g.length_mi;
        self.committed_cost += reserve;
        self.reserved.insert(g.id, reserve);
        self.first_dispatch.get_or_insert(now);
        self.dispatch_times.insert(g.id, now);
    }

    /// Release the reservation made for `g` at dispatch time (exactly the
    /// reserved amount, even if the price moved since).
    fn release_reserve(&mut self, g: &Gridlet) {
        let reserve =
            self.reserved.remove(&g.id).unwrap_or_else(|| self.cost_per_mi() * g.length_mi);
        self.committed_cost = (self.committed_cost - reserve).max(0.0);
    }

    fn observe_turnaround(&mut self, g: &Gridlet, now: f64) {
        if let Some(t0) = self.dispatch_times.remove(&g.id) {
            let turnaround = (now - t0).max(1e-9);
            let implied = g.length_mi / turnaround;
            self.per_slot_rate = Some(match self.per_slot_rate {
                Some(prev) => prev + RATE_EWMA_ALPHA * (implied - prev),
                None => implied,
            });
        }
    }

    /// Account a successful completion at time `now`.
    pub fn on_completed(&mut self, g: &Gridlet, now: f64) {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
        self.release_reserve(g);
        self.completed += 1;
        self.mi_done += g.length_mi;
        self.spent += g.cost;
        self.last_return = Some(now);
        self.observe_turnaround(g, now);
    }

    /// Account a failed/cancelled return (the job goes back to the pool;
    /// cancelled work may still carry a partial-cost charge).
    pub fn on_returned_unfinished(&mut self, g: &Gridlet) {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
        self.release_reserve(g);
        self.dispatch_times.remove(&g.id);
        self.spent += g.cost;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(pes: usize, mips: f64, price: f64) -> BrokerResource {
        BrokerResource::new(ResourceInfo {
            id: 0,
            name: "R".into(),
            num_pe: pes,
            mips_per_pe: mips,
            cost_per_pe_time: price,
            time_shared: true,
            time_zone: 0.0,
        })
    }

    #[test]
    fn turnaround_rate_estimation() {
        let mut v = view(1, 100.0, 1.0); // dispatch limit = 2, 100 MIPS
        assert_eq!(v.rate_estimate(10.0), 100.0, "optimistic before data");
        let mut g0 = Gridlet::new(0, 500.0, 0, 0);
        g0.cost = 5.0;
        let mut g1 = Gridlet::new(1, 500.0, 0, 0);
        g1.cost = 5.0;
        v.on_dispatched(&g0, 0.0);
        v.on_dispatched(&g1, 0.0);
        // Both share the PE: each returns after 10 t → per-slot 50 MI/t,
        // rate = 2 slots × 50 = 100 = full capacity (unbiased).
        v.on_completed(&g0, 10.0);
        assert_eq!(v.rate_estimate(10.0), 100.0);
        v.on_completed(&g1, 10.0);
        assert_eq!(v.rate_estimate(11.0), 100.0);
        assert_eq!(v.completed, 2);
        assert_eq!(v.spent, 10.0);
        assert_eq!(v.committed_cost, 0.0);
    }

    #[test]
    fn competition_inflates_turnaround_and_lowers_rate() {
        let mut v = view(1, 100.0, 1.0);
        let g = Gridlet::new(0, 500.0, 0, 0);
        v.on_dispatched(&g, 0.0);
        // Another user's load makes our job take 4× longer than dedicated.
        v.on_completed(&g, 20.0); // per-slot 25 → rate 50 < capacity 100
        assert_eq!(v.rate_estimate(20.0), 50.0);
        // Estimate is capped at aggregate MIPS even for lone fast jobs.
        let g2 = Gridlet::new(2, 500.0, 0, 0);
        v.on_dispatched(&g2, 100.0);
        v.on_completed(&g2, 101.0); // implied 500/slot, EWMA pulls up
        assert!(v.rate_estimate(101.0) <= 100.0);
    }

    #[test]
    fn committed_cost_reserved_and_released() {
        let mut v = view(4, 100.0, 1.0);
        let g = Gridlet::new(0, 500.0, 0, 0);
        v.on_dispatched(&g, 1.0);
        assert!((v.committed_cost - 5.0).abs() < 1e-12); // 500 MI × 0.01 G$/MI
        assert_eq!(v.first_dispatch, Some(1.0));
        v.on_returned_unfinished(&g);
        assert_eq!(v.committed_cost, 0.0);
    }

    #[test]
    fn dispatch_limit_scales_with_pes() {
        let v = view(4, 100.0, 1.0);
        assert_eq!(v.dispatch_limit(), 8);
    }

    #[test]
    fn committed_counts_both() {
        let mut v = view(1, 100.0, 1.0);
        v.assigned.push_back(Gridlet::new(0, 1.0, 0, 0));
        v.outstanding = 2;
        assert_eq!(v.committed(), 3);
    }

    #[test]
    fn price_updates_and_spot_discount_drive_cost() {
        let mut v = view(1, 100.0, 2.0);
        assert_eq!(v.cost_per_mi(), 0.02, "static price to start");
        v.current_price = 4.0; // PRICE_UPDATE arrived
        assert_eq!(v.cost_per_mi(), 0.04);
        v.spot_discount = Some(0.5);
        assert_eq!(v.effective_price(), 2.0);
        assert_eq!(v.cost_per_mi(), 0.02);
    }

    #[test]
    fn reservation_released_at_dispatch_price_despite_update() {
        let mut v = view(4, 100.0, 1.0);
        let g = Gridlet::new(0, 500.0, 0, 0);
        v.on_dispatched(&g, 1.0); // reserve at 0.01 G$/MI → 5.0
        v.current_price = 3.0; // price triples while the job is away
        v.on_completed(&g, 2.0);
        assert_eq!(v.committed_cost, 0.0, "release is the reserved amount");
    }

    #[test]
    fn unfinished_return_keeps_completion_count() {
        let mut v = view(1, 100.0, 1.0);
        v.outstanding = 1;
        let mut g = Gridlet::new(0, 100.0, 0, 0);
        g.cost = 1.5; // partial charge for cancelled work
        v.on_returned_unfinished(&g);
        assert_eq!(v.completed, 0);
        assert_eq!(v.outstanding, 0);
        assert_eq!(v.spent, 1.5);
    }
}
