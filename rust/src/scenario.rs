//! Scenario assembly and execution — the equivalent of the paper's Fig 15
//! `CreateSampleGridEnvironement`: build the entity graph (GIS, statistics,
//! shutdown, resources, user+broker pairs), run the simulation, and collect
//! per-user results.

use crate::broker::broker::BrokerConfig;
use crate::broker::policy::make_policy;
use crate::broker::{Broker, ExperimentResult, ExperimentSpec, UserEntity};
use crate::des::Simulation;
use crate::gridsim::{
    AllocPolicy, BaudLink, GridInformationService, GridResource, GridSimShutdown, GridStatistics,
    MachineList, Msg, ResourceCalendar, ResourceCharacteristics,
};
use crate::runtime::{Advisor, AdvisorInput, NativeAdvisor, XlaAdvisor};
use std::cell::RefCell;
use std::rc::Rc;

/// Declarative description of one grid resource (Table 2 row).
#[derive(Debug, Clone)]
pub struct ResourceSpec {
    pub name: String,
    pub arch: String,
    pub os: String,
    pub machines: usize,
    pub pes_per_machine: usize,
    pub mips_per_pe: f64,
    pub policy: AllocPolicy,
    /// G$ per PE per time unit.
    pub price: f64,
    pub time_zone: f64,
    /// Background load profile; `None` = no local load (paper §5 setup).
    pub calendar: Option<ResourceCalendar>,
}

impl ResourceSpec {
    pub fn characteristics(&self) -> ResourceCharacteristics {
        ResourceCharacteristics::new(
            self.arch.clone(),
            self.os.clone(),
            MachineList::cluster(self.machines, self.pes_per_machine, self.mips_per_pe),
            self.policy,
            self.price,
            self.time_zone,
        )
    }

    pub fn num_pe(&self) -> usize {
        self.machines * self.pes_per_machine
    }
}

/// Which allocation engine backs DBC cost-optimization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdvisorKind {
    /// Pure-Rust sequential greedy.
    Native,
    /// AOT JAX/Pallas artifact (`artifacts/advisor.hlo.txt`) via PJRT.
    Xla,
}

/// Network model selection.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkSpec {
    /// Zero-delay (the paper's §5 experiments ignore staging).
    Instantaneous,
    /// Baud-rate delays with optional uniform latency.
    Baud { default_rate: f64, latency: f64 },
}

/// A complete simulation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub resources: Vec<ResourceSpec>,
    /// One experiment spec per user (each user gets a private broker).
    pub users: Vec<ExperimentSpec>,
    pub seed: u64,
    pub network: NetworkSpec,
    pub advisor: AdvisorKind,
    pub broker_config: BrokerConfig,
    /// Hard simulation-time limit (safety net).
    pub max_time: f64,
}

impl Scenario {
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder::default()
    }
}

/// Builder for [`Scenario`].
#[derive(Default)]
pub struct ScenarioBuilder {
    resources: Vec<ResourceSpec>,
    users: Vec<ExperimentSpec>,
    seed: u64,
    network: Option<NetworkSpec>,
    advisor: Option<AdvisorKind>,
    broker_config: Option<BrokerConfig>,
    max_time: Option<f64>,
}

impl ScenarioBuilder {
    pub fn resources(mut self, specs: Vec<ResourceSpec>) -> Self {
        self.resources = specs;
        self
    }

    pub fn resource(mut self, spec: ResourceSpec) -> Self {
        self.resources.push(spec);
        self
    }

    pub fn user(mut self, spec: ExperimentSpec) -> Self {
        self.users.push(spec);
        self
    }

    /// `n` identical users (the paper's §5.4 competition experiments).
    pub fn users(mut self, n: usize, spec: ExperimentSpec) -> Self {
        for _ in 0..n {
            self.users.push(spec.clone());
        }
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn network(mut self, network: NetworkSpec) -> Self {
        self.network = Some(network);
        self
    }

    pub fn advisor(mut self, advisor: AdvisorKind) -> Self {
        self.advisor = Some(advisor);
        self
    }

    pub fn broker_config(mut self, config: BrokerConfig) -> Self {
        self.broker_config = Some(config);
        self
    }

    pub fn max_time(mut self, t: f64) -> Self {
        self.max_time = Some(t);
        self
    }

    pub fn build(self) -> Scenario {
        assert!(!self.resources.is_empty(), "scenario needs resources");
        assert!(!self.users.is_empty(), "scenario needs at least one user");
        Scenario {
            resources: self.resources,
            users: self.users,
            seed: self.seed,
            network: self.network.unwrap_or(NetworkSpec::Instantaneous),
            advisor: self.advisor.unwrap_or(AdvisorKind::Native),
            broker_config: self.broker_config.unwrap_or_default(),
            max_time: self.max_time.unwrap_or(1e9),
        }
    }
}

/// Shared advisor handle: lets every broker in a multi-user scenario reuse
/// one compiled XLA executable (compilation happens once, execution on each
/// scheduling tick).
struct SharedAdvisor {
    inner: Rc<RefCell<dyn Advisor>>,
    label: &'static str,
}

impl Advisor for SharedAdvisor {
    fn advise(&mut self, input: &AdvisorInput) -> Vec<usize> {
        self.inner.borrow_mut().advise(input)
    }
    fn name(&self) -> &'static str {
        self.label
    }
}

/// Outcome of a scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Per-user experiment results, in user order.
    pub users: Vec<ExperimentResult>,
    /// Simulation end time.
    pub end_time: f64,
    /// Events dispatched by the kernel (engine-level metric).
    pub events: u64,
}

impl ScenarioReport {
    /// Mean Gridlets completed per user (Figs 33/36 series value).
    pub fn mean_completed(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.gridlets_completed as f64).sum::<f64>()
            / self.users.len() as f64
    }

    /// Mean budget spent per user (Figs 35/38).
    pub fn mean_spent(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.budget_spent).sum::<f64>() / self.users.len() as f64
    }

    /// Mean experiment termination time (Figs 34/37).
    pub fn mean_finish_time(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.finish_time - u.start_time).sum::<f64>()
            / self.users.len() as f64
    }
}

/// Build the entity graph for `scenario`, run it to completion, and collect
/// per-user results.
pub fn run_scenario(scenario: &Scenario) -> ScenarioReport {
    let mut sim: Simulation<Msg> = Simulation::with_config(crate::des::SimConfig {
        max_time: scenario.max_time,
        max_events: u64::MAX,
    });
    match &scenario.network {
        NetworkSpec::Instantaneous => {
            sim.set_link_model(Box::new(BaudLink::instantaneous()));
        }
        NetworkSpec::Baud { default_rate, latency } => {
            sim.set_link_model(Box::new(
                BaudLink::new().with_default_rate(*default_rate).with_default_latency(*latency),
            ));
        }
    }

    let gis = sim.add(Box::new(GridInformationService::new("GIS")));
    let stats = sim.add(Box::new(GridStatistics::new("GridStatistics")));
    let shutdown = sim.add(Box::new(GridSimShutdown::new("GridSimShutdown", scenario.users.len())));

    for spec in &scenario.resources {
        let calendar = spec.calendar.clone().unwrap_or_else(ResourceCalendar::no_load);
        let resource =
            GridResource::new(spec.name.clone(), spec.characteristics(), calendar, gis)
                .with_stats(stats);
        sim.add(Box::new(resource));
    }

    // One compiled advisor shared by all brokers.
    let shared: Rc<RefCell<dyn Advisor>> = match scenario.advisor {
        AdvisorKind::Native => Rc::new(RefCell::new(NativeAdvisor::new())),
        AdvisorKind::Xla => Rc::new(RefCell::new(
            XlaAdvisor::load_default().expect("failed to load artifacts/advisor.hlo.txt — run `make artifacts`"),
        )),
    };
    let label = match scenario.advisor {
        AdvisorKind::Native => "native",
        AdvisorKind::Xla => "xla",
    };

    let mut user_ids = Vec::new();
    for (i, spec) in scenario.users.iter().enumerate() {
        let advisor = Box::new(SharedAdvisor { inner: shared.clone(), label });
        let policy = make_policy(spec.optimization, advisor);
        let broker = Broker::new(
            format!("Broker_{i}"),
            gis,
            policy,
            scenario.broker_config.clone(),
        );
        let broker_id = sim.add(Box::new(broker));
        // Paper Fig 15 per-user seed derivation: seed·997·(1+i)+1.
        let user_seed = scenario
            .seed
            .wrapping_mul(997)
            .wrapping_mul(1 + i as u64)
            .wrapping_add(1);
        let user = UserEntity::new(format!("U{i}"), broker_id, shutdown, spec.clone(), user_seed)
            .with_stats(stats);
        user_ids.push(sim.add(Box::new(user)));
    }

    let end_time = sim.run();
    let users = user_ids
        .iter()
        .map(|&id| {
            sim.get::<UserEntity>(id)
                .expect("user entity")
                .result
                .clone()
                .unwrap_or_else(|| ExperimentResult {
                    gridlets_completed: 0,
                    gridlets_total: 0,
                    budget_spent: 0.0,
                    finish_time: end_time,
                    start_time: 0.0,
                    deadline: 0.0,
                    budget: 0.0,
                    per_resource: vec![],
                    trace: vec![],
                })
        })
        .collect();
    ScenarioReport { users, end_time, events: sim.events_processed() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Optimization;

    fn small_resource(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
        ResourceSpec {
            name: name.into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: pes,
            mips_per_pe: mips,
            policy: AllocPolicy::TimeShared,
            price,
            time_zone: 0.0,
            calendar: None,
        }
    }

    #[test]
    fn single_user_completes_everything_with_slack() {
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .resource(small_resource("R1", 2, 100.0, 2.0))
            .user(
                ExperimentSpec::task_farm(20, 1_000.0, 0.10)
                    .deadline(1_000.0)
                    .budget(100_000.0)
                    .optimization(Optimization::Cost),
            )
            .seed(42)
            .build();
        let report = run_scenario(&scenario);
        assert_eq!(report.users.len(), 1);
        let u = &report.users[0];
        assert_eq!(u.gridlets_completed, 20, "ample deadline+budget: all done");
        assert!(u.budget_spent > 0.0);
        assert!(u.finish_time <= 1_000.0);
        // Cost optimization should favour the cheap resource.
        let r0 = u.per_resource.iter().find(|r| r.name == "R0").unwrap();
        let r1 = u.per_resource.iter().find(|r| r.name == "R1").unwrap();
        assert!(r0.gridlets_completed >= r1.gridlets_completed);
    }

    #[test]
    fn deterministic_runs() {
        let build = || {
            Scenario::builder()
                .resource(small_resource("R0", 2, 100.0, 1.0))
                .user(
                    ExperimentSpec::task_farm(10, 1_000.0, 0.10)
                        .deadline(500.0)
                        .budget(10_000.0),
                )
                .seed(7)
                .build()
        };
        let a = run_scenario(&build());
        let b = run_scenario(&build());
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.events, b.events);
        assert_eq!(a.users[0].gridlets_completed, b.users[0].gridlets_completed);
        assert_eq!(a.users[0].budget_spent, b.users[0].budget_spent);
    }

    #[test]
    fn zero_budget_processes_nothing() {
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .user(ExperimentSpec::task_farm(5, 1_000.0, 0.0).deadline(100.0).budget(0.0))
            .seed(1)
            .build();
        let report = run_scenario(&scenario);
        assert_eq!(report.users[0].gridlets_completed, 0);
        assert_eq!(report.users[0].budget_spent, 0.0);
    }

    #[test]
    fn tight_deadline_processes_fewer() {
        let run_with_deadline = |d: f64| {
            let scenario = Scenario::builder()
                .resource(small_resource("R0", 2, 100.0, 1.0))
                .user(ExperimentSpec::task_farm(40, 1_000.0, 0.10).deadline(d).budget(1e9))
                .seed(3)
                .build();
            run_scenario(&scenario).users[0].gridlets_completed
        };
        let tight = run_with_deadline(30.0);
        let loose = run_with_deadline(10_000.0);
        assert_eq!(loose, 40);
        assert!(tight < loose, "tight {tight} < loose {loose}");
    }
}
