//! Scenario description — the declarative half of the paper's Fig 15
//! `CreateSampleGridEnvironement`: resources (Table 2 rows), users with
//! per-user workload/policy/advisor/broker heterogeneity, network model,
//! advisor engine and kernel limits. Execution lives in [`crate::session`]:
//! build a [`crate::session::GridSession`] and run/step it.

use crate::broker::broker::BrokerConfig;
use crate::broker::{ExperimentResult, ExperimentSpec, Optimization};
use crate::faults::FaultsSpec;
use crate::gridsim::{AllocPolicy, MachineList, ResourceCalendar, ResourceCharacteristics};
use crate::market::MarketSpec;
use crate::workload::WorkloadSpec;

/// Declarative description of one grid resource (Table 2 row).
#[derive(Debug, Clone)]
pub struct ResourceSpec {
    /// Resource name (Table 2 "Resource name" column; unique per scenario).
    pub name: String,
    /// Architecture label (informational, reported in characteristics).
    pub arch: String,
    /// Operating-system label (informational).
    pub os: String,
    /// Number of machines in the cluster.
    pub machines: usize,
    /// Processing elements per machine.
    pub pes_per_machine: usize,
    /// MIPS rating of each PE (SPEC-like rating in the paper).
    pub mips_per_pe: f64,
    /// Local scheduler: time-shared or space-shared.
    pub policy: AllocPolicy,
    /// G$ per PE per time unit.
    pub price: f64,
    /// Resource time zone (informational).
    pub time_zone: f64,
    /// Background load profile; `None` = no local load (paper §5 setup).
    pub calendar: Option<ResourceCalendar>,
}

impl ResourceSpec {
    /// Materialize the characteristics record handed to [`crate::gridsim::GridResource`].
    pub fn characteristics(&self) -> ResourceCharacteristics {
        ResourceCharacteristics::new(
            self.arch.clone(),
            self.os.clone(),
            MachineList::cluster(self.machines, self.pes_per_machine, self.mips_per_pe),
            self.policy,
            self.price,
            self.time_zone,
        )
    }

    /// Total processing elements (`machines × pes_per_machine`).
    pub fn num_pe(&self) -> usize {
        self.machines * self.pes_per_machine
    }
}

/// Which allocation engine backs DBC cost-optimization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdvisorKind {
    /// Pure-Rust sequential greedy.
    Native,
    /// AOT JAX/Pallas artifact (`artifacts/advisor.hlo.txt`) via PJRT.
    Xla,
}

/// Network model selection.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkSpec {
    /// Zero-delay (the paper's §5 experiments ignore staging).
    Instantaneous,
    /// Baud-rate delays with optional uniform latency.
    Baud { default_rate: f64, latency: f64 },
    /// Flow-level shared bandwidth (see [`crate::network::FlowLink`]):
    /// concurrent transfers fair-share access-link capacity and finish
    /// events are rescheduled on every flow start/finish.
    Flow {
        /// Access-link capacity (bits per time unit) for every entity
        /// without an explicit override.
        default_capacity: f64,
        /// Fixed per-message latency added after each transfer.
        latency: f64,
        /// Per-entity capacity overrides, keyed by entity *name* (resource
        /// names, `U0`/`Broker_0`, `GIS`, …); resolved to ids at session
        /// build time. A `Vec` (not a map) so the spec stays `PartialEq`
        /// with a deterministic `Debug` for sweep checkpoint digests.
        capacities: Vec<(String, f64)>,
    },
}

/// One user of the grid: the experiment plus optional overrides of the
/// scenario-wide execution knobs. `None` fields fall back to the scenario
/// defaults, so homogeneous scenarios (paper §5.4's identical competing
/// users) stay one-liners while heterogeneous ones — "users with different
/// requirements" — override per user.
#[derive(Debug, Clone)]
pub struct UserSpec {
    /// The experiment this user runs (workload, deadline/budget, policy).
    pub experiment: ExperimentSpec,
    /// Advisor engine override for this user's broker.
    pub advisor: Option<AdvisorKind>,
    /// Broker tuning override for this user's broker.
    pub broker: Option<BrokerConfig>,
    /// Delay before the experiment is submitted (activity model).
    pub submit_delay: f64,
    /// Network link rate override for this user's site (applied to both
    /// the user and its broker entity): baud rate under
    /// [`NetworkSpec::Baud`], access-link capacity under
    /// [`NetworkSpec::Flow`]. `None` falls back to the network default.
    pub link_rate: Option<f64>,
    /// Spot bid: the most this user will pay (G$ per PE per time unit) on a
    /// spot tier. `None` (the default) means the user never rents spot —
    /// spot-tier resources then charge it the full dynamic price and never
    /// preempt its jobs. Only meaningful when the scenario's
    /// [`MarketSpec`] declares spot resources.
    pub max_spot_price: Option<f64>,
}

impl UserSpec {
    /// Wrap an experiment with all per-user overrides at their defaults.
    pub fn new(experiment: ExperimentSpec) -> UserSpec {
        UserSpec {
            experiment,
            advisor: None,
            broker: None,
            submit_delay: 0.0,
            link_rate: None,
            max_spot_price: None,
        }
    }

    /// Override the advisor engine for this user's broker.
    pub fn advisor(mut self, kind: AdvisorKind) -> UserSpec {
        self.advisor = Some(kind);
        self
    }

    /// Override the broker tuning for this user's broker.
    pub fn broker(mut self, config: BrokerConfig) -> UserSpec {
        self.broker = Some(config);
        self
    }

    /// Delay the experiment submission by `delay` time units.
    pub fn submit_delay(mut self, delay: f64) -> UserSpec {
        assert!(delay >= 0.0, "submit delay must be >= 0");
        self.submit_delay = delay;
        self
    }

    /// Override this user's site link rate (baud rate or flow capacity,
    /// depending on the scenario's [`NetworkSpec`]).
    pub fn link_rate(mut self, rate: f64) -> UserSpec {
        assert!(rate.is_finite() && rate > 0.0, "link rate must be finite and positive");
        self.link_rate = Some(rate);
        self
    }

    /// Place a spot bid: rent spot tiers while their discounted price stays
    /// at or below `bid` (G$ per PE per time unit), accepting preemption
    /// when the price crosses it.
    pub fn max_spot_price(mut self, bid: f64) -> UserSpec {
        assert!(bid.is_finite() && bid >= 0.0, "spot bid must be finite and >= 0");
        self.max_spot_price = Some(bid);
        self
    }

    // ExperimentSpec builder forwarding, so a `UserSpec` chains exactly like
    // the `ExperimentSpec` it wraps.

    /// Replace the workload (forwards to [`ExperimentSpec::workload`]).
    pub fn workload(mut self, w: WorkloadSpec) -> UserSpec {
        self.experiment = self.experiment.workload(w);
        self
    }

    /// Set an absolute deadline (forwards to [`ExperimentSpec::deadline`]).
    pub fn deadline(mut self, d: f64) -> UserSpec {
        self.experiment = self.experiment.deadline(d);
        self
    }

    /// Set an absolute budget (forwards to [`ExperimentSpec::budget`]).
    pub fn budget(mut self, b: f64) -> UserSpec {
        self.experiment = self.experiment.budget(b);
        self
    }

    /// Set the deadline as a D-factor (forwards to [`ExperimentSpec::d_factor`]).
    pub fn d_factor(mut self, f: f64) -> UserSpec {
        self.experiment = self.experiment.d_factor(f);
        self
    }

    /// Set the budget as a B-factor (forwards to [`ExperimentSpec::b_factor`]).
    pub fn b_factor(mut self, f: f64) -> UserSpec {
        self.experiment = self.experiment.b_factor(f);
        self
    }

    /// Set the DBC policy (forwards to [`ExperimentSpec::optimization`]).
    pub fn optimization(mut self, o: Optimization) -> UserSpec {
        self.experiment = self.experiment.optimization(o);
        self
    }
}

impl From<ExperimentSpec> for UserSpec {
    fn from(experiment: ExperimentSpec) -> UserSpec {
        UserSpec::new(experiment)
    }
}

/// A complete simulation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Grid resources (Table 2 rows).
    pub resources: Vec<ResourceSpec>,
    /// One user spec per user (each user gets a private broker).
    pub users: Vec<UserSpec>,
    /// Master seed; per-user streams are derived deterministically from it.
    pub seed: u64,
    /// Network model the messages travel through.
    pub network: NetworkSpec,
    /// Default advisor engine (per-user [`UserSpec::advisor`] overrides).
    pub advisor: AdvisorKind,
    /// Default broker tuning (per-user [`UserSpec::broker`] overrides).
    pub broker_config: BrokerConfig,
    /// Failure–repair processes per resource; `None` (the default) builds
    /// no [`crate::faults::FaultInjector`] at all, so the event stream is
    /// identical to a pre-reliability scenario.
    pub faults: Option<FaultsSpec>,
    /// Economic market layer: utilization-driven pricing models and spot
    /// tiers per resource. `None` (the default) keeps every resource at its
    /// static configured price with no `PRICE_UPDATE` traffic, so the event
    /// stream and all cost arithmetic are identical to a pre-market
    /// scenario.
    pub market: Option<MarketSpec>,
    /// Hard simulation-time limit (safety net).
    pub max_time: f64,
}

impl Scenario {
    /// Start building a scenario.
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder::default()
    }
}

/// Builder for [`Scenario`].
#[derive(Default)]
pub struct ScenarioBuilder {
    resources: Vec<ResourceSpec>,
    users: Vec<UserSpec>,
    seed: u64,
    network: Option<NetworkSpec>,
    advisor: Option<AdvisorKind>,
    broker_config: Option<BrokerConfig>,
    faults: Option<FaultsSpec>,
    market: Option<MarketSpec>,
    max_time: Option<f64>,
}

impl ScenarioBuilder {
    /// Replace the full resource list.
    pub fn resources(mut self, specs: Vec<ResourceSpec>) -> Self {
        self.resources = specs;
        self
    }

    /// Add one resource.
    pub fn resource(mut self, spec: ResourceSpec) -> Self {
        self.resources.push(spec);
        self
    }

    /// Add one user — an [`ExperimentSpec`] (scenario defaults apply) or a
    /// full [`UserSpec`] with per-user overrides.
    pub fn user(mut self, spec: impl Into<UserSpec>) -> Self {
        self.users.push(spec.into());
        self
    }

    /// `n` identical users (the paper's §5.4 competition experiments).
    pub fn users(mut self, n: usize, spec: impl Into<UserSpec>) -> Self {
        let spec = spec.into();
        for _ in 0..n {
            self.users.push(spec.clone());
        }
        self
    }

    /// Set the master seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Select the network model (default: instantaneous).
    pub fn network(mut self, network: NetworkSpec) -> Self {
        self.network = Some(network);
        self
    }

    /// Select the default advisor engine (default: native).
    pub fn advisor(mut self, advisor: AdvisorKind) -> Self {
        self.advisor = Some(advisor);
        self
    }

    /// Set the default broker tuning.
    pub fn broker_config(mut self, config: BrokerConfig) -> Self {
        self.broker_config = Some(config);
        self
    }

    /// Drive resources with the given failure–repair processes.
    pub fn faults(mut self, faults: FaultsSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Attach the economic market layer (dynamic pricing / spot tiers).
    pub fn market(mut self, market: MarketSpec) -> Self {
        self.market = Some(market);
        self
    }

    /// Set the hard simulation-time limit.
    pub fn max_time(mut self, t: f64) -> Self {
        self.max_time = Some(t);
        self
    }

    /// Finalize the scenario (panics without resources or users).
    pub fn build(self) -> Scenario {
        assert!(!self.resources.is_empty(), "scenario needs resources");
        assert!(!self.users.is_empty(), "scenario needs at least one user");
        Scenario {
            resources: self.resources,
            users: self.users,
            seed: self.seed,
            network: self.network.unwrap_or(NetworkSpec::Instantaneous),
            advisor: self.advisor.unwrap_or(AdvisorKind::Native),
            broker_config: self.broker_config.unwrap_or_default(),
            faults: self.faults,
            market: self.market,
            max_time: self.max_time.unwrap_or(1e9),
        }
    }
}

/// Outcome of a scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Per-user experiment results, in user order. For a user whose
    /// experiment did not terminate before the run ended (kernel limit),
    /// the entry carries the broker's real partial accounting and the
    /// user's index appears in [`unfinished`](Self::unfinished).
    pub users: Vec<ExperimentResult>,
    /// Indices of users whose experiments did not finish.
    pub unfinished: Vec<usize>,
    /// Simulation end time.
    pub end_time: f64,
    /// Events dispatched by the kernel (engine-level metric).
    pub events: u64,
}

impl ScenarioReport {
    /// Did every user's experiment terminate?
    pub fn all_finished(&self) -> bool {
        self.unfinished.is_empty()
    }

    /// Mean Gridlets completed per user (Figs 33/36 series value).
    pub fn mean_completed(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.gridlets_completed as f64).sum::<f64>()
            / self.users.len() as f64
    }

    /// Mean budget spent per user (Figs 35/38).
    pub fn mean_spent(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.budget_spent).sum::<f64>() / self.users.len() as f64
    }

    /// Mean fraction of Gridlets completed per user (robustness figures).
    pub fn mean_completion_rate(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.completion_factor()).sum::<f64>() / self.users.len() as f64
    }

    /// Total Gridlets lost to resource failures, across all users.
    pub fn total_lost(&self) -> usize {
        self.users.iter().map(|u| u.gridlets_lost).sum()
    }

    /// Total lost Gridlets resubmitted by broker policy, across all users.
    pub fn total_resubmitted(&self) -> usize {
        self.users.iter().map(|u| u.gridlets_resubmitted).sum()
    }

    /// Total lost Gridlets abandoned by broker policy, across all users.
    pub fn total_abandoned(&self) -> usize {
        self.users.iter().map(|u| u.gridlets_abandoned).sum()
    }

    /// Total Gridlets preempted off spot tiers, across all users.
    pub fn total_preempted(&self) -> usize {
        self.users.iter().map(|u| u.gridlets_preempted).sum()
    }

    /// Mean experiment termination time (Figs 34/37).
    pub fn mean_finish_time(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.finish_time - u.start_time).sum::<f64>()
            / self.users.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Optimization;
    use crate::session::GridSession;

    fn run(scenario: &Scenario) -> ScenarioReport {
        GridSession::new(scenario).run_to_completion()
    }

    fn small_resource(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
        ResourceSpec {
            name: name.into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: pes,
            mips_per_pe: mips,
            policy: AllocPolicy::TimeShared,
            price,
            time_zone: 0.0,
            calendar: None,
        }
    }

    #[test]
    fn single_user_completes_everything_with_slack() {
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .resource(small_resource("R1", 2, 100.0, 2.0))
            .user(
                ExperimentSpec::task_farm(20, 1_000.0, 0.10)
                    .deadline(1_000.0)
                    .budget(100_000.0)
                    .optimization(Optimization::Cost),
            )
            .seed(42)
            .build();
        let report = run(&scenario);
        assert_eq!(report.users.len(), 1);
        assert!(report.all_finished());
        let u = &report.users[0];
        assert_eq!(u.gridlets_completed, 20, "ample deadline+budget: all done");
        assert!(u.budget_spent > 0.0);
        assert!(u.finish_time <= 1_000.0);
        // Cost optimization should favour the cheap resource.
        let r0 = u.per_resource.iter().find(|r| r.name == "R0").unwrap();
        let r1 = u.per_resource.iter().find(|r| r.name == "R1").unwrap();
        assert!(r0.gridlets_completed >= r1.gridlets_completed);
    }

    #[test]
    fn deterministic_runs() {
        let build = || {
            Scenario::builder()
                .resource(small_resource("R0", 2, 100.0, 1.0))
                .user(
                    ExperimentSpec::task_farm(10, 1_000.0, 0.10)
                        .deadline(500.0)
                        .budget(10_000.0),
                )
                .seed(7)
                .build()
        };
        let a = run(&build());
        let b = run(&build());
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.events, b.events);
        assert_eq!(a.users[0].gridlets_completed, b.users[0].gridlets_completed);
        assert_eq!(a.users[0].budget_spent, b.users[0].budget_spent);
    }

    #[test]
    fn zero_budget_processes_nothing() {
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .user(ExperimentSpec::task_farm(5, 1_000.0, 0.0).deadline(100.0).budget(0.0))
            .seed(1)
            .build();
        let report = run(&scenario);
        assert_eq!(report.users[0].gridlets_completed, 0);
        assert_eq!(report.users[0].budget_spent, 0.0);
    }

    #[test]
    fn tight_deadline_processes_fewer() {
        let run_with_deadline = |d: f64| {
            let scenario = Scenario::builder()
                .resource(small_resource("R0", 2, 100.0, 1.0))
                .user(ExperimentSpec::task_farm(40, 1_000.0, 0.10).deadline(d).budget(1e9))
                .seed(3)
                .build();
            run(&scenario).users[0].gridlets_completed
        };
        let tight = run_with_deadline(30.0);
        let loose = run_with_deadline(10_000.0);
        assert_eq!(loose, 40);
        assert!(tight < loose, "tight {tight} < loose {loose}");
    }

    #[test]
    fn user_spec_wraps_and_forwards() {
        let spec: UserSpec = ExperimentSpec::task_farm(5, 100.0, 0.0).into();
        assert!(spec.advisor.is_none());
        assert!(spec.broker.is_none());
        let spec = spec
            .deadline(50.0)
            .budget(500.0)
            .optimization(Optimization::Time)
            .advisor(AdvisorKind::Native)
            .broker(BrokerConfig { min_tick: 2.0, ..BrokerConfig::default() })
            .submit_delay(3.0);
        assert_eq!(spec.experiment.optimization, Optimization::Time);
        assert_eq!(spec.advisor, Some(AdvisorKind::Native));
        assert_eq!(spec.broker.as_ref().unwrap().min_tick, 2.0);
        assert_eq!(spec.submit_delay, 3.0);
    }

    #[test]
    fn heterogeneous_users_build() {
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .user(ExperimentSpec::task_farm(5, 100.0, 0.0).optimization(Optimization::Cost))
            .user(
                UserSpec::new(
                    ExperimentSpec::task_farm(5, 100.0, 0.0).optimization(Optimization::Time),
                )
                .broker(BrokerConfig { max_gridlets_per_pe: 1, ..BrokerConfig::default() }),
            )
            .seed(1)
            .build();
        assert_eq!(scenario.users.len(), 2);
        assert!(scenario.users[0].broker.is_none(), "defaults untouched");
        assert_eq!(scenario.users[1].broker.as_ref().unwrap().max_gridlets_per_pe, 1);
    }
}
