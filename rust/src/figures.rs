//! Figure/table regeneration harness — one entry point per table and figure
//! of the paper's evaluation (§3.5 Table 1; §5 Table 2, Figures 21–38).
//!
//! Each function returns CSV series shaped like the paper's plots; the
//! `repro figures` CLI writes them under `results/`. Absolute values depend
//! on this reimplementation, but the *shapes* (who wins, saturation points,
//! crossovers) are asserted against the paper in `rust/tests/`.
//!
//! Every multi-cell grid is a [`SweepSpec`] executed by the parallel sweep
//! engine ([`crate::sweep::run_sweep`]) — there are no hand-rolled scenario
//! loops here. [`FigureConfig::jobs`] sets the worker count; per-cell
//! deterministic seeding makes the output identical at any value.

use crate::broker::{ExperimentSpec, Optimization};
use crate::config::testbed::{mips_per_dollar, wwg_testbed};
use crate::output::csv::CsvWriter;
use crate::scenario::{AdvisorKind, Scenario};
use crate::session::GridSession;
use crate::sweep::{run_sweep, SweepResults, SweepSpec};

/// The paper's §5.3 deadline axis: 100–3600 in steps of 500.
pub fn paper_deadlines() -> Vec<f64> {
    (0..8).map(|i| 100.0 + 500.0 * i as f64).collect()
}

/// The paper's §5.3 budget axis: 5000–22000 in steps of 1000.
pub fn paper_budgets() -> Vec<f64> {
    (0..18).map(|i| 5_000.0 + 1_000.0 * i as f64).collect()
}

/// Figure-grid configuration: `paper` reproduces the exact §5 grids; the
/// reduced `quick` grid keeps CI fast.
#[derive(Debug, Clone)]
pub struct FigureConfig {
    /// Deadline axis for the deadline×budget grids ([`figs21_24`]).
    pub deadlines: Vec<f64>,
    /// Budget axis for the deadline×budget and per-resource grids.
    pub budgets: Vec<f64>,
    /// Gridlets per user in every generated workload (the paper uses 200).
    pub gridlets: usize,
    /// User-count axis for the competition figures ([`figs33_38`],
    /// [`fig_market`], [`fig_workflow`]).
    pub user_counts: Vec<usize>,
    /// Mean inter-arrival axis for the day/night arrival figure
    /// ([`fig_day_night`]).
    pub arrival_means: Vec<f64>,
    /// Access-link capacity axis (bits per time unit) for the flow-network
    /// contention figure ([`fig_network_load`]).
    pub link_capacities: Vec<f64>,
    /// MTBF-scaling axis (fault severity) for the robustness figure
    /// ([`fig_robustness`]); 1 is the base failure rate, smaller is harsher.
    pub mtbf_scalings: Vec<f64>,
    /// Base RNG seed; every sweep cell derives its own stream from it.
    pub seed: u64,
    /// Advisor engine for cost-optimization (native or AOT artifact).
    pub advisor: AdvisorKind,
    /// Sweep-engine worker threads (results are identical at any value).
    pub jobs: usize,
}

impl FigureConfig {
    /// The full §5 grids (8 deadlines × 18 budgets, 200 Gridlets, user
    /// counts to 100) — minutes of CPU, for `repro figures --paper`.
    pub fn paper() -> FigureConfig {
        FigureConfig {
            deadlines: paper_deadlines(),
            budgets: paper_budgets(),
            gridlets: 200,
            user_counts: vec![1, 10, 20, 30, 40, 50, 60, 70, 80, 90, 100],
            arrival_means: vec![2.0, 5.0, 10.0, 20.0, 40.0],
            link_capacities: vec![1_200.0, 2_400.0, 4_800.0, 9_600.0, 19_200.0, 38_400.0],
            mtbf_scalings: vec![0.125, 0.25, 0.5, 1.0, 2.0, 4.0],
            seed: 27,
            advisor: AdvisorKind::Native,
            jobs: 1,
        }
    }

    /// Reduced grid for tests/quick runs.
    pub fn quick() -> FigureConfig {
        FigureConfig {
            deadlines: vec![100.0, 1_100.0, 3_100.0],
            budgets: vec![5_000.0, 10_000.0, 22_000.0],
            gridlets: 100,
            user_counts: vec![1, 5, 10],
            arrival_means: vec![5.0, 20.0],
            link_capacities: vec![2_400.0, 19_200.0],
            mtbf_scalings: vec![0.25, 1.0, 4.0],
            seed: 27,
            advisor: AdvisorKind::Native,
            jobs: 1,
        }
    }

    /// Worker-thread builder (`1` = serial).
    pub fn jobs(mut self, jobs: usize) -> FigureConfig {
        self.jobs = jobs.max(1);
        self
    }

    /// The single-user WWG base scenario all single-user figure grids sweep
    /// over (deadline/budget placeholders — every cell overrides them).
    fn single_user_base(&self) -> Scenario {
        Scenario::builder()
            .resources(wwg_testbed())
            .user(
                ExperimentSpec::task_farm(self.gridlets, 10_000.0, 0.10)
                    .deadline(3_100.0)
                    .budget(22_000.0)
                    .optimization(Optimization::Cost),
            )
            .seed(self.seed)
            .advisor(self.advisor.clone())
            .build()
    }
}

/// Run a figure grid, panicking with the engine's error on failure (figure
/// functions return plain CSV; an advisor that cannot initialize is fatal
/// here exactly as it was for the serial loops).
fn sweep(spec: &SweepSpec, jobs: usize) -> SweepResults {
    run_sweep(spec, jobs).unwrap_or_else(|e| panic!("figure sweep failed: {e}"))
}

/// One (deadline, budget) cell as a plain session run — no worker pool for
/// a single deterministic cell.
fn run_single(deadline: f64, budget: f64, cfg: &FigureConfig) -> crate::scenario::ScenarioReport {
    let mut scenario = cfg.single_user_base();
    scenario.users[0] = scenario.users[0].clone().deadline(deadline).budget(budget);
    GridSession::try_new(&scenario)
        .unwrap_or_else(|e| panic!("figure run failed: {e}"))
        .run_to_completion()
}

/// Table 1: the 3-Gridlet time- vs space-shared scheduling scenario.
pub fn table1() -> CsvWriter {
    use crate::gridsim::{
        gridlet::Gridlet, res_gridlet::ResGridlet, resource::LocalScheduler,
        space_shared::SpaceShared, time_shared::TimeShared, SpacePolicy,
    };
    let arrivals = [(1usize, 10.0, 0.0), (2, 8.5, 4.0), (3, 9.5, 7.0)];
    let drive = |sched: &mut dyn LocalScheduler| -> Vec<(usize, f64, f64)> {
        let mut out = vec![];
        let mut pending: Vec<(usize, f64, f64)> = arrivals.to_vec();
        let mut now = 0.0;
        while out.len() < 3 {
            // Next event: earliest of (arrival, completion).
            let next_arr = pending.first().map(|&(_, _, t)| t).unwrap_or(f64::INFINITY);
            let next_done = sched.next_completion(now).unwrap_or(f64::INFINITY);
            if next_arr <= next_done {
                now = next_arr;
                let (id, mi, t) = pending.remove(0);
                sched.submit(ResGridlet::new(Gridlet::new(id, mi, 0, 0), t, id as u64), t);
            } else {
                now = next_done;
                for rg in sched.collect(now) {
                    out.push((rg.gridlet.id, rg.gridlet.finish_time, rg.gridlet.elapsed()));
                }
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    };
    let mut ts = TimeShared::new(2, 1.0);
    let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::Fcfs);
    let t = drive(&mut ts);
    let s = drive(&mut ss);
    let mut csv = CsvWriter::new(&[
        "gridlet",
        "length_mi",
        "arrival",
        "ts_finish",
        "ts_elapsed",
        "ss_finish",
        "ss_elapsed",
    ]);
    for ((id, mi, arr), ((_, tf, te), (_, sf, se))) in
        arrivals.iter().zip(t.iter().zip(s.iter()))
    {
        csv.row_f64(&[*id as f64, *mi, *arr, *tf, *te, *sf, *se]);
    }
    csv
}

/// Table 2: the WWG testbed.
pub fn table2() -> CsvWriter {
    let mut csv = CsvWriter::new(&[
        "name", "arch", "pes", "mips", "manager", "price_g$", "mips_per_g$",
    ]);
    for r in wwg_testbed() {
        csv.row(&[
            r.name.clone(),
            r.arch.clone(),
            r.num_pe().to_string(),
            format!("{}", r.mips_per_pe),
            if r.policy.is_time_shared() { "time-shared".into() } else { "space-shared".into() },
            format!("{}", r.price),
            format!("{:.2}", mips_per_dollar(&r)),
        ]);
    }
    csv
}

/// Figures 21–24: the single-user DBC cost-optimization sweep. Returns one
/// CSV with a row per (deadline, budget) cell carrying all three metrics.
pub fn figs21_24(cfg: &FigureConfig) -> CsvWriter {
    let mut csv = CsvWriter::new(&[
        "deadline", "budget", "gridlets_done", "time_used", "budget_spent",
    ]);
    // An empty axis is an empty grid (header-only CSV), not a sweep over
    // the base value.
    if cfg.deadlines.is_empty() || cfg.budgets.is_empty() {
        return csv;
    }
    let spec = SweepSpec::over(cfg.single_user_base())
        .deadlines(cfg.deadlines.clone())
        .budgets(cfg.budgets.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let u = &outcome.report.users[0];
        csv.row_f64(&[
            outcome.cell.deadline.expect("deadline axis"),
            outcome.cell.budget.expect("budget axis"),
            u.gridlets_completed as f64,
            u.finish_time - u.start_time,
            u.budget_spent,
        ]);
    }
    csv
}

/// Figures 25–27: per-resource Gridlet counts vs budget at a fixed deadline
/// (the paper uses 100 / 1100 / 3100).
pub fn figs25_27(deadline: f64, cfg: &FigureConfig) -> CsvWriter {
    let names: Vec<String> = wwg_testbed().iter().map(|r| r.name.clone()).collect();
    let mut header: Vec<&str> = vec!["budget", "all"];
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    header.extend(name_refs);
    let mut csv = CsvWriter::new(&header);
    if cfg.budgets.is_empty() {
        return csv;
    }
    let spec = SweepSpec::over(cfg.single_user_base())
        .deadlines(vec![deadline])
        .budgets(cfg.budgets.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let u = &outcome.report.users[0];
        let mut row = vec![outcome.cell.budget.expect("budget axis"), u.gridlets_completed as f64];
        for n in &names {
            let done = u
                .per_resource
                .iter()
                .find(|r| &r.name == n)
                .map(|r| r.gridlets_completed)
                .unwrap_or(0);
            row.push(done as f64);
        }
        csv.row_f64(&row);
    }
    csv
}

/// Figures 28–32: time-trace of Gridlets completed / committed and budget
/// spent per resource for one (deadline, budget) cell.
pub fn figs28_32(deadline: f64, budget: f64, cfg: &FigureConfig) -> CsvWriter {
    let report = run_single(deadline, budget, cfg);
    let mut csv = CsvWriter::new(&["time", "resource", "completed", "committed", "spent"]);
    for p in &report.users[0].trace {
        csv.row(&[
            format!("{:.2}", p.time),
            p.resource.clone(),
            p.completed.to_string(),
            p.committed.to_string(),
            format!("{:.2}", p.spent),
        ]);
    }
    csv
}

/// Figures 33–38: multi-user competition — mean Gridlets done, termination
/// time and budget spent per user, for each (users, budget) cell at a fixed
/// deadline (3100 for Figs 33–35, 10000 for Figs 36–38).
pub fn figs33_38(deadline: f64, cfg: &FigureConfig) -> CsvWriter {
    let mut csv = CsvWriter::new(&[
        "users", "budget", "mean_gridlets_done", "mean_termination_time", "mean_budget_spent",
    ]);
    if cfg.user_counts.is_empty() || cfg.budgets.is_empty() {
        return csv;
    }
    let spec = SweepSpec::over(cfg.single_user_base())
        .deadlines(vec![deadline])
        .budgets(cfg.budgets.clone())
        .user_counts(cfg.user_counts.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        csv.row_f64(&[
            outcome.cell.users.expect("users axis") as f64,
            outcome.cell.budget.expect("budget axis"),
            outcome.report.mean_completed(),
            outcome.report.mean_finish_time(),
            outcome.report.mean_spent(),
        ]);
    }
    csv
}

/// Day/night arrivals (beyond the paper's closed batches): one user whose
/// jobs stream in under a rate-modulated Poisson process — rate 1× for the
/// "day" half of each 2000-unit cycle, 0.25× for the "night" half — swept
/// over the base mean inter-arrival ([`FigureConfig::arrival_means`]).
/// Constraints are kept loose so the CSV isolates the arrival dynamics:
/// one row per arrival-mean cell with completions, makespan and spend.
pub fn fig_day_night(cfg: &FigureConfig) -> CsvWriter {
    use crate::workload::{ArrivalProcess, RateEnvelope, WorkloadSpec};
    let mut csv = CsvWriter::new(&[
        "arrival_mean", "gridlets_done", "gridlets_total", "time_used", "budget_spent",
    ]);
    if cfg.arrival_means.is_empty() {
        return csv;
    }
    let workload = WorkloadSpec::online(
        WorkloadSpec::task_farm(cfg.gridlets, 10_000.0, 0.10),
        ArrivalProcess::Modulated {
            mean_interarrival: cfg.arrival_means[0],
            envelope: RateEnvelope::Piecewise { period: 2_000.0, rates: vec![1.0, 0.25] },
        },
    );
    let base = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::new(workload)
                .deadline(1e6)
                .budget(1e9)
                .optimization(Optimization::Cost),
        )
        .seed(cfg.seed)
        .advisor(cfg.advisor.clone())
        .build();
    let spec = SweepSpec::over(base).mean_interarrivals(cfg.arrival_means.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let u = &outcome.report.users[0];
        csv.row_f64(&[
            outcome.cell.mean_interarrival.expect("arrival-mean axis"),
            u.gridlets_completed as f64,
            u.gridlets_total as f64,
            u.finish_time - u.start_time,
            u.budget_spent,
        ]);
    }
    csv
}

/// Network-load figure (beyond the paper's closed batches): several users
/// whose jobs stream in through a contended [`crate::network::FlowLink`]
/// access network, swept over the shared default link capacity
/// ([`FigureConfig::link_capacities`]). Every arrival message and gridlet
/// transfer fair-shares its endpoints' links, so shrinking the capacity
/// stretches release and staging times. One row per capacity cell:
/// completions, makespan, and the makespan slowdown relative to the
/// *fastest* capacity in the axis (slowdown ≥ 1, = 1 at the best cell).
pub fn fig_network_load(cfg: &FigureConfig) -> CsvWriter {
    use crate::scenario::NetworkSpec;
    use crate::workload::{ArrivalProcess, WorkloadSpec};
    let mut csv = CsvWriter::new(&[
        "link_capacity", "gridlets_done", "gridlets_total", "time_used", "slowdown",
    ]);
    if cfg.link_capacities.is_empty() {
        return csv;
    }
    let users = 4;
    let per_user = (cfg.gridlets / users).max(1);
    let workload = |seed_shift: f64| {
        WorkloadSpec::online(
            WorkloadSpec::task_farm(per_user, 10_000.0, 0.10),
            ArrivalProcess::Poisson { mean_interarrival: 20.0 + seed_shift },
        )
    };
    let mut builder = Scenario::builder().resources(wwg_testbed());
    for u in 0..users {
        // Slightly different arrival means so the users' flows interleave
        // rather than lock-step.
        builder = builder.user(
            ExperimentSpec::new(workload(u as f64))
                .deadline(1e6)
                .budget(1e9)
                .optimization(Optimization::Cost),
        );
    }
    let base = builder
        .network(NetworkSpec::Flow {
            // Placeholder — every cell overrides it via the sweep axis.
            default_capacity: cfg.link_capacities[0],
            latency: 0.05,
            capacities: vec![],
        })
        .seed(cfg.seed)
        .advisor(cfg.advisor.clone())
        .build();
    let spec = SweepSpec::over(base).link_capacities(cfg.link_capacities.clone());
    let results = sweep(&spec, cfg.jobs);
    // Slowdown is normalized to the fastest makespan in the grid.
    let best = results
        .outcomes
        .iter()
        .map(|o| o.report.mean_finish_time())
        .fold(f64::INFINITY, f64::min);
    for outcome in &results.outcomes {
        let done: usize = outcome.report.users.iter().map(|u| u.gridlets_completed).sum();
        let total: usize = outcome.report.users.iter().map(|u| u.gridlets_total).sum();
        let makespan = outcome.report.mean_finish_time();
        csv.row_f64(&[
            outcome.cell.link_capacity.expect("link-capacity axis"),
            done as f64,
            total as f64,
            makespan,
            if best > 0.0 { makespan / best } else { 1.0 },
        ]);
    }
    csv
}

/// Robustness figure (reliability layer, beyond the paper's always-up
/// testbed): the WWG grid under stochastic failure–repair processes, swept
/// over DBC policy × MTBF scaling ([`FigureConfig::mtbf_scalings`]). The
/// broker *abandons* Gridlets drained by a failure, so each policy's
/// completion rate directly exposes how much work it had in flight on the
/// resources that went down. Common random numbers across cells: the fault
/// timeline at scaling `s` is the base timeline with uptimes stretched by
/// `s`, so shrinking MTBF monotonically adds failures rather than drawing a
/// fresh, incomparable schedule. One row per (policy, scaling) cell.
pub fn fig_robustness(cfg: &FigureConfig) -> CsvWriter {
    use crate::broker::{BrokerConfig, ResubmissionPolicy};
    use crate::faults::{FaultProcess, FaultsSpec};
    let mut csv = CsvWriter::new(&[
        "policy",
        "mtbf_scaling",
        "completion_rate",
        "gridlets_done",
        "gridlets_total",
        "gridlets_lost",
        "gridlets_abandoned",
        "budget_spent",
    ]);
    if cfg.mtbf_scalings.is_empty() {
        return csv;
    }
    // Base failure process: a resource stays up ~1500 time units and needs
    // ~150 to repair — a handful of outages over the 3100-unit deadline at
    // scaling 1, near-constant churn at 0.125, near-clean at 4.
    let base = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(cfg.gridlets, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .broker_config(BrokerConfig {
            resubmission: ResubmissionPolicy::Abandon,
            ..BrokerConfig::default()
        })
        .faults(FaultsSpec::all(FaultProcess::Exponential { mtbf: 1_500.0, mttr: 150.0 }))
        .seed(cfg.seed)
        .advisor(cfg.advisor.clone())
        .build();
    let spec = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time])
        .mtbf_scalings(cfg.mtbf_scalings.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let report = &outcome.report;
        let done: usize = report.users.iter().map(|u| u.gridlets_completed).sum();
        let total: usize = report.users.iter().map(|u| u.gridlets_total).sum();
        let spent: f64 = report.users.iter().map(|u| u.budget_spent).sum();
        let mut fields = vec![outcome.cell.policy.expect("policy axis").label().to_string()];
        fields.extend(
            [
                outcome.cell.mtbf_scaling.expect("mtbf-scaling axis"),
                report.mean_completion_rate(),
                done as f64,
                total as f64,
                report.total_lost() as f64,
                report.total_abandoned() as f64,
                spent,
            ]
            .iter()
            .map(|x| crate::output::csv::trim_float(*x)),
        );
        csv.row(&fields);
    }
    csv
}

/// Market-equilibrium figure (economic layer, beyond the paper's static
/// Table 2 prices): the WWG grid under utilization-linear pricing — every
/// resource's posted price climbs from its Table 2 base toward 2× as the
/// resource fills — with a preemptible spot tier (discount 0.6) on the five
/// cheapest resources, swept over DBC policy × user count (offered load).
/// Every user bids 2.5 G$ for spot capacity: affordable on an idle tier,
/// crossed on the 3-G$ resources once demand lifts the posted price, so
/// rising load converts cheap spot work into preemptions and pushes jobs
/// back to on-demand capacity. One row per (policy, users) cell;
/// `mean_price_paid` is the mean G$ actually charged per completed Gridlet
/// (charge-at-execution, partial spot charges included), tracing the demand
/// curve toward its congested equilibrium.
pub fn fig_market(cfg: &FigureConfig) -> CsvWriter {
    use crate::market::{MarketSpec, PriceModel};
    let mut csv = CsvWriter::new(&[
        "policy",
        "users",
        "mean_price_paid",
        "completion_rate",
        "gridlets_done",
        "gridlets_total",
        "gridlets_preempted",
        "budget_spent",
    ]);
    if cfg.user_counts.is_empty() {
        return csv;
    }
    let mut market = MarketSpec::new();
    for r in wwg_testbed() {
        market = market.pricing_for(
            r.name.clone(),
            PriceModel::UtilizationLinear {
                base: r.price,
                slope: r.price,
                floor: r.price,
                cap: 2.0 * r.price,
            },
        );
        // Spot on the cheap half of the testbed only, so preempted work
        // always has on-demand capacity to fall back to.
        if r.price <= 3.0 {
            market = market.spot_for(r.name.clone(), 0.6);
        }
    }
    let mut base = cfg.single_user_base();
    base.market = Some(market);
    base.users[0].max_spot_price = Some(2.5);
    let spec = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time])
        .user_counts(cfg.user_counts.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let report = &outcome.report;
        let done: usize = report.users.iter().map(|u| u.gridlets_completed).sum();
        let total: usize = report.users.iter().map(|u| u.gridlets_total).sum();
        let spent: f64 = report.users.iter().map(|u| u.budget_spent).sum();
        let mut fields = vec![outcome.cell.policy.expect("policy axis").label().to_string()];
        fields.extend(
            [
                outcome.cell.users.expect("users axis") as f64,
                if done > 0 { spent / done as f64 } else { 0.0 },
                if total > 0 { done as f64 / total as f64 } else { 0.0 },
                done as f64,
                total as f64,
                report.total_preempted() as f64,
                spent,
            ]
            .iter()
            .map(|x| crate::output::csv::trim_float(*x)),
        );
        csv.row(&fields);
    }
    csv
}

/// Workflow figure (DAG layer, beyond the paper's independent task farms):
/// a fork–join workflow — one prep stage fanning out to heterogeneous
/// simulation branches that a post stage joins — on the WWG testbed, swept
/// over DBC policy × user count. The DAG materializes in descending
/// upward-rank order and children are precedence-released as parents
/// complete, so the HEFT cell exercises the full list-scheduling path while
/// cost/time cells schedule the same eligible jobs with the paper's DBC
/// heuristics. Constraints are loose (every job completes in every cell),
/// so the CSV isolates *makespan*: one row per (policy, users) cell.
pub fn fig_workflow(cfg: &FigureConfig) -> CsvWriter {
    use crate::workload::{DagNode, WorkloadSpec};
    let mut csv = CsvWriter::new(&[
        "policy", "users", "makespan", "gridlets_done", "gridlets_total", "budget_spent",
    ]);
    if cfg.user_counts.is_empty() {
        return csv;
    }
    // Branch lengths step from 8k to 24k MI so list scheduling has real
    // choices: the long branches dominate the critical path and rank-ordered
    // ids put them first in the broker's pool.
    let width = (cfg.gridlets / 10).max(2);
    let mut nodes = vec![DagNode::new("prep", 5_000.0)];
    let mut edges = Vec::new();
    for b in 0..width {
        let name = format!("sim{b}");
        let mi = 8_000.0 + 16_000.0 * b as f64 / (width - 1).max(1) as f64;
        nodes.push(DagNode::new(name.clone(), mi));
        edges.push(("prep".to_string(), name.clone()));
        edges.push((name, "post".to_string()));
    }
    nodes.push(DagNode::new("post", 5_000.0));
    let base = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::new(WorkloadSpec::dag(nodes, edges))
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(cfg.seed)
        .advisor(cfg.advisor.clone())
        .build();
    let spec = SweepSpec::over(base)
        .policies(vec![Optimization::Cost, Optimization::Time, Optimization::Heft])
        .user_counts(cfg.user_counts.clone());
    let results = sweep(&spec, cfg.jobs);
    for outcome in &results.outcomes {
        let report = &outcome.report;
        let done: usize = report.users.iter().map(|u| u.gridlets_completed).sum();
        let total: usize = report.users.iter().map(|u| u.gridlets_total).sum();
        let spent: f64 = report.users.iter().map(|u| u.budget_spent).sum();
        let mut fields = vec![outcome.cell.policy.expect("policy axis").label().to_string()];
        fields.extend(
            [
                outcome.cell.users.expect("users axis") as f64,
                report.mean_finish_time(),
                done as f64,
                total as f64,
                spent,
            ]
            .iter()
            .map(|x| crate::output::csv::trim_float(*x)),
        );
        csv.row(&fields);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_numbers() {
        let csv = table1().to_string();
        // G1: ts 10/10, ss 10/10 ; G2: ts 14/10, ss 12.5/8.5 ; G3: ts 18/11, ss 19.5/12.5
        assert!(csv.contains("1,10,0,10,10,10,10"), "{csv}");
        assert!(csv.contains("2,8.5000,4,14,10,12.5000,8.5000"), "{csv}");
        assert!(csv.contains("3,9.5000,7,18,11,19.5000,12.5000"), "{csv}");
    }

    #[test]
    fn table2_has_all_rows() {
        let csv = table2().to_string();
        assert_eq!(csv.lines().count(), 12); // header + 11 resources
        assert!(csv.contains("R8"));
        assert!(csv.contains("380.00")); // R8 MIPS/G$
    }

    #[test]
    fn quick_sweep_produces_grid() {
        let cfg = FigureConfig { gridlets: 20, ..FigureConfig::quick() };
        let csv = figs21_24(&cfg);
        assert_eq!(csv.len(), cfg.deadlines.len() * cfg.budgets.len());
    }

    #[test]
    fn parallel_figures_match_serial() {
        let cfg = FigureConfig {
            gridlets: 20,
            deadlines: vec![100.0, 3_100.0],
            budgets: vec![5_000.0, 22_000.0],
            ..FigureConfig::quick()
        };
        let serial = figs21_24(&cfg).to_string();
        let parallel = figs21_24(&cfg.clone().jobs(4)).to_string();
        assert_eq!(serial, parallel, "figure grids are jobs-invariant");
    }

    #[test]
    fn day_night_rows_per_arrival_mean() {
        let cfg = FigureConfig {
            gridlets: 15,
            arrival_means: vec![2.0, 10.0],
            ..FigureConfig::quick()
        };
        let csv = fig_day_night(&cfg);
        assert_eq!(csv.len(), 2, "one row per arrival-mean cell");
        let text = csv.to_string();
        assert!(text.starts_with("arrival_mean,"), "{text}");
        // Loose constraints: everything completes in both cells.
        for line in text.lines().skip(1) {
            let fields: Vec<&str> = line.split(',').collect();
            assert_eq!(fields[1], fields[2], "done == total under loose constraints");
        }
    }

    #[test]
    fn network_load_rows_per_capacity() {
        let cfg = FigureConfig {
            gridlets: 16,
            link_capacities: vec![1_200.0, 38_400.0],
            ..FigureConfig::quick()
        };
        let csv = fig_network_load(&cfg);
        assert_eq!(csv.len(), 2, "one row per link-capacity cell");
        let text = csv.to_string();
        assert!(text.starts_with("link_capacity,"), "{text}");
        let rows: Vec<Vec<f64>> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|f| f.parse().unwrap()).collect())
            .collect();
        // Slowdown is normalized: the fastest cell reads exactly 1, the
        // starved 1200 b/u link is strictly slower than 38400 b/u.
        let slow = &rows[0];
        let fast = &rows[1];
        assert_eq!(fast[4], 1.0, "fastest capacity defines slowdown 1: {text}");
        assert!(slow[4] > 1.0, "contended link must slow the run: {text}");
        assert!(slow[3] > fast[3], "makespan grows as capacity shrinks: {text}");
    }

    #[test]
    fn robustness_rows_per_policy_and_scaling() {
        let cfg = FigureConfig {
            gridlets: 20,
            mtbf_scalings: vec![0.25, 4.0],
            ..FigureConfig::quick()
        };
        let csv = fig_robustness(&cfg);
        assert_eq!(csv.len(), 4, "two policies x two MTBF scalings");
        let text = csv.to_string();
        assert!(text.starts_with("policy,mtbf_scaling,completion_rate,"), "{text}");
        // Rows come out policy-major (cost 0.25, cost 4, time 0.25, time 4).
        let rows: Vec<(String, Vec<f64>)> = text
            .lines()
            .skip(1)
            .map(|l| {
                let mut it = l.split(',');
                let policy = it.next().unwrap().to_string();
                (policy, it.map(|f| f.parse().unwrap()).collect())
            })
            .collect();
        assert_eq!(rows[0].0, "cost");
        assert_eq!(rows[2].0, "time");
        for pair in rows.chunks(2) {
            let (harsh, clean) = (&pair[0].1, &pair[1].1);
            assert_eq!(harsh[0], 0.25, "{text}");
            assert_eq!(clean[0], 4.0, "{text}");
            // Shrinking MTBF can only remove completions under CRN + Abandon.
            assert!(harsh[1] <= clean[1], "completion degrades with MTBF: {text}");
            // Under Abandon every drained Gridlet is abandoned exactly once.
            assert_eq!(harsh[4], harsh[5], "lost == abandoned under Abandon: {text}");
        }
        // The harsh cost cell (mean uptime 375 across 11 resources over a
        // ~3100-unit horizon) must actually lose work.
        assert!(rows[0].1[4] >= 1.0, "harsh cell loses Gridlets: {text}");
        assert!(rows[0].1[1] < 1.0, "harsh cell completion rate < 1: {text}");
    }

    #[test]
    fn market_rows_per_policy_and_load() {
        let cfg = FigureConfig {
            gridlets: 15,
            user_counts: vec![1, 6],
            ..FigureConfig::quick()
        };
        let csv = fig_market(&cfg);
        assert_eq!(csv.len(), 4, "two policies x two user counts");
        let text = csv.to_string();
        assert!(
            text.starts_with("policy,users,mean_price_paid,completion_rate,"),
            "{text}"
        );
        // Rows come out policy-major (cost 1, cost 6, time 1, time 6).
        let rows: Vec<(String, Vec<f64>)> = text
            .lines()
            .skip(1)
            .map(|l| {
                let mut it = l.split(',');
                let policy = it.next().unwrap().to_string();
                (policy, it.map(|f| f.parse().unwrap()).collect())
            })
            .collect();
        assert_eq!(rows[0].0, "cost");
        assert_eq!(rows[2].0, "time");
        for pair in rows.chunks(2) {
            let (light, heavy) = (&pair[0].1, &pair[1].1);
            assert_eq!(light[0], 1.0, "{text}");
            assert_eq!(heavy[0], 6.0, "{text}");
            for r in [light, heavy] {
                assert!((0.0..=1.0).contains(&r[2]), "completion rate in [0, 1]: {text}");
                assert!(r[1] >= 0.0 && r[5] >= 0.0, "prices and preemptions count up: {text}");
                assert!(r[3] > 0.0, "some work completes in every cell: {text}");
            }
            // Six competing users offer 6x the work, so total spend must
            // exceed the single-user cell's under common random numbers.
            assert!(heavy[6] > light[6], "offered load drives total spend: {text}");
        }
    }

    #[test]
    fn workflow_rows_per_policy_and_users() {
        let cfg = FigureConfig {
            gridlets: 40, // fork–join width 4 → 6 jobs per user
            user_counts: vec![1, 4],
            ..FigureConfig::quick()
        };
        let csv = fig_workflow(&cfg);
        assert_eq!(csv.len(), 6, "three policies x two user counts");
        let text = csv.to_string();
        assert!(text.starts_with("policy,users,makespan,"), "{text}");
        // Rows come out policy-major in axis order (cost, time, heft).
        let rows: Vec<(String, Vec<f64>)> = text
            .lines()
            .skip(1)
            .map(|l| {
                let mut it = l.split(',');
                let policy = it.next().unwrap().to_string();
                (policy, it.map(|f| f.parse().unwrap()).collect())
            })
            .collect();
        assert_eq!(rows[0].0, "cost");
        assert_eq!(rows[2].0, "time");
        assert_eq!(rows[4].0, "heft");
        for (policy, r) in &rows {
            // Loose constraints: the whole workflow completes in every cell,
            // so the figure isolates makespan.
            assert_eq!(r[2], r[3], "{policy}: done == total: {text}");
            assert!(r[1] > 0.0, "{policy}: positive makespan: {text}");
            assert!(r[4] > 0.0, "{policy}: positive spend: {text}");
        }
    }

    #[test]
    fn resource_selection_columns() {
        let cfg = FigureConfig {
            gridlets: 20,
            budgets: vec![22_000.0],
            ..FigureConfig::quick()
        };
        let csv = figs25_27(3_100.0, &cfg).to_string();
        let header = csv.lines().next().unwrap();
        assert!(header.starts_with("budget,all,R0,R1"));
        assert!(header.ends_with("R10"));
    }
}
