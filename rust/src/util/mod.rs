//! Support utilities built in-tree because the image has no crates.io access
//! beyond the vendored `xla`/`anyhow` set: a seedable RNG, a JSON
//! parser/serializer for config and results, a CLI argument parser, a mini
//! property-testing runner, and summary statistics.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
