//! Mini property-based testing runner (the image has no `proptest`).
//!
//! Runs a property against `n` generated cases from a seeded [`Rng`] and, on
//! failure, reports the case index and the per-case seed so the exact input
//! can be regenerated in isolation. No shrinking — generators are kept small
//! and structured instead.

use super::rng::Rng;

/// Run `prop` on `cases` inputs produced by `gen`. Panics (test failure) on
/// the first violated case with a reproduction seed.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: u32,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (case_seed={case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |rng| rng.uniform(0.0, 100.0),
            |&x| check(x >= 0.0 && x < 100.0, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_repro_info() {
        forall(2, 50, |rng| rng.below(10), |&x| check(x < 5, format!("{x} < 5")));
    }

    #[test]
    fn check_close_scales_tolerance() {
        assert!(check_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(check_close(1.0, 1.1, 1e-6, "small").is_err());
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<f64> = vec![];
        forall(7, 20, |rng| rng.next_f64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<f64> = vec![];
        forall(7, 20, |rng| rng.next_f64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
