//! Generic summary statistics used by the output/report layer.
//! (The paper's `gridsim.Accumulator` lives in `gridsim::statistics`; this
//! module adds quantiles and histograms for benchmark reporting.)

/// Streaming summary of a data series (Welford variance).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Standard error of the mean (`s / √n`; 0 for fewer than two samples).
    /// `mean ± 1.96·std_err` is the usual 95% confidence interval.
    pub fn std_err(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exact quantile of a sample (sorts a copy; fine at simulation scales).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty series");
    assert!((0.0..=1.0).contains(&q));
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.sum(), 40.0);
        // Sample variance of that classic series is 32/7.
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert!((s.std_err() - (32.0f64 / 7.0).sqrt() / 8.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn std_err_needs_two_samples() {
        let mut s = Summary::new();
        assert_eq!(s.std_err(), 0.0);
        s.add(5.0);
        assert_eq!(s.std_err(), 0.0);
        s.add(7.0);
        assert!(s.std_err() > 0.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.3) - 3.0).abs() < 1e-12);
    }
}
