//! Minimal JSON parser/serializer (the image has no `serde`).
//!
//! Supports the full JSON grammar: null, booleans, numbers (as `f64`),
//! strings with escapes (`\uXXXX` included), arrays, and objects. Objects
//! preserve insertion order so emitted config files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field {key:?}"))
    }

    /// Convert an object into a map for bulk consumption.
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Value>> {
        match self {
            Value::Obj(fields) => Some(fields.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected {:?}", b as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected {word:?}"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Value::Num(n)),
            Err(_) => self.err(format!("bad number {text:?}")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        match std::str::from_utf8(&self.bytes[start..self.pos]) {
                            Ok(s) => out.push_str(s),
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("unterminated \\u escape"),
            };
            let d = (c as char).to_digit(16);
            match d {
                Some(d) => v = v * 16 + d,
                None => return self.err("bad hex digit"),
            }
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing content");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..n {
                out.push_str("  ");
            }
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape_into(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                write_value(item, out, indent + 1, pretty);
            }
            if !items.is_empty() {
                pad(out, indent);
            }
            out.push(']');
        }
        Value::Obj(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape_into(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if !fields.is_empty() {
                pad(out, indent);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, false);
    out
}

/// Serialize with two-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"name":"R0","pes":4,"mips":515.5,"shared":true,"tags":[1,2,3],"extra":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Value::obj(vec![
            ("x", Value::from(1.0)),
            ("y", Value::Arr(vec![Value::from(true), Value::Null])),
        ]);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Value::Obj(fields) = &v {
            let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn req_helpers() {
        let v = parse(r#"{"a": 1, "b": "s"}"#).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.req_str("b").unwrap(), "s");
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_str("a").is_err());
    }

    #[test]
    fn error_offsets() {
        let err = parse("[1, x]").unwrap_err();
        assert_eq!(err.offset, 4);
    }
}
