//! Tiny CLI argument parser (the image has no `clap`).
//!
//! Grammar: `repro <subcommand> [--flag value] [--switch] [positional...]`.
//! `--flag=value` is also accepted. Unknown flags are collected and reported
//! by the caller so each subcommand can validate its own surface.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub flags: BTreeMap<String, String>,
    /// Bare `--switch` tokens.
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.switches.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Comma-separated typed list; `what` names the element kind in errors
    /// (and is the place to spell out the accepted values).
    pub fn flag_list<T: std::str::FromStr>(
        &self,
        key: &str,
        what: &str,
    ) -> anyhow::Result<Option<Vec<T>>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|tok| {
                    tok.trim().parse::<T>().map_err(|_| {
                        anyhow::anyhow!("--{key} expects comma-separated {what}, got {tok:?}")
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// Comma-separated numeric list: `--deadlines 100,600,1100`.
    pub fn flag_f64_list(&self, key: &str) -> anyhow::Result<Option<Vec<f64>>> {
        self.flag_list(key, "numbers")
    }

    /// Comma-separated integer list: `--users 1,10,20`.
    pub fn flag_usize_list(&self, key: &str) -> anyhow::Result<Option<Vec<usize>>> {
        self.flag_list(key, "integers")
    }

    pub fn has_switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["run", "scenario.json", "extra"]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["scenario.json", "extra"]);
    }

    #[test]
    fn flags_both_syntaxes() {
        let a = parse(&["run", "--policy", "cost", "--seed=42"]);
        assert_eq!(a.flag("policy"), Some("cost"));
        assert_eq!(a.flag("seed"), Some("42"));
    }

    #[test]
    fn switches() {
        let a = parse(&["figures", "--all", "--out", "results"]);
        assert!(a.has_switch("all"));
        assert_eq!(a.flag("out"), Some("results"));
        assert!(!a.has_switch("missing"));
    }

    #[test]
    fn trailing_switch_not_eating_next_flag() {
        let a = parse(&["x", "--verbose", "--seed", "7"]);
        assert!(a.has_switch("verbose"));
        assert_eq!(a.flag("seed"), Some("7"));
    }

    #[test]
    fn typed_flags() {
        let a = parse(&["x", "--d", "3.5", "--n", "12", "--bad", "xyz"]);
        assert_eq!(a.flag_f64("d").unwrap(), Some(3.5));
        assert_eq!(a.flag_usize("n").unwrap(), Some(12));
        assert!(a.flag_f64("bad").is_err());
        assert_eq!(a.flag_f64("absent").unwrap(), None);
    }

    #[test]
    fn list_flags() {
        let a = parse(&["x", "--deadlines", "100, 600,1100", "--users", "1,10", "--bad", "1,x"]);
        assert_eq!(a.flag_f64_list("deadlines").unwrap(), Some(vec![100.0, 600.0, 1_100.0]));
        assert_eq!(a.flag_usize_list("users").unwrap(), Some(vec![1, 10]));
        assert!(a.flag_usize_list("bad").is_err());
        assert_eq!(a.flag_f64_list("absent").unwrap(), None);
    }

    #[test]
    fn empty() {
        let a = parse(&[]);
        assert!(a.command.is_none());
        assert!(a.positional.is_empty());
    }
}
