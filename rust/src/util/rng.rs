//! Deterministic, seedable pseudo-random number generator.
//!
//! SplitMix64 to expand the seed, xoshiro256** for the stream — small, fast,
//! and adequate for simulation workloads. Every stochastic component of the
//! simulator (Gridlet length jitter, local-load noise, arrival processes)
//! draws from an explicitly seeded instance so whole runs are reproducible —
//! the paper's core motivation ("repeatable and controlled environment").

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// One SplitMix64 step: advance `state` by the golden-ratio increment and
/// return the finalized output. `pub(crate)` so seed-derivation helpers
/// (e.g. `sweep::replication_seed`) share one copy of the constants.
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a 64-bit seed. Two instances with the same seed produce
    /// identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream for a subcomponent (seed, stream-id).
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform double in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform double in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. Panics on `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire-style rejection-free enough for simulation purposes:
        // use the high bits via 128-bit multiply.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Exponentially distributed sample with the given mean (inter-arrival
    /// processes).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (used for local-load noise).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Shuffle a slice (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.uniform(5.0, 6.5);
            assert!((5.0..6.5).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn derive_is_independent_and_deterministic() {
        let root = Rng::new(99);
        let mut a1 = root.derive(1);
        let mut a2 = root.derive(1);
        let mut b = root.derive(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
