//! # GridSim — a Rust reproduction of the GridSim toolkit
//!
//! Reproduction of *GridSim: A Toolkit for the Modeling and Simulation of
//! Distributed Resource Management and Scheduling for Grid Computing*
//! (Buyya & Murshed, 2002) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map:
//! * [`des`] — deterministic discrete-event simulation kernel (the SimJava
//!   substrate, rebuilt as an event-handler model) with a stepped execution
//!   API: `init()` / `step()` / `run_until(t)` / `finalize()`.
//! * [`gridsim`] — the grid entity toolkit: PEs, machines, time-/space-shared
//!   resources, Gridlets, the information service, network delays,
//!   statistics, calendars and reservations.
//! * [`network`] — flow-level network models: [`network::FlowLink`]
//!   fair-shares access-link capacity among concurrent transfers, with
//!   per-flow finish events rescheduled in the DES queue on every flow
//!   start/finish (`gridsim::network::BaudLink` stays the zero-contention
//!   fast path).
//! * [`broker`] — the Nimrod-G-like economic resource broker with
//!   deadline-and-budget-constrained (DBC) scheduling policies and a
//!   configurable resubmission policy for jobs lost to resource failures.
//! * [`market`] — the economic market layer: utilization-driven dynamic
//!   pricing models ([`market::PriceModel`]) and the preemptible spot tier.
//!   Resources publish `PRICE_UPDATE` events as demand moves their price;
//!   brokers charge the price in effect while work ran, and spot jobs are
//!   preempted when the price crosses the user's bid.
//! * [`faults`] — the reliability layer: a [`faults::FaultInjector`] entity
//!   drives per-resource failure–repair processes (exponential, Weibull, or
//!   explicit up/down traces) from dedicated deterministic RNG streams, so
//!   MTBF sweeps hold common random numbers across cells.
//! * [`runtime`] — PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   advisor kernels (`artifacts/*.hlo.txt`) and executes them from the
//!   broker's scheduling hot path (behind the `xla` cargo feature).
//! * [`scenario`] / [`session`] — declarative scenario description (with
//!   per-user heterogeneity) and the composable `GridSession` execution
//!   handle.
//! * [`sweep`] — declarative parameter grids over a base scenario
//!   (deadline × budget × users × policy × resource subset × replications)
//!   executed on a multi-threaded worker pool with deterministic per-cell
//!   seeding: results are bit-identical at any `--jobs` value.
//! * [`config`] / [`workload`] — scenario configuration (incl. the WWG
//!   testbed of Table 2, and a strict JSON loader) and the first-class
//!   [`workload::WorkloadSpec`] application models: generative task farms
//!   and heavy-tailed mixes, explicit job lists, real-trace replay (legacy
//!   4-column and full 18-column SWF logs, split per user by
//!   [`workload::TraceSelector`]), declarative composition (`concat`/`mix`),
//!   online arrivals released mid-run (Poisson, fixed-interval, or
//!   day/night rate-modulated), and DAG workflows ([`workload::dag`])
//!   whose jobs are precedence-released as their parents complete, with
//!   HEFT-style list scheduling on the broker side. See
//!   `docs/ARCHITECTURE.md` for the paper-section ↔ module map and the
//!   online-arrival and workflow event flows.
//! * [`figures`] — the harness that regenerates every table and figure of
//!   the paper's evaluation section, plus the beyond-paper figures (arrival
//!   dynamics, network contention, robustness, market, workflows).
//!
//! ## The `GridSession` lifecycle
//!
//! Execution is organised around [`session::GridSession`]:
//! **build → step/observe → report**. Build a [`scenario::Scenario`]
//! (heterogeneous users override policy, advisor and broker tuning per
//! user via [`scenario::UserSpec`]), then drive it as far as you like,
//! probing broker state along the way (compile-checked; `no_run` because
//! rustdoc test binaries do not inherit the xla_extension rpath):
//!
//! ```no_run
//! use gridsim::broker::{BrokerConfig, ExperimentSpec, Optimization};
//! use gridsim::config::testbed::wwg_testbed;
//! use gridsim::scenario::{Scenario, UserSpec};
//! use gridsim::session::GridSession;
//!
//! let scenario = Scenario::builder()
//!     .resources(wwg_testbed())
//!     // Two users with *different* requirements: one cost-optimizes with
//!     // default broker tuning, one time-optimizes with a conservative
//!     // dispatcher — the scenario-level values stay the defaults.
//!     .user(ExperimentSpec::task_farm(100, 10_000.0, 0.10)
//!         .deadline(3_100.0)
//!         .budget(22_000.0)
//!         .optimization(Optimization::Cost))
//!     .user(UserSpec::new(ExperimentSpec::task_farm(100, 10_000.0, 0.10)
//!             .deadline(3_100.0)
//!             .budget(22_000.0)
//!             .optimization(Optimization::Time))
//!         .broker(BrokerConfig { max_gridlets_per_pe: 1, ..BrokerConfig::default() }))
//!     .seed(7)
//!     .build();
//!
//! // Build → step/observe → report. The horizon grows monotonically —
//! // `run_until` leaves the clock on the last dispatched event, so a
//! // clock-relative horizon could stall ahead of a sparse event queue.
//! let mut session = GridSession::new(&scenario);
//! session.init();
//! let mut horizon = 0.0;
//! while !session.is_idle() {
//!     horizon += 500.0;
//!     session.run_until(horizon);
//!     for user in &session.snapshot().users {
//!         println!("{}: {}/{} gridlets, {:.0} G$ spent",
//!             user.state, user.gridlets_completed, user.gridlets_total,
//!             user.budget_spent);
//!     }
//! }
//! let report = session.report();
//! assert!(report.outcomes.iter().all(|o| o.is_finished()));
//! ```
//!
//! Stepped execution is exact: a `run_until` sweep in any increments yields
//! results bit-identical to one `run_to_completion()`. For fire-and-forget
//! runs, `run_to_completion()` is the whole lifecycle in one call; for
//! parameter grids, build a [`sweep::SweepSpec`].

// Every public item must carry rustdoc (CI runs `cargo doc` with
// `-D warnings`). Modules that predate the policy carry a module-level
// `allow` below; remove an `allow` once its module is fully documented —
// never add a new one. `broker`, `workload`, `sweep`, `session`, `des`,
// `faults`, `figures`, `gridsim`, `market`, `network`, `output`, `runtime`
// and `scenario` are fully documented and enforced.
#![warn(missing_docs)]

pub mod broker;
#[allow(missing_docs)] // TODO(docs)
pub mod config;
pub mod des;
pub mod faults;
pub mod figures;
pub mod gridsim;
pub mod market;
pub mod network;
pub mod output;
pub mod runtime;
pub mod scenario;
pub mod session;
pub mod sweep;
#[allow(missing_docs)] // TODO(docs)
pub mod util;
pub mod workload;
