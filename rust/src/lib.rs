//! # GridSim — a Rust reproduction of the GridSim toolkit
//!
//! Reproduction of *GridSim: A Toolkit for the Modeling and Simulation of
//! Distributed Resource Management and Scheduling for Grid Computing*
//! (Buyya & Murshed, 2002) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map:
//! * [`des`] — deterministic discrete-event simulation kernel (the SimJava
//!   substrate, rebuilt as an event-handler model).
//! * [`gridsim`] — the grid entity toolkit: PEs, machines, time-/space-shared
//!   resources, Gridlets, the information service, network delays,
//!   statistics, calendars and reservations.
//! * [`broker`] — the Nimrod-G-like economic resource broker with
//!   deadline-and-budget-constrained (DBC) scheduling policies.
//! * [`runtime`] — PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   advisor kernels (`artifacts/*.hlo.txt`) and executes them from the
//!   broker's scheduling hot path.
//! * [`config`] / [`workload`] — scenario configuration (incl. the WWG
//!   testbed of Table 2) and synthetic task-farming application generator.
//! * [`figures`] — the harness that regenerates every table and figure of
//!   the paper's evaluation section.
//!
//! Quick start (compile-checked; `no_run` because rustdoc test binaries do
//! not inherit the xla_extension rpath):
//!
//! ```no_run
//! use gridsim::config::testbed::wwg_testbed;
//! use gridsim::broker::{ExperimentSpec, Optimization};
//! use gridsim::scenario::{Scenario, run_scenario};
//!
//! let scenario = Scenario::builder()
//!     .resources(wwg_testbed())
//!     .user(ExperimentSpec::task_farm(20, 10_000.0, 0.10)
//!         .deadline(3_100.0)
//!         .budget(22_000.0)
//!         .optimization(Optimization::Cost))
//!     .seed(7)
//!     .build();
//! let report = run_scenario(&scenario);
//! assert!(report.users[0].gridlets_completed > 0);
//! ```

pub mod broker;
pub mod config;
pub mod des;
pub mod figures;
pub mod gridsim;
pub mod output;
pub mod runtime;
pub mod scenario;
pub mod util;
pub mod workload;
