//! Economic market layer: utilization-driven dynamic pricing and the
//! preemptible spot tier.
//!
//! The paper's broker optimizes against *static* per-resource prices
//! (Table 2); Buyya's economy-grid thesis (cs/0204048) is the direct sequel,
//! modeling posted-price and commodity-market economies where prices respond
//! to demand. This module supplies the pricing side of that economy:
//!
//! * [`PricingModel`] — the pricing contract: a price in G$ per PE per time
//!   unit as a function of instantaneous utilization and simulation time,
//!   always inside a floor/cap envelope.
//! * [`PriceModel`] — the concrete models: [`PriceModel::Static`] (the
//!   default, byte-identical to the pre-market toolkit),
//!   [`PriceModel::UtilizationLinear`] and [`PriceModel::UtilizationStep`].
//! * [`MarketSpec`] — the scenario-level attachment: per-resource pricing
//!   models plus per-resource spot-tier discounts, mirroring
//!   [`crate::faults::FaultsSpec`]'s side-table design so resource and
//!   broker construction stay byte-identical when no market is configured.
//!
//! ## Charge-at-execution contract
//!
//! A dynamic price changes *while jobs run*, so the broker must not charge
//! the admission-time snapshot. Each `GridResource` with a market keeps a
//! lazy time-integral of its price; a returned Gridlet carries
//! `paid_rate` — the time-averaged price over its residency (spot-discounted
//! for bid-carrying jobs) — and the broker charges
//! `paid_rate × cpu_time`. When the price never changed during a residency
//! the resource reports the current price *exactly* (no division), so the
//! `Static` model reproduces today's `price × cpu_time` arithmetic bit for
//! bit.
//!
//! ## Determinism contract
//!
//! Pricing is a pure function of (utilization, time): no RNG streams are
//! consumed, so adding a market never perturbs workload materialization or
//! failure processes — sweeps over `spot_discounts` hold common random
//! numbers across cells. Spot preemption visits resident jobs in sorted
//! `(owner, id)` order, keeping event emission independent of hash-map
//! iteration order.

/// The pricing contract: G$ per PE per time unit as a function of the
/// resource's instantaneous utilization (fraction of PEs busy or committed,
/// in `[0, 1]`) and the simulation time, clamped to the model's floor/cap
/// envelope.
pub trait PricingModel {
    /// Price in effect at `utilization` (in `[0, 1]`) and simulation `time`.
    fn price_at(&self, utilization: f64, time: f64) -> f64;
}

/// A concrete pricing model for one resource.
#[derive(Debug, Clone, PartialEq)]
pub enum PriceModel {
    /// Constant price — the pre-market behavior. `price_at` returns `price`
    /// exactly at every utilization (no clamping arithmetic is applied, so
    /// the configured value survives bit for bit).
    Static {
        /// Price in G$ per PE per time unit (Table 2 "Price").
        price: f64,
    },
    /// Posted price rising linearly with utilization:
    /// `clamp(base + slope·u, floor, cap)`.
    UtilizationLinear {
        /// Price at zero utilization.
        base: f64,
        /// Price increase per unit utilization (≥ 0 keeps the model
        /// monotone non-decreasing).
        slope: f64,
        /// Lower bound of the price envelope.
        floor: f64,
        /// Upper bound of the price envelope (`f64::INFINITY` for none).
        cap: f64,
    },
    /// Piecewise-constant tariff: `base` below the first threshold, then
    /// the price of the highest `(threshold, price)` step whose threshold
    /// is ≤ utilization; clamped to `[floor, cap]`.
    UtilizationStep {
        /// Price below the first step threshold.
        base: f64,
        /// `(threshold, price)` steps with strictly ascending thresholds
        /// in `[0, 1]`.
        steps: Vec<(f64, f64)>,
        /// Lower bound of the price envelope.
        floor: f64,
        /// Upper bound of the price envelope (`f64::INFINITY` for none).
        cap: f64,
    },
}

impl PricingModel for PriceModel {
    fn price_at(&self, utilization: f64, _time: f64) -> f64 {
        match self {
            PriceModel::Static { price } => *price,
            PriceModel::UtilizationLinear { base, slope, floor, cap } => {
                (base + slope * utilization).clamp(*floor, *cap)
            }
            PriceModel::UtilizationStep { base, steps, floor, cap } => {
                let mut level = *base;
                for &(threshold, price) in steps {
                    if utilization >= threshold {
                        level = price;
                    } else {
                        break;
                    }
                }
                level.clamp(*floor, *cap)
            }
        }
    }
}

impl PriceModel {
    /// Check the model's parameters: prices finite and non-negative, slope
    /// non-negative, `floor ≤ cap` (the cap may be `+∞`), step thresholds
    /// strictly ascending in `[0, 1]`.
    pub fn validate(&self) -> Result<(), String> {
        fn finite_nonneg(label: &str, v: f64) -> Result<(), String> {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{label} must be finite and >= 0, got {v}"));
            }
            Ok(())
        }
        fn envelope(floor: f64, cap: f64) -> Result<(), String> {
            finite_nonneg("floor", floor)?;
            if cap.is_nan() || cap < floor {
                return Err(format!("cap ({cap}) must be >= floor ({floor})"));
            }
            Ok(())
        }
        match self {
            PriceModel::Static { price } => finite_nonneg("price", *price),
            PriceModel::UtilizationLinear { base, slope, floor, cap } => {
                finite_nonneg("base", *base)?;
                finite_nonneg("slope", *slope)?;
                envelope(*floor, *cap)
            }
            PriceModel::UtilizationStep { base, steps, floor, cap } => {
                finite_nonneg("base", *base)?;
                let mut prev = -1.0;
                for &(threshold, price) in steps {
                    if !(0.0..=1.0).contains(&threshold) {
                        return Err(format!(
                            "step threshold {threshold} outside [0, 1]"
                        ));
                    }
                    if threshold <= prev {
                        return Err(format!(
                            "step thresholds must be strictly ascending \
                             ({threshold} after {prev})"
                        ));
                    }
                    prev = threshold;
                    finite_nonneg("step price", price)?;
                }
                envelope(*floor, *cap)
            }
        }
    }
}

/// Scenario-level market attachment: which resources get a dynamic pricing
/// model and which rent out a preemptible spot tier.
///
/// Both sides are `(resource name, value)` lists — a `Vec` (not a map) so
/// the spec stays `PartialEq` with deterministic `Debug` (the sweep digest
/// hashes the `Debug` form). A resource named in `spot` but not in
/// `pricing` is priced `Static` at its configured price; a resource named
/// in neither carries **no** market state and emits no market events.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MarketSpec {
    /// Per-resource pricing models, fully resolved (the JSON loader folds
    /// its `"default"` model into one entry per resource at parse time).
    pub pricing: Vec<(String, PriceModel)>,
    /// Per-resource spot-tier discount in `(0, 1]`: bid-carrying jobs rent
    /// at `discount × current price` but are preempted when the price
    /// crosses their bid.
    pub spot: Vec<(String, f64)>,
}

impl MarketSpec {
    /// Empty spec (attach entries with [`MarketSpec::pricing_for`] /
    /// [`MarketSpec::spot_for`]).
    pub fn new() -> MarketSpec {
        MarketSpec::default()
    }

    /// Attach (or replace) the pricing model for one resource.
    pub fn pricing_for(mut self, name: impl Into<String>, model: PriceModel) -> MarketSpec {
        let name = name.into();
        self.pricing.retain(|(n, _)| *n != name);
        self.pricing.push((name, model));
        self
    }

    /// Attach (or replace) a spot-tier discount for one resource.
    pub fn spot_for(mut self, name: impl Into<String>, discount: f64) -> MarketSpec {
        let name = name.into();
        self.spot.retain(|(n, _)| *n != name);
        self.spot.push((name, discount));
        self
    }

    /// The market configuration of resource `name`, if any:
    /// `(pricing model, spot discount)`. `base_price` is the resource's
    /// configured static price, used when the resource is spot-only.
    pub fn config_for(&self, name: &str, base_price: f64) -> Option<(PriceModel, Option<f64>)> {
        let model = self.pricing.iter().find(|(n, _)| n == name).map(|(_, m)| m.clone());
        let discount = self.spot.iter().find(|(n, _)| n == name).map(|&(_, d)| d);
        match (model, discount) {
            (None, None) => None,
            (Some(m), d) => Some((m, d)),
            (None, Some(d)) => Some((PriceModel::Static { price: base_price }, Some(d))),
        }
    }

    /// Check the spec: at least one entry (an empty market drives nothing),
    /// every model valid, every discount finite in `(0, 1]`.
    pub fn validate(&self) -> Result<(), String> {
        if self.pricing.is_empty() && self.spot.is_empty() {
            return Err(
                "market spec drives nothing: no pricing models and no spot tiers".into()
            );
        }
        for (name, model) in &self.pricing {
            model.validate().map_err(|e| format!("pricing for {name:?}: {e}"))?;
        }
        for &(ref name, d) in &self.spot {
            if !d.is_finite() || d <= 0.0 || d > 1.0 {
                return Err(format!(
                    "spot discount for {name:?} must be in (0, 1], got {d}"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_is_flat() {
        let m = PriceModel::Static { price: 3.0 };
        for u in [0.0, 0.25, 0.5, 1.0] {
            assert_eq!(m.price_at(u, 100.0), 3.0);
        }
    }

    #[test]
    fn linear_slopes_and_clamps() {
        let m = PriceModel::UtilizationLinear { base: 1.0, slope: 4.0, floor: 2.0, cap: 4.0 };
        assert_eq!(m.price_at(0.0, 0.0), 2.0, "floor binds");
        assert_eq!(m.price_at(0.5, 0.0), 3.0, "interior");
        assert_eq!(m.price_at(1.0, 0.0), 4.0, "cap binds");
    }

    #[test]
    fn step_picks_highest_crossed_threshold() {
        let m = PriceModel::UtilizationStep {
            base: 1.0,
            steps: vec![(0.5, 2.0), (0.9, 5.0)],
            floor: 0.0,
            cap: f64::INFINITY,
        };
        assert_eq!(m.price_at(0.0, 0.0), 1.0);
        assert_eq!(m.price_at(0.49, 0.0), 1.0);
        assert_eq!(m.price_at(0.5, 0.0), 2.0);
        assert_eq!(m.price_at(0.95, 0.0), 5.0);
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(PriceModel::Static { price: -1.0 }.validate().is_err());
        assert!(PriceModel::Static { price: f64::NAN }.validate().is_err());
        assert!(PriceModel::UtilizationLinear { base: 1.0, slope: 1.0, floor: 2.0, cap: 1.0 }
            .validate()
            .is_err());
        assert!(PriceModel::UtilizationLinear {
            base: 1.0,
            slope: 1.0,
            floor: 0.0,
            cap: f64::INFINITY
        }
        .validate()
        .is_ok());
        assert!(PriceModel::UtilizationStep {
            base: 1.0,
            steps: vec![(0.5, 2.0), (0.4, 3.0)],
            floor: 0.0,
            cap: f64::INFINITY
        }
        .validate()
        .is_err(), "descending thresholds");
        assert!(PriceModel::UtilizationStep {
            base: 1.0,
            steps: vec![(1.5, 2.0)],
            floor: 0.0,
            cap: f64::INFINITY
        }
        .validate()
        .is_err(), "threshold outside [0,1]");
    }

    #[test]
    fn spec_resolves_spot_only_resources_to_static() {
        let spec = MarketSpec::new()
            .pricing_for("R0", PriceModel::Static { price: 4.0 })
            .spot_for("R1", 0.5);
        let (m, d) = spec.config_for("R0", 9.0).unwrap();
        assert_eq!(m, PriceModel::Static { price: 4.0 });
        assert_eq!(d, None);
        let (m, d) = spec.config_for("R1", 9.0).unwrap();
        assert_eq!(m, PriceModel::Static { price: 9.0 }, "spot-only uses configured price");
        assert_eq!(d, Some(0.5));
        assert!(spec.config_for("R2", 1.0).is_none(), "unnamed resources carry no market");
    }

    #[test]
    fn spec_validation() {
        assert!(MarketSpec::new().validate().is_err(), "empty spec drives nothing");
        assert!(MarketSpec::new().spot_for("R0", 0.0).validate().is_err());
        assert!(MarketSpec::new().spot_for("R0", 1.5).validate().is_err());
        assert!(MarketSpec::new().spot_for("R0", 1.0).validate().is_ok());
        assert!(MarketSpec::new()
            .pricing_for("R0", PriceModel::Static { price: -1.0 })
            .validate()
            .is_err());
    }

    #[test]
    fn builders_replace_existing_entries() {
        let spec = MarketSpec::new()
            .pricing_for("R0", PriceModel::Static { price: 1.0 })
            .pricing_for("R0", PriceModel::Static { price: 2.0 })
            .spot_for("R0", 0.5)
            .spot_for("R0", 0.7);
        assert_eq!(spec.pricing.len(), 1);
        assert_eq!(spec.spot, vec![("R0".to_string(), 0.7)]);
    }
}
