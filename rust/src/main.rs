//! `repro` — the GridSim reproduction launcher.
//!
//! Subcommands:
//!   table1                         print Table 1 (time- vs space-shared)
//!   table2                         print Table 2 (the WWG testbed)
//!   run --scenario FILE            run a JSON scenario and report
//!   run --testbed wwg [...]        run an inline single-user experiment
//!   figures [--set S] [--full]     regenerate paper figures into --out DIR
//!   selftest                       quick end-to-end smoke run
//!
//! Common flags: --advisor native|xla, --seed N, --out DIR.

use anyhow::{anyhow, bail, Result};
use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::scenario_file::parse_scenario;
use gridsim::config::testbed::wwg_testbed;
use gridsim::figures;
use gridsim::output::report;
use gridsim::scenario::{run_scenario, AdvisorKind, Scenario};
use gridsim::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn advisor_kind(args: &Args) -> Result<AdvisorKind> {
    match args.flag("advisor").unwrap_or("native") {
        "native" => Ok(AdvisorKind::Native),
        "xla" => Ok(AdvisorKind::Xla),
        other => bail!("unknown advisor {other:?} (native|xla)"),
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("table1") => {
            println!("{}", figures::table1().to_string());
            Ok(())
        }
        Some("table2") => {
            println!("{}", figures::table2().to_string());
            Ok(())
        }
        Some("run") => cmd_run(args),
        Some("figures") => cmd_figures(args),
        Some("selftest") => cmd_selftest(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "repro — GridSim reproduction (Buyya & Murshed 2002)\n\
         \n\
         usage: repro <command> [flags]\n\
         \n\
         commands:\n\
           table1                      Table 1: time- vs space-shared scheduling\n\
           table2                      Table 2: the simulated WWG testbed\n\
           run --scenario FILE         run a JSON scenario\n\
           run [--deadline D] [--budget B] [--gridlets N] [--policy P] [--users N]\n\
                                       inline run on the WWG testbed\n\
           figures [--set SET] [--full] [--out DIR]\n\
                                       regenerate figures (SET: tables|single|\n\
                                       resource-selection|traces|multi3100|multi10000|all)\n\
           selftest                    quick end-to-end smoke run\n\
         \n\
         common flags: --advisor native|xla   --seed N   --out DIR"
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let scenario = if let Some(path) = args.flag("scenario") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        let mut s = parse_scenario(&text)?;
        s.advisor = advisor_kind(args)?;
        if let Some(seed) = args.flag_usize("seed")? {
            s.seed = seed as u64;
        }
        s
    } else {
        let deadline = args.flag_f64("deadline")?.unwrap_or(3_100.0);
        let budget = args.flag_f64("budget")?.unwrap_or(22_000.0);
        let gridlets = args.flag_usize("gridlets")?.unwrap_or(200);
        let users = args.flag_usize("users")?.unwrap_or(1);
        let policy = Optimization::parse(args.flag("policy").unwrap_or("cost"))
            .ok_or_else(|| anyhow!("unknown policy"))?;
        Scenario::builder()
            .resources(wwg_testbed())
            .users(
                users,
                ExperimentSpec::task_farm(gridlets, 10_000.0, 0.10)
                    .deadline(deadline)
                    .budget(budget)
                    .optimization(policy),
            )
            .seed(args.flag_usize("seed")?.unwrap_or(27) as u64)
            .advisor(advisor_kind(args)?)
            .build()
    };
    let start = std::time::Instant::now();
    let result = run_scenario(&scenario);
    let wall = start.elapsed();
    println!(
        "simulated {} users / {} resources: {} events, sim time {:.1}, wall {:.3}s ({:.0} ev/s)",
        scenario.users.len(),
        scenario.resources.len(),
        result.events,
        result.end_time,
        wall.as_secs_f64(),
        result.events as f64 / wall.as_secs_f64().max(1e-9),
    );
    for (i, u) in result.users.iter().enumerate() {
        println!("{}", report::experiment_line(&format!("U{i}"), u));
    }
    if result.users.len() == 1 {
        println!("\n{}", report::resource_table(&result.users[0]));
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = Path::new(args.flag("out").unwrap_or("results")).to_path_buf();
    let mut cfg = if args.has_switch("full") {
        figures::SweepConfig::paper()
    } else {
        figures::SweepConfig::quick()
    };
    cfg.advisor = advisor_kind(args)?;
    if let Some(seed) = args.flag_usize("seed")? {
        cfg.seed = seed as u64;
    }
    let set = args.flag("set").unwrap_or("all").to_string();
    let mut wrote = vec![];
    let mut emit = |name: &str, csv: gridsim::output::csv::CsvWriter| -> Result<()> {
        let path = out.join(format!("{name}.csv"));
        csv.write_to(&path)?;
        wrote.push(path.display().to_string());
        Ok(())
    };
    if matches!(set.as_str(), "tables" | "all") {
        emit("table1", figures::table1())?;
        emit("table2", figures::table2())?;
    }
    if matches!(set.as_str(), "single" | "all") {
        emit("figs21_24_single_user_sweep", figures::figs21_24(&cfg))?;
    }
    if matches!(set.as_str(), "resource-selection" | "all") {
        emit("fig25_selection_deadline100", figures::figs25_27(100.0, &cfg))?;
        emit("fig26_selection_deadline1100", figures::figs25_27(1_100.0, &cfg))?;
        emit("fig27_selection_deadline3100", figures::figs25_27(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "traces" | "all") {
        emit("figs28_29_31_trace_d100_b22000", figures::figs28_32(100.0, 22_000.0, &cfg))?;
        emit("fig30_trace_d3100_b5000", figures::figs28_32(3_100.0, 5_000.0, &cfg))?;
        emit("fig32_trace_d1100_b22000", figures::figs28_32(1_100.0, 22_000.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi3100" | "all") {
        emit("figs33_35_multi_user_d3100", figures::figs33_38(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi10000" | "all") {
        emit("figs36_38_multi_user_d10000", figures::figs33_38(10_000.0, &cfg))?;
    }
    if wrote.is_empty() {
        bail!("unknown figure set {set:?}");
    }
    for w in wrote {
        println!("wrote {w}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(7)
        .advisor(advisor_kind(args)?)
        .build();
    let report = run_scenario(&scenario);
    let u = &report.users[0];
    println!(
        "selftest: {}/{} gridlets, {:.1} G$ spent, {} events",
        u.gridlets_completed, u.gridlets_total, u.budget_spent, report.events
    );
    if u.gridlets_completed != 50 {
        bail!("selftest failed: expected 50 completions");
    }
    println!("selftest OK");
    Ok(())
}
