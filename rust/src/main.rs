//! `repro` — the GridSim reproduction launcher.
//!
//! Subcommands:
//!   table1                         print Table 1 (time- vs space-shared)
//!   table2                         print Table 2 (the WWG testbed)
//!   run --scenario FILE            run a JSON scenario and report
//!   run --testbed wwg [...]        run an inline single-user experiment
//!   sweep --scenario FILE          run a declarative parameter sweep
//!   sweep --deadlines ... [...]    inline sweep on the WWG testbed
//!   figures [--set S] [--full]     regenerate paper figures into --out DIR
//!   selftest                       quick end-to-end smoke run
//!
//! Common flags: --advisor native|xla, --seed N, --out DIR, --jobs N.
//! `run` extras: --policies cost,time,... assigns policies per user
//! round-robin (heterogeneous competition); --watch T runs the simulation
//! through `GridSession` in T-sized increments, printing a per-broker
//! progress snapshot after each. `sweep` executes on a --jobs-sized worker
//! pool; per-cell deterministic seeding makes its CSV output byte-identical
//! at any --jobs value. Every sweep appends one fsync'd checkpoint line per
//! completed cell to OUT/sweep_cells.jsonl; `sweep ... --resume DIR` skips
//! the cells recorded there and reruns only the missing ones, with final
//! CSVs byte-identical to an uninterrupted run.

use anyhow::{anyhow, bail, Result};
use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::scenario_file::{parse_scenario_at, parse_sweep_at};
use gridsim::config::testbed::wwg_testbed;
use gridsim::figures;
use gridsim::output::report;
use gridsim::output::sweep::{aggregate_csv, long_csv};
use gridsim::scenario::{AdvisorKind, Scenario, ScenarioReport, UserSpec};
use gridsim::session::GridSession;
use gridsim::sweep::{default_jobs, run_sweep_checkpointed, SweepSpec};
use gridsim::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn advisor_kind(args: &Args) -> Result<AdvisorKind> {
    match args.flag("advisor").unwrap_or("native") {
        "native" => Ok(AdvisorKind::Native),
        "xla" => Ok(AdvisorKind::Xla),
        other => bail!("unknown advisor {other:?} (native|xla)"),
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("table1") => {
            println!("{}", figures::table1().to_string());
            Ok(())
        }
        Some("table2") => {
            println!("{}", figures::table2().to_string());
            Ok(())
        }
        Some("run") => cmd_run(args),
        Some("sweep") => cmd_sweep(args),
        Some("figures") => cmd_figures(args),
        Some("selftest") => cmd_selftest(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "repro — GridSim reproduction (Buyya & Murshed 2002)\n\
         \n\
         usage: repro <command> [flags]\n\
         \n\
         commands:\n\
           table1                      Table 1: time- vs space-shared scheduling\n\
           table2                      Table 2: the simulated WWG testbed\n\
           run --scenario FILE         run a JSON scenario\n\
           run [--deadline D] [--budget B] [--gridlets N] [--policy P] [--users N]\n\
               [--policies P1,P2,...]  inline run on the WWG testbed (policies\n\
                                       are assigned per user, round-robin)\n\
           run ... --watch T           step the run in T-sized time increments,\n\
                                       printing per-broker progress after each\n\
           sweep --scenario FILE       run the file's declarative \"sweep\" grid\n\
                                       (plain scenario files work too; axis flags\n\
                                       below override the file's axes)\n\
           sweep [--deadlines D1,D2,...] [--budgets B1,...] [--users N1,...]\n\
                 [--policies P1,...] [--resources R1+R2,R3,...]\n\
                 [--mean-interarrivals M1,...] [--heavy-fractions F1,...]\n\
                 [--link-capacities C1,...] [--mtbf-scalings S1,...]\n\
                 [--spot-discounts D1,...] [--replications R] [--gridlets N]\n\
                                       inline sweep on the WWG testbed; writes\n\
                                       sweep_long.csv + sweep_agg.csv to --out\n\
                                       (workload-shape axes need a scenario file\n\
                                       whose users declare matching workloads;\n\
                                       the structured trace_selectors/mix_weights\n\
                                       axes are file-only — see README)\n\
           sweep ... --resume DIR      resume a killed sweep from the per-cell\n\
                                       checkpoint DIR/sweep_cells.jsonl (same\n\
                                       scenario/axes; completed cells are\n\
                                       skipped, CSVs land in DIR and are\n\
                                       byte-identical to an uninterrupted run)\n\
           figures [--set SET] [--full] [--out DIR]\n\
                                       regenerate figures (SET: tables|single|\n\
                                       resource-selection|traces|multi3100|multi10000|\n\
                                       day-night|network|robustness|market|\n\
                                       workflow|all)\n\
           selftest                    quick end-to-end smoke run\n\
         \n\
         common flags: --advisor native|xla   --seed N   --out DIR   --jobs N\n\
         (sweep/figures run on a --jobs worker pool, default = CPU count;\n\
         output is byte-identical at any --jobs value)"
    );
}

/// The shared inline-run defaults (gridlets 200, deadline 3100, budget
/// 22000, the paper's §5 workload shape) — one source for both `repro run`
/// and the `repro sweep` inline base, so the two cannot drift.
fn inline_experiment(args: &Args, policy: Optimization) -> Result<ExperimentSpec> {
    Ok(
        ExperimentSpec::task_farm(args.flag_usize("gridlets")?.unwrap_or(200), 10_000.0, 0.10)
            .deadline(args.flag_f64("deadline")?.unwrap_or(3_100.0))
            .budget(args.flag_f64("budget")?.unwrap_or(22_000.0))
            .optimization(policy),
    )
}

fn inline_seed(args: &Args) -> Result<u64> {
    Ok(args.flag_usize("seed")?.unwrap_or(27) as u64)
}

/// The single `--policy` flag (default cost).
fn policy_flag(args: &Args) -> Result<Optimization> {
    args.flag("policy").unwrap_or("cost").parse::<Optimization>().map_err(|e| anyhow!(e))
}

fn build_inline_scenario(args: &Args) -> Result<Scenario> {
    let users = args.flag_usize("users")?.unwrap_or(1);
    // --policies cost,time,... assigns per-user policies round-robin, the
    // simplest heterogeneous competition setup.
    let default_policy = policy_flag(args)?;
    let policies: Vec<Optimization> =
        policies_flag(args)?.unwrap_or_else(|| vec![default_policy]);
    let mut builder = Scenario::builder()
        .resources(wwg_testbed())
        .seed(inline_seed(args)?)
        .advisor(advisor_kind(args)?);
    for i in 0..users {
        builder = builder.user(UserSpec::new(inline_experiment(
            args,
            policies[i % policies.len()],
        )?));
    }
    Ok(builder.build())
}

/// Drive a session in `interval`-sized increments, printing a per-broker
/// progress line after each (the CLI consuming the same observer API as
/// figures and tests).
fn run_watched(session: &mut GridSession, interval: f64) -> ScenarioReport {
    session.init();
    let mut horizon = interval;
    while !session.is_idle() {
        let before = session.events_processed();
        session.run_until(horizon);
        horizon += interval;
        // Fast-forward across gaps in a sparse queue (e.g. a large
        // submit_delay): one iteration instead of millions of empty ones.
        if let Some(next) = session.next_event_time() {
            if next > horizon {
                horizon = next;
            }
        }
        if session.events_processed() == before {
            continue; // nothing due this interval — no spam
        }
        let snap = session.snapshot();
        let line = snap
            .users
            .iter()
            .map(|u| format!("{}:{}/{}", u.state, u.gridlets_completed, u.gridlets_total))
            .collect::<Vec<_>>()
            .join("  ");
        eprintln!("[t={:>10.1}  {:>9} ev] {line}", snap.time, snap.events);
    }
    session.report().into_scenario_report()
}

fn cmd_run(args: &Args) -> Result<()> {
    let scenario = if let Some(path) = args.flag("scenario") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        // Relative trace-workload paths resolve against the scenario file's
        // directory, not the invocation directory.
        let mut s = parse_scenario_at(&text, Path::new(path).parent())?;
        // CLI flags override the file only when explicitly given.
        if args.flag("advisor").is_some() {
            s.advisor = advisor_kind(args)?;
        }
        if let Some(seed) = args.flag_usize("seed")? {
            s.seed = seed as u64;
        }
        s
    } else {
        build_inline_scenario(args)?
    };
    let start = std::time::Instant::now();
    let mut session = GridSession::try_new(&scenario)?;
    let result = match args.flag_f64("watch")? {
        Some(interval) if interval > 0.0 => run_watched(&mut session, interval),
        Some(interval) => bail!("--watch expects a positive interval, got {interval}"),
        None => session.run_to_completion(),
    };
    let wall = start.elapsed();
    println!(
        "simulated {} users / {} resources: {} events, sim time {:.1}, wall {:.3}s ({:.0} ev/s)",
        scenario.users.len(),
        scenario.resources.len(),
        result.events,
        result.end_time,
        wall.as_secs_f64(),
        result.events as f64 / wall.as_secs_f64().max(1e-9),
    );
    for (i, u) in result.users.iter().enumerate() {
        let marker = if result.unfinished.contains(&i) { "  [DID NOT FINISH]" } else { "" };
        println!("{}{marker}", report::experiment_line(&format!("U{i}"), u));
    }
    if result.users.len() == 1 {
        println!("\n{}", report::resource_table(&result.users[0]));
    }
    if !result.all_finished() {
        bail!(
            "{} of {} experiments did not finish before the kernel limit",
            result.unfinished.len(),
            result.users.len()
        );
    }
    Ok(())
}

/// Comma-separated `--policies` list, with the accepted values in the error.
fn policies_flag(args: &Args) -> Result<Option<Vec<Optimization>>> {
    args.flag_list("policies", "policies (cost|time|cost-time|none|heft)")
}

/// Worker-pool size: `--jobs N`, defaulting to the CPU count.
fn jobs_flag(args: &Args) -> Result<usize> {
    match args.flag_usize("jobs")? {
        Some(0) => bail!("--jobs expects a positive worker count"),
        Some(n) => Ok(n),
        None => Ok(default_jobs()),
    }
}

/// Build the sweep spec for `repro sweep`: a scenario file (its `"sweep"`
/// section is optional — a plain file is a zero-axis sweep), or inline axes
/// over the WWG testbed. Axis flags given on the command line override the
/// file's axes (same rule as --seed and --advisor: CLI wins only when
/// explicitly given).
fn build_sweep_spec(args: &Args) -> Result<SweepSpec> {
    let mut spec = if let Some(path) = args.flag("scenario") {
        // These flags configure the inline base's single user; silently
        // dropping them against a file (which defines its own users) would
        // betray the loader's no-ignored-input discipline.
        for flag in ["gridlets", "deadline", "budget", "policy"] {
            if args.flag(flag).is_some() {
                bail!(
                    "--{flag} only applies to the inline base; with --scenario, \
                     set it in the file's \"users\" section instead"
                );
            }
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        let mut spec = parse_sweep_at(&text, Path::new(path).parent())?;
        if args.flag("advisor").is_some() {
            spec.base.advisor = advisor_kind(args)?;
        }
        if let Some(seed) = args.flag_usize("seed")? {
            spec.base.seed = seed as u64;
        }
        spec
    } else {
        // Inline base: one user on the WWG testbed, sharing `repro run`'s
        // inline defaults. Unlike `run`, the sweep's --users/--policies
        // flags are *axes* (lists), so the base is always single-user;
        // cells override per-axis.
        let base = Scenario::builder()
            .resources(wwg_testbed())
            .user(inline_experiment(args, policy_flag(args)?)?)
            .seed(inline_seed(args)?)
            .advisor(advisor_kind(args)?)
            .build();
        SweepSpec::over(base)
    };
    if let Some(ds) = args.flag_f64_list("deadlines")? {
        spec = spec.deadlines(ds);
    }
    if let Some(bs) = args.flag_f64_list("budgets")? {
        spec = spec.budgets(bs);
    }
    if let Some(us) = args.flag_usize_list("users")? {
        spec = spec.user_counts(us);
    }
    if let Some(policies) = policies_flag(args)? {
        spec = spec.policies(policies);
    }
    // Subsets separate resources with `+` inside one subset, `,` between
    // subsets: `--resources R8,R8+R4,R0+R1+R2`.
    if let Some(list) = args.flag("resources") {
        let subsets: Vec<Vec<String>> = list
            .split(',')
            .map(|subset| subset.split('+').map(|n| n.trim().to_string()).collect())
            .collect();
        spec = spec.resource_subsets(subsets);
    }
    if let Some(ms) = args.flag_f64_list("mean-interarrivals")? {
        spec = spec.mean_interarrivals(ms);
    }
    if let Some(fs) = args.flag_f64_list("heavy-fractions")? {
        spec = spec.heavy_fractions(fs);
    }
    // Like the workload-shape axes, this needs a base whose network is
    // already {"model": "flow"} — spec.validate() reports it otherwise.
    if let Some(cs) = args.flag_f64_list("link-capacities")? {
        spec = spec.link_capacities(cs);
    }
    // Likewise: scaling MTBF needs a base with a "faults" block to scale —
    // spec.validate() reports it otherwise.
    if let Some(ss) = args.flag_f64_list("mtbf-scalings")? {
        spec = spec.mtbf_scalings(ss);
    }
    // Likewise: discounting a spot tier needs a base whose market declares
    // one — spec.validate() reports it otherwise.
    if let Some(ds) = args.flag_f64_list("spot-discounts")? {
        spec = spec.spot_discounts(ds);
    }
    if let Some(r) = args.flag_usize("replications")? {
        spec = spec.replications(r);
    }
    Ok(spec)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let spec = build_sweep_spec(args)?;
    let jobs = jobs_flag(args)?;
    // --resume DIR resumes *and* writes in place: completed cells are read
    // from DIR/sweep_cells.jsonl and the CSVs land next to it.
    let resume = args.flag("resume");
    let out = match (args.flag("out"), resume) {
        // Path-wise comparison, so equivalent spellings ("results" vs
        // "results/") of the same directory are accepted.
        (Some(o), Some(r)) if Path::new(o) != Path::new(r) => bail!(
            "--out {o:?} and --resume {r:?} point at different directories; \
             --resume resumes and writes in place (drop --out)"
        ),
        (_, Some(r)) => Path::new(r).to_path_buf(),
        (o, None) => Path::new(o.unwrap_or("results")).to_path_buf(),
    };
    eprintln!(
        "sweep: {} cells ({} users base, {} resources) on {} worker(s)",
        spec.cell_count(),
        spec.base.users.len(),
        spec.base.resources.len(),
        jobs.min(spec.cell_count().max(1)),
    );
    let results = run_sweep_checkpointed(&spec, jobs, &out, resume.is_some())?;
    let long = long_csv(&spec, &results);
    let agg = aggregate_csv(&spec, &results);
    let long_path = out.join("sweep_long.csv");
    let agg_path = out.join("sweep_agg.csv");
    long.write_to(&long_path)?;
    agg.write_to(&agg_path)?;
    if results.cells_reused > 0 {
        println!(
            "resumed {} completed cell(s) from {}",
            results.cells_reused,
            out.join("sweep_cells.jsonl").display()
        );
    }
    // The rate covers only what this run dispatched: reused cells carry
    // their events into the total but cost this run no wall time.
    let executed_events = results.total_events() - results.events_reused;
    println!(
        "swept {} cells in {:.3}s on {} worker(s): {} events total ({:.0} ev/s)",
        results.outcomes.len() - results.cells_reused,
        results.wall_secs,
        results.jobs,
        results.total_events(),
        executed_events as f64 / results.wall_secs.max(1e-9),
    );
    let unfinished = results.cells_with_unfinished();
    if unfinished > 0 {
        println!(
            "note: {unfinished} cell(s) had users that did not finish \
             (marked finished=0 in the long CSV)"
        );
    }
    println!("wrote {}", long_path.display());
    println!("wrote {}", agg_path.display());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = Path::new(args.flag("out").unwrap_or("results")).to_path_buf();
    let mut cfg = if args.has_switch("full") {
        figures::FigureConfig::paper()
    } else {
        figures::FigureConfig::quick()
    };
    cfg.advisor = advisor_kind(args)?;
    cfg = cfg.jobs(jobs_flag(args)?);
    if let Some(seed) = args.flag_usize("seed")? {
        cfg.seed = seed as u64;
    }
    let set = args.flag("set").unwrap_or("all").to_string();
    let mut wrote = vec![];
    let mut emit = |name: &str, csv: gridsim::output::csv::CsvWriter| -> Result<()> {
        let path = out.join(format!("{name}.csv"));
        csv.write_to(&path)?;
        wrote.push(path.display().to_string());
        Ok(())
    };
    if matches!(set.as_str(), "tables" | "all") {
        emit("table1", figures::table1())?;
        emit("table2", figures::table2())?;
    }
    if matches!(set.as_str(), "single" | "all") {
        emit("figs21_24_single_user_sweep", figures::figs21_24(&cfg))?;
    }
    if matches!(set.as_str(), "resource-selection" | "all") {
        emit("fig25_selection_deadline100", figures::figs25_27(100.0, &cfg))?;
        emit("fig26_selection_deadline1100", figures::figs25_27(1_100.0, &cfg))?;
        emit("fig27_selection_deadline3100", figures::figs25_27(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "traces" | "all") {
        emit("figs28_29_31_trace_d100_b22000", figures::figs28_32(100.0, 22_000.0, &cfg))?;
        emit("fig30_trace_d3100_b5000", figures::figs28_32(3_100.0, 5_000.0, &cfg))?;
        emit("fig32_trace_d1100_b22000", figures::figs28_32(1_100.0, 22_000.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi3100" | "all") {
        emit("figs33_35_multi_user_d3100", figures::figs33_38(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi10000" | "all") {
        emit("figs36_38_multi_user_d10000", figures::figs33_38(10_000.0, &cfg))?;
    }
    if matches!(set.as_str(), "day-night" | "all") {
        emit("fig_day_night_modulated_arrivals", figures::fig_day_night(&cfg))?;
    }
    if matches!(set.as_str(), "network" | "all") {
        emit("fig_network_load_flow_contention", figures::fig_network_load(&cfg))?;
    }
    if matches!(set.as_str(), "robustness" | "all") {
        emit("fig_robustness_mtbf_sweep", figures::fig_robustness(&cfg))?;
    }
    if matches!(set.as_str(), "market" | "all") {
        emit("fig_market_equilibrium", figures::fig_market(&cfg))?;
    }
    if matches!(set.as_str(), "workflow" | "all") {
        emit("fig_workflow_policies", figures::fig_workflow(&cfg))?;
    }
    if wrote.is_empty() {
        bail!("unknown figure set {set:?}");
    }
    for w in wrote {
        println!("wrote {w}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(7)
        .advisor(advisor_kind(args)?)
        .build();
    let report = GridSession::try_new(&scenario)?.run_to_completion();
    let u = &report.users[0];
    println!(
        "selftest: {}/{} gridlets, {:.1} G$ spent, {} events",
        u.gridlets_completed, u.gridlets_total, u.budget_spent, report.events
    );
    if u.gridlets_completed != 50 {
        bail!("selftest failed: expected 50 completions");
    }
    println!("selftest OK");
    Ok(())
}
