//! `repro` — the GridSim reproduction launcher.
//!
//! Subcommands:
//!   table1                         print Table 1 (time- vs space-shared)
//!   table2                         print Table 2 (the WWG testbed)
//!   run --scenario FILE            run a JSON scenario and report
//!   run --testbed wwg [...]        run an inline single-user experiment
//!   figures [--set S] [--full]     regenerate paper figures into --out DIR
//!   selftest                       quick end-to-end smoke run
//!
//! Common flags: --advisor native|xla, --seed N, --out DIR.
//! `run` extras: --policies cost,time,... assigns policies per user
//! round-robin (heterogeneous competition); --watch T runs the simulation
//! through `GridSession` in T-sized increments, printing a per-broker
//! progress snapshot after each.

use anyhow::{anyhow, bail, Result};
use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::scenario_file::parse_scenario;
use gridsim::config::testbed::wwg_testbed;
use gridsim::figures;
use gridsim::output::report;
use gridsim::scenario::{AdvisorKind, Scenario, ScenarioReport, UserSpec};
use gridsim::session::GridSession;
use gridsim::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn advisor_kind(args: &Args) -> Result<AdvisorKind> {
    match args.flag("advisor").unwrap_or("native") {
        "native" => Ok(AdvisorKind::Native),
        "xla" => Ok(AdvisorKind::Xla),
        other => bail!("unknown advisor {other:?} (native|xla)"),
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("table1") => {
            println!("{}", figures::table1().to_string());
            Ok(())
        }
        Some("table2") => {
            println!("{}", figures::table2().to_string());
            Ok(())
        }
        Some("run") => cmd_run(args),
        Some("figures") => cmd_figures(args),
        Some("selftest") => cmd_selftest(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "repro — GridSim reproduction (Buyya & Murshed 2002)\n\
         \n\
         usage: repro <command> [flags]\n\
         \n\
         commands:\n\
           table1                      Table 1: time- vs space-shared scheduling\n\
           table2                      Table 2: the simulated WWG testbed\n\
           run --scenario FILE         run a JSON scenario\n\
           run [--deadline D] [--budget B] [--gridlets N] [--policy P] [--users N]\n\
               [--policies P1,P2,...]  inline run on the WWG testbed (policies\n\
                                       are assigned per user, round-robin)\n\
           run ... --watch T           step the run in T-sized time increments,\n\
                                       printing per-broker progress after each\n\
           figures [--set SET] [--full] [--out DIR]\n\
                                       regenerate figures (SET: tables|single|\n\
                                       resource-selection|traces|multi3100|multi10000|all)\n\
           selftest                    quick end-to-end smoke run\n\
         \n\
         common flags: --advisor native|xla   --seed N   --out DIR"
    );
}

fn build_inline_scenario(args: &Args) -> Result<Scenario> {
    let deadline = args.flag_f64("deadline")?.unwrap_or(3_100.0);
    let budget = args.flag_f64("budget")?.unwrap_or(22_000.0);
    let gridlets = args.flag_usize("gridlets")?.unwrap_or(200);
    let users = args.flag_usize("users")?.unwrap_or(1);
    let default_policy = Optimization::parse(args.flag("policy").unwrap_or("cost"))
        .ok_or_else(|| anyhow!("unknown policy"))?;
    // --policies cost,time,... assigns per-user policies round-robin, the
    // simplest heterogeneous competition setup.
    let policies: Vec<Optimization> = match args.flag("policies") {
        None => vec![default_policy],
        Some(list) => list
            .split(',')
            .map(|p| {
                Optimization::parse(p.trim())
                    .ok_or_else(|| anyhow!("unknown policy {p:?} in --policies"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let mut builder = Scenario::builder()
        .resources(wwg_testbed())
        .seed(args.flag_usize("seed")?.unwrap_or(27) as u64)
        .advisor(advisor_kind(args)?);
    for i in 0..users {
        builder = builder.user(UserSpec::new(
            ExperimentSpec::task_farm(gridlets, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(policies[i % policies.len()]),
        ));
    }
    Ok(builder.build())
}

/// Drive a session in `interval`-sized increments, printing a per-broker
/// progress line after each (the CLI consuming the same observer API as
/// figures and tests).
fn run_watched(session: &mut GridSession, interval: f64) -> ScenarioReport {
    session.init();
    let mut horizon = interval;
    while !session.is_idle() {
        let before = session.events_processed();
        session.run_until(horizon);
        horizon += interval;
        // Fast-forward across gaps in a sparse queue (e.g. a large
        // submit_delay): one iteration instead of millions of empty ones.
        if let Some(next) = session.next_event_time() {
            if next > horizon {
                horizon = next;
            }
        }
        if session.events_processed() == before {
            continue; // nothing due this interval — no spam
        }
        let snap = session.snapshot();
        let line = snap
            .users
            .iter()
            .map(|u| format!("{}:{}/{}", u.state, u.gridlets_completed, u.gridlets_total))
            .collect::<Vec<_>>()
            .join("  ");
        eprintln!("[t={:>10.1}  {:>9} ev] {line}", snap.time, snap.events);
    }
    session.report().into_scenario_report()
}

fn cmd_run(args: &Args) -> Result<()> {
    let scenario = if let Some(path) = args.flag("scenario") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        let mut s = parse_scenario(&text)?;
        // CLI flags override the file only when explicitly given.
        if args.flag("advisor").is_some() {
            s.advisor = advisor_kind(args)?;
        }
        if let Some(seed) = args.flag_usize("seed")? {
            s.seed = seed as u64;
        }
        s
    } else {
        build_inline_scenario(args)?
    };
    let start = std::time::Instant::now();
    let mut session = GridSession::try_new(&scenario)?;
    let result = match args.flag_f64("watch")? {
        Some(interval) if interval > 0.0 => run_watched(&mut session, interval),
        Some(interval) => bail!("--watch expects a positive interval, got {interval}"),
        None => session.run_to_completion(),
    };
    let wall = start.elapsed();
    println!(
        "simulated {} users / {} resources: {} events, sim time {:.1}, wall {:.3}s ({:.0} ev/s)",
        scenario.users.len(),
        scenario.resources.len(),
        result.events,
        result.end_time,
        wall.as_secs_f64(),
        result.events as f64 / wall.as_secs_f64().max(1e-9),
    );
    for (i, u) in result.users.iter().enumerate() {
        let marker = if result.unfinished.contains(&i) { "  [DID NOT FINISH]" } else { "" };
        println!("{}{marker}", report::experiment_line(&format!("U{i}"), u));
    }
    if result.users.len() == 1 {
        println!("\n{}", report::resource_table(&result.users[0]));
    }
    if !result.all_finished() {
        bail!(
            "{} of {} experiments did not finish before the kernel limit",
            result.unfinished.len(),
            result.users.len()
        );
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = Path::new(args.flag("out").unwrap_or("results")).to_path_buf();
    let mut cfg = if args.has_switch("full") {
        figures::SweepConfig::paper()
    } else {
        figures::SweepConfig::quick()
    };
    cfg.advisor = advisor_kind(args)?;
    if let Some(seed) = args.flag_usize("seed")? {
        cfg.seed = seed as u64;
    }
    let set = args.flag("set").unwrap_or("all").to_string();
    let mut wrote = vec![];
    let mut emit = |name: &str, csv: gridsim::output::csv::CsvWriter| -> Result<()> {
        let path = out.join(format!("{name}.csv"));
        csv.write_to(&path)?;
        wrote.push(path.display().to_string());
        Ok(())
    };
    if matches!(set.as_str(), "tables" | "all") {
        emit("table1", figures::table1())?;
        emit("table2", figures::table2())?;
    }
    if matches!(set.as_str(), "single" | "all") {
        emit("figs21_24_single_user_sweep", figures::figs21_24(&cfg))?;
    }
    if matches!(set.as_str(), "resource-selection" | "all") {
        emit("fig25_selection_deadline100", figures::figs25_27(100.0, &cfg))?;
        emit("fig26_selection_deadline1100", figures::figs25_27(1_100.0, &cfg))?;
        emit("fig27_selection_deadline3100", figures::figs25_27(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "traces" | "all") {
        emit("figs28_29_31_trace_d100_b22000", figures::figs28_32(100.0, 22_000.0, &cfg))?;
        emit("fig30_trace_d3100_b5000", figures::figs28_32(3_100.0, 5_000.0, &cfg))?;
        emit("fig32_trace_d1100_b22000", figures::figs28_32(1_100.0, 22_000.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi3100" | "all") {
        emit("figs33_35_multi_user_d3100", figures::figs33_38(3_100.0, &cfg))?;
    }
    if matches!(set.as_str(), "multi10000" | "all") {
        emit("figs36_38_multi_user_d10000", figures::figs33_38(10_000.0, &cfg))?;
    }
    if wrote.is_empty() {
        bail!("unknown figure set {set:?}");
    }
    for w in wrote {
        println!("wrote {w}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(50, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(22_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(7)
        .advisor(advisor_kind(args)?)
        .build();
    let report = GridSession::try_new(&scenario)?.run_to_completion();
    let u = &report.users[0];
    println!(
        "selftest: {}/{} gridlets, {:.1} G$ spent, {} events",
        u.gridlets_completed, u.gridlets_total, u.budget_spent, report.events
    );
    if u.gridlets_completed != 50 {
        bail!("selftest failed: expected 50 completions");
    }
    println!("selftest OK");
    Ok(())
}
