//! The sweep execution engine: a fixed-size worker pool over independent
//! cells, with optional per-cell checkpointing for resumable sweeps.
//!
//! Workers pull the next unclaimed cell index from an atomic counter, build
//! the cell's [`crate::session::GridSession`] locally, run it to completion
//! and write the outcome into the cell's own slot. Collection is by cell
//! index, so the result vector — and any CSV derived from it — is identical
//! for any worker count and any completion order. There is no inter-cell
//! communication: the only shared state is the claim counter, the per-cell
//! result slots, and (when checkpointing) the append-only checkpoint file.
//!
//! Two engine-level reuse mechanisms keep long campaigns cheap without
//! touching simulation semantics:
//!
//! * **Per-worker advisor cache** — each worker thread holds one
//!   [`crate::session::AdvisorCache`], so consecutive cells on that worker
//!   share one advisor engine per [`crate::scenario::AdvisorKind`] instead
//!   of rebuilding it per cell (for an `advisor: xla` sweep that is one
//!   PJRT compilation per worker instead of per cell). Advisors are pure
//!   per-tick functions, so reuse is bit-transparent.
//! * **Checkpoint/resume** — [`run_sweep_checkpointed`] appends one fsync'd
//!   JSON line per completed cell to `sweep_cells.jsonl` (format:
//!   [`crate::output::sweep`]); resuming skips completed cells and executes
//!   only the missing ones. Because cached reports round-trip bit-exactly
//!   and collection stays cell-index-ordered, a resumed sweep's CSVs are
//!   byte-identical to an uninterrupted run at any worker count.

use super::{SweepCell, SweepSpec};
use crate::output::sweep::{
    cell_digest, checkpoint_line, parse_checkpoint, sweep_digest, CHECKPOINT_FILE,
};
use crate::scenario::ScenarioReport;
use crate::session::{AdvisorCache, GridSession};
use anyhow::{anyhow, Context as _, Result};
use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One executed cell: the grid point plus its simulation report.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    /// The grid point this outcome belongs to.
    pub cell: SweepCell,
    /// The cell's full simulation report.
    pub report: ScenarioReport,
}

/// All outcomes of one sweep, in cell-index order.
#[derive(Debug, Clone)]
pub struct SweepResults {
    /// One outcome per cell, ordered by [`SweepCell::index`].
    pub outcomes: Vec<CellOutcome>,
    /// Worker threads actually used.
    pub jobs: usize,
    /// Wall-clock seconds for the whole sweep. Diagnostic only — never part
    /// of the CSV output (which must be byte-identical across runs).
    pub wall_secs: f64,
    /// Cells whose reports were taken from a resume checkpoint instead of
    /// being executed (0 for non-checkpointed or fresh runs).
    pub cells_reused: usize,
    /// Events belonging to the reused cells — already counted by
    /// [`total_events`](Self::total_events) but not dispatched by this run,
    /// so throughput rates should divide `total_events() - events_reused`
    /// by [`wall_secs`](Self::wall_secs).
    pub events_reused: u64,
}

impl SweepResults {
    /// Total events dispatched across all cells (scale metric).
    pub fn total_events(&self) -> u64 {
        self.outcomes.iter().map(|o| o.report.events).sum()
    }

    /// Cells in which at least one user did not finish.
    pub fn cells_with_unfinished(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.report.all_finished()).count()
    }
}

/// Default worker count: one per available core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Execute every cell of `spec` on `jobs` worker threads (clamped to
/// `1..=cell_count`). Results come back in cell-index order regardless of
/// scheduling; with deterministic per-cell seeds the outcome is therefore
/// bit-identical for any `jobs` value.
pub fn run_sweep(spec: &SweepSpec, jobs: usize) -> Result<SweepResults> {
    spec.validate()?;
    let cells = spec.cells();
    execute(spec, jobs, cells, None)
}

/// [`run_sweep`] with per-cell checkpointing into `dir/sweep_cells.jsonl`.
///
/// Every completed cell appends one fsync'd JSON line (format:
/// [`crate::output::sweep`]) before it counts as done, so a killed sweep
/// loses at most its in-flight cells. With `resume = false` any existing
/// checkpoint is overwritten and every cell runs; with `resume = true` the
/// existing checkpoint (if any) is validated against `spec` — a digest
/// mismatch is a hard error — completed cells are reused verbatim, and only
/// the missing ones execute (appending to the same file, so a resumed run
/// can itself be killed and resumed).
///
/// The final [`SweepResults`] — and therefore the CSVs written from it —
/// are byte-identical to an uninterrupted [`run_sweep`] at any `jobs`
/// value: cached reports round-trip bit-exactly and collection stays
/// cell-index-ordered.
pub fn run_sweep_checkpointed(
    spec: &SweepSpec,
    jobs: usize,
    dir: &Path,
    resume: bool,
) -> Result<SweepResults> {
    spec.validate()?;
    let cells = spec.cells();
    let path = dir.join(CHECKPOINT_FILE);
    let digest = sweep_digest(spec);
    let completed = if resume && path.exists() {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("cannot read {}: {e}", path.display()))?;
        let completed = parse_checkpoint(&text, digest, &cells)
            .with_context(|| format!("cannot resume from {}", path.display()))?;
        // Repair before appending: a kill mid-append can leave a torn final
        // fragment (or a complete line missing its newline). Appending
        // straight after it would merge the fragment with the first new
        // record into one unparseable line, poisoning the *next* resume.
        // parse_checkpoint already guaranteed every non-final line is a
        // valid record, so the damage — if any — is confined to the tail:
        let line_count = text.lines().count();
        let rebuilt = if text.is_empty() || (completed.len() == line_count && text.ends_with('\n'))
        {
            None // intact (or empty) — the common case costs no rewrite
        } else if completed.len() == line_count {
            // The final record is valid but lost its trailing newline
            // (killed between the two write_all calls): restore it.
            Some(format!("{text}\n"))
        } else if completed.len() + 1 == line_count {
            // Torn final fragment: drop it, keep everything else verbatim.
            let keep: Vec<&str> = text.lines().take(line_count - 1).collect();
            Some(if keep.is_empty() { String::new() } else { keep.join("\n") + "\n" })
        } else {
            // Duplicate cells (hand-concatenated checkpoints): re-serialize
            // the surviving records — bit-exact lines — in cell order.
            let mut indices: Vec<usize> = completed.keys().copied().collect();
            indices.sort_unstable();
            let mut out = String::new();
            for i in indices {
                let line =
                    checkpoint_line(cell_digest(digest, i, cells[i].seed), i, &completed[&i]);
                out.push_str(&line);
                out.push('\n');
            }
            Some(out)
        };
        if let Some(rebuilt) = rebuilt {
            let tmp = dir.join(format!("{CHECKPOINT_FILE}.tmp"));
            // Same durability discipline as the per-line appends: the tmp
            // file is fsync'd before the rename and the directory entry
            // after it, so even a power loss mid-repair cannot lose
            // surviving records.
            {
                let mut f = std::fs::File::create(&tmp)
                    .map_err(|e| anyhow!("cannot write {}: {e}", tmp.display()))?;
                f.write_all(rebuilt.as_bytes())
                    .and_then(|()| f.sync_all())
                    .map_err(|e| anyhow!("cannot write {}: {e}", tmp.display()))?;
            }
            std::fs::rename(&tmp, &path)
                .map_err(|e| anyhow!("cannot replace {}: {e}", path.display()))?;
            std::fs::File::open(dir)
                .and_then(|d| d.sync_all())
                .map_err(|e| anyhow!("cannot sync {}: {e}", dir.display()))?;
        }
        completed
    } else {
        HashMap::new()
    };
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow!("cannot create {}: {e}", dir.display()))?;
    // Resume appends to the repaired file; a fresh run truncates any stale
    // checkpoint (same overwrite semantics as the CSVs next to it).
    let file = std::fs::OpenOptions::new()
        .create(true)
        .append(resume)
        .write(true)
        .truncate(!resume)
        .open(&path)
        .map_err(|e| anyhow!("cannot open {}: {e}", path.display()))?;
    let checkpoint = Checkpoint { file: Mutex::new(file), digest, completed };
    execute(spec, jobs, cells, Some(checkpoint))
}

/// Shared state of a checkpointed run: the append-only file and the cells
/// already completed by a previous run.
struct Checkpoint {
    file: Mutex<std::fs::File>,
    digest: u64,
    completed: HashMap<usize, ScenarioReport>,
}

impl Checkpoint {
    /// Append one completed cell's line and fsync it — only after this
    /// returns does the cell count as done.
    fn record(&self, cell: &SweepCell, report: &ScenarioReport) -> Result<()> {
        let digest = cell_digest(self.digest, cell.index, cell.seed);
        let line = checkpoint_line(digest, cell.index, report);
        let mut file = self.file.lock().expect("checkpoint file lock");
        file.write_all(line.as_bytes())
            .and_then(|()| file.write_all(b"\n"))
            .map_err(|e| anyhow!("checkpoint write: {e}"))?;
        // The fsync is the commit point: a cell only counts as done once
        // its line is durable, so a kill can never "lose" a skipped cell.
        file.sync_data().map_err(|e| anyhow!("checkpoint fsync: {e}"))?;
        Ok(())
    }
}

fn execute(
    spec: &SweepSpec,
    jobs: usize,
    cells: Vec<SweepCell>,
    checkpoint: Option<Checkpoint>,
) -> Result<SweepResults> {
    // Only the cells missing from the checkpoint execute; `pending[k]` maps
    // a claim number to its cell index.
    let empty = HashMap::new();
    let reused: &HashMap<usize, ScenarioReport> = match &checkpoint {
        Some(c) => &c.completed,
        None => &empty,
    };
    let pending: Vec<usize> =
        (0..cells.len()).filter(|i| !reused.contains_key(i)).collect();
    let jobs = jobs.clamp(1, pending.len().max(1));
    let next = AtomicUsize::new(0);
    // One failed cell fails the whole sweep, so workers stop claiming new
    // cells as soon as any cell errors (in-flight cells finish) instead of
    // burning CPU on results that would be discarded.
    let abort = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<CellOutcome>>>> =
        pending.iter().map(|_| Mutex::new(None)).collect();

    let start = std::time::Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                // Worker-local advisor reuse: consecutive cells on this
                // worker share one engine per advisor kind (bit-transparent
                // — see `AdvisorCache`).
                let mut advisors = AdvisorCache::new();
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= pending.len() {
                        break;
                    }
                    let cell = &cells[pending[k]];
                    let outcome = run_cell(spec, cell, &mut advisors).and_then(|outcome| {
                        if let Some(c) = &checkpoint {
                            c.record(cell, &outcome.report)?;
                        }
                        Ok(outcome)
                    });
                    if outcome.is_err() {
                        abort.store(true, Ordering::Relaxed);
                    }
                    *slots[k].lock().expect("cell slot lock") = Some(outcome);
                }
            });
        }
    });
    let wall_secs = start.elapsed().as_secs_f64();

    let mut collected: Vec<Option<Result<CellOutcome>>> = Vec::with_capacity(slots.len());
    for slot in slots {
        collected.push(slot.into_inner().expect("cell slot lock"));
    }
    // Surface the real cell error, not a hole left by the abort.
    if let Some((k, result)) = collected
        .iter_mut()
        .enumerate()
        .find(|(_, r)| matches!(r, Some(Err(_))))
    {
        let err = result.take().expect("matched Some").expect_err("matched Err");
        return Err(err.context(format!("sweep cell {}", pending[k])));
    }
    let mut executed: HashMap<usize, CellOutcome> = HashMap::with_capacity(collected.len());
    for (k, slot) in collected.into_iter().enumerate() {
        match slot {
            Some(Ok(outcome)) => {
                executed.insert(pending[k], outcome);
            }
            Some(Err(_)) => unreachable!("error cells returned above"),
            None => panic!("sweep cell {} was never executed", pending[k]),
        }
    }
    // Assemble in cell-index order: executed cells from their slots, reused
    // cells straight from the checkpoint (bit-exact round trip).
    let cells_reused = reused.len();
    let events_reused: u64 = reused.values().map(|r| r.events).sum();
    let outcomes = cells
        .into_iter()
        .map(|cell| match executed.remove(&cell.index) {
            Some(outcome) => outcome,
            None => CellOutcome {
                report: reused
                    .get(&cell.index)
                    .cloned()
                    .unwrap_or_else(|| panic!("cell {} neither run nor resumed", cell.index)),
                cell,
            },
        })
        .collect();
    Ok(SweepResults { outcomes, jobs, wall_secs, cells_reused, events_reused })
}

fn run_cell(
    spec: &SweepSpec,
    cell: &SweepCell,
    advisors: &mut AdvisorCache,
) -> Result<CellOutcome> {
    let scenario = spec.scenario_for(cell);
    let report = GridSession::try_new_cached(&scenario, advisors)?.run_to_completion();
    Ok(CellOutcome { cell: cell.clone(), report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{ExperimentSpec, Optimization};
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, Scenario};

    fn base() -> Scenario {
        Scenario::builder()
            .resource(ResourceSpec {
                name: "R0".into(),
                arch: "test".into(),
                os: "linux".into(),
                machines: 1,
                pes_per_machine: 2,
                mips_per_pe: 100.0,
                policy: AllocPolicy::TimeShared,
                price: 1.0,
                time_zone: 0.0,
                calendar: None,
            })
            .user(
                ExperimentSpec::task_farm(6, 500.0, 0.10)
                    .deadline(5_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            .seed(5)
            .build()
    }

    #[test]
    fn serial_and_parallel_agree_cell_for_cell() {
        let spec = SweepSpec::over(base())
            .deadlines(vec![50.0, 5_000.0])
            .budgets(vec![10.0, 1e6])
            .replications(2);
        let serial = run_sweep(&spec, 1).unwrap();
        let parallel = run_sweep(&spec, 4).unwrap();
        assert_eq!(serial.outcomes.len(), 8);
        assert_eq!(parallel.outcomes.len(), 8);
        for (a, b) in serial.outcomes.iter().zip(&parallel.outcomes) {
            assert_eq!(a.cell.index, b.cell.index);
            assert_eq!(a.cell.seed, b.cell.seed);
            assert_eq!(a.report.events, b.report.events);
            assert_eq!(a.report.end_time.to_bits(), b.report.end_time.to_bits());
            for (u, v) in a.report.users.iter().zip(&b.report.users) {
                assert_eq!(u.gridlets_completed, v.gridlets_completed);
                assert_eq!(u.budget_spent.to_bits(), v.budget_spent.to_bits());
            }
        }
    }

    #[test]
    fn oversized_jobs_clamp_to_cell_count() {
        let spec = SweepSpec::over(base());
        let results = run_sweep(&spec, 64).unwrap();
        assert_eq!(results.jobs, 1, "1 cell → 1 worker");
        assert_eq!(results.outcomes.len(), 1);
        assert!(results.outcomes[0].report.all_finished());
    }

    #[test]
    fn invalid_spec_errors_before_running() {
        let spec = SweepSpec::over(base()).resource_subsets(vec![vec!["nope".into()]]);
        let err = run_sweep(&spec, 2).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn replications_produce_distinct_but_reproducible_workloads() {
        let spec = SweepSpec::over(base()).replications(3);
        let a = run_sweep(&spec, 2).unwrap();
        let b = run_sweep(&spec, 3).unwrap();
        // Replications differ from each other (different seeds)...
        assert_eq!(a.outcomes.len(), 3);
        let t0 = a.outcomes[0].report.end_time.to_bits();
        let t1 = a.outcomes[1].report.end_time.to_bits();
        assert_ne!(a.outcomes[0].cell.seed, a.outcomes[1].cell.seed);
        // (end times may coincide by chance, so only assert seed difference
        // and cross-run stability)
        let _ = (t0, t1);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.report.events, y.report.events);
            assert_eq!(x.report.end_time.to_bits(), y.report.end_time.to_bits());
        }
    }
}
