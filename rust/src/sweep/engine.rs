//! The sweep execution engine: a fixed-size worker pool over independent
//! cells.
//!
//! Workers pull the next unclaimed cell index from an atomic counter, build
//! the cell's [`crate::session::GridSession`] locally, run it to completion
//! and write the outcome into the cell's own slot. Collection is by cell
//! index, so the result vector — and any CSV derived from it — is identical
//! for any worker count and any completion order. There is no inter-cell
//! communication: the only shared state is the claim counter and the
//! per-cell result slots.

use super::{SweepCell, SweepSpec};
use crate::scenario::ScenarioReport;
use crate::session::GridSession;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One executed cell: the grid point plus its simulation report.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    /// The grid point this outcome belongs to.
    pub cell: SweepCell,
    /// The cell's full simulation report.
    pub report: ScenarioReport,
}

/// All outcomes of one sweep, in cell-index order.
#[derive(Debug, Clone)]
pub struct SweepResults {
    /// One outcome per cell, ordered by [`SweepCell::index`].
    pub outcomes: Vec<CellOutcome>,
    /// Worker threads actually used.
    pub jobs: usize,
    /// Wall-clock seconds for the whole sweep. Diagnostic only — never part
    /// of the CSV output (which must be byte-identical across runs).
    pub wall_secs: f64,
}

impl SweepResults {
    /// Total events dispatched across all cells (scale metric).
    pub fn total_events(&self) -> u64 {
        self.outcomes.iter().map(|o| o.report.events).sum()
    }

    /// Cells in which at least one user did not finish.
    pub fn cells_with_unfinished(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.report.all_finished()).count()
    }
}

/// Default worker count: one per available core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Execute every cell of `spec` on `jobs` worker threads (clamped to
/// `1..=cell_count`). Results come back in cell-index order regardless of
/// scheduling; with deterministic per-cell seeds the outcome is therefore
/// bit-identical for any `jobs` value.
pub fn run_sweep(spec: &SweepSpec, jobs: usize) -> Result<SweepResults> {
    spec.validate()?;
    let cells = spec.cells();
    let jobs = jobs.clamp(1, cells.len().max(1));
    let next = AtomicUsize::new(0);
    // One failed cell fails the whole sweep, so workers stop claiming new
    // cells as soon as any cell errors (in-flight cells finish) instead of
    // burning CPU on results that would be discarded.
    let abort = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<CellOutcome>>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();

    let start = std::time::Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let outcome = run_cell(spec, &cells[i]);
                if outcome.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().expect("cell slot lock") = Some(outcome);
            });
        }
    });
    let wall_secs = start.elapsed().as_secs_f64();

    let mut collected: Vec<Option<Result<CellOutcome>>> = Vec::with_capacity(cells.len());
    for slot in slots {
        collected.push(slot.into_inner().expect("cell slot lock"));
    }
    // Surface the real cell error, not a hole left by the abort.
    if let Some((i, result)) = collected
        .iter_mut()
        .enumerate()
        .find(|(_, r)| matches!(r, Some(Err(_))))
    {
        let err = result.take().expect("matched Some").expect_err("matched Err");
        return Err(err.context(format!("sweep cell {i}")));
    }
    let mut outcomes = Vec::with_capacity(cells.len());
    for (i, slot) in collected.into_iter().enumerate() {
        match slot {
            Some(Ok(outcome)) => outcomes.push(outcome),
            Some(Err(_)) => unreachable!("error cells returned above"),
            None => panic!("sweep cell {i} was never executed"),
        }
    }
    Ok(SweepResults { outcomes, jobs, wall_secs })
}

fn run_cell(spec: &SweepSpec, cell: &SweepCell) -> Result<CellOutcome> {
    let scenario = spec.scenario_for(cell);
    let report = GridSession::try_new(&scenario)?.run_to_completion();
    Ok(CellOutcome { cell: cell.clone(), report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{ExperimentSpec, Optimization};
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, Scenario};

    fn base() -> Scenario {
        Scenario::builder()
            .resource(ResourceSpec {
                name: "R0".into(),
                arch: "test".into(),
                os: "linux".into(),
                machines: 1,
                pes_per_machine: 2,
                mips_per_pe: 100.0,
                policy: AllocPolicy::TimeShared,
                price: 1.0,
                time_zone: 0.0,
                calendar: None,
            })
            .user(
                ExperimentSpec::task_farm(6, 500.0, 0.10)
                    .deadline(5_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            .seed(5)
            .build()
    }

    #[test]
    fn serial_and_parallel_agree_cell_for_cell() {
        let spec = SweepSpec::over(base())
            .deadlines(vec![50.0, 5_000.0])
            .budgets(vec![10.0, 1e6])
            .replications(2);
        let serial = run_sweep(&spec, 1).unwrap();
        let parallel = run_sweep(&spec, 4).unwrap();
        assert_eq!(serial.outcomes.len(), 8);
        assert_eq!(parallel.outcomes.len(), 8);
        for (a, b) in serial.outcomes.iter().zip(&parallel.outcomes) {
            assert_eq!(a.cell.index, b.cell.index);
            assert_eq!(a.cell.seed, b.cell.seed);
            assert_eq!(a.report.events, b.report.events);
            assert_eq!(a.report.end_time.to_bits(), b.report.end_time.to_bits());
            for (u, v) in a.report.users.iter().zip(&b.report.users) {
                assert_eq!(u.gridlets_completed, v.gridlets_completed);
                assert_eq!(u.budget_spent.to_bits(), v.budget_spent.to_bits());
            }
        }
    }

    #[test]
    fn oversized_jobs_clamp_to_cell_count() {
        let spec = SweepSpec::over(base());
        let results = run_sweep(&spec, 64).unwrap();
        assert_eq!(results.jobs, 1, "1 cell → 1 worker");
        assert_eq!(results.outcomes.len(), 1);
        assert!(results.outcomes[0].report.all_finished());
    }

    #[test]
    fn invalid_spec_errors_before_running() {
        let spec = SweepSpec::over(base()).resource_subsets(vec![vec!["nope".into()]]);
        let err = run_sweep(&spec, 2).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn replications_produce_distinct_but_reproducible_workloads() {
        let spec = SweepSpec::over(base()).replications(3);
        let a = run_sweep(&spec, 2).unwrap();
        let b = run_sweep(&spec, 3).unwrap();
        // Replications differ from each other (different seeds)...
        assert_eq!(a.outcomes.len(), 3);
        let t0 = a.outcomes[0].report.end_time.to_bits();
        let t1 = a.outcomes[1].report.end_time.to_bits();
        assert_ne!(a.outcomes[0].cell.seed, a.outcomes[1].cell.seed);
        // (end times may coincide by chance, so only assert seed difference
        // and cross-run stability)
        let _ = (t0, t1);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.report.events, y.report.events);
            assert_eq!(x.report.end_time.to_bits(), y.report.end_time.to_bits());
        }
    }
}
