//! Parameter sweeps as data — the paper's "different scenarios such as
//! varying number of resources and users" (§5, Figures 21–38) expressed as a
//! declarative grid instead of hand-rolled nested loops.
//!
//! A [`SweepSpec`] names a base [`Scenario`] plus cartesian axes (deadline,
//! budget, user count, scheduling policy, resource subset, workload shape —
//! arrival mean, heavy-tail fraction, trace selector, mix weights — fault
//! severity via MTBF scaling, spot-tier discount, and replications).
//! [`SweepSpec::cells`] expands the grid into independent [`SweepCell`]s in
//! a fixed row-major order, and [`engine::run_sweep`] executes them on a
//! fixed-size `std::thread` worker pool. Three properties make sweeps
//! reproducible:
//!
//! 1. **Pure cell expansion** — a cell is a value; materializing its
//!    [`Scenario`] ([`SweepSpec::scenario_for`]) touches no global state.
//! 2. **Deterministic seeding** — a cell's RNG seed depends only on the base
//!    seed and the replication index ([`replication_seed`]); cells that vary
//!    only in parameter axes share the base seed (common random numbers, the
//!    standard variance-reduction discipline for simulation experiments).
//! 3. **Index-ordered collection** — workers write results into the cell's
//!    own slot, so output order never depends on thread count or completion
//!    order. The same spec produces byte-identical CSV at any `--jobs`
//!    (proven by `rust/tests/sweep_determinism.rs`).
//!
//! Long campaigns additionally get **checkpoint/resume**
//! ([`engine::run_sweep_checkpointed`], `repro sweep --resume`): each
//! completed cell is appended to `sweep_cells.jsonl` keyed by a digest of
//! the spec + cell, so a killed 10k-cell sweep restarts from the completed
//! cells instead of from zero — with final CSVs byte-identical to an
//! uninterrupted run. Scenario materialization is cheap even for
//! trace-driven bases: a cell's clone shares the `Arc`-held job list of
//! every [`crate::workload::WorkloadSpec::Trace`] rather than copying the
//! log per cell.

pub mod engine;

pub use engine::{
    default_jobs, run_sweep, run_sweep_checkpointed, CellOutcome, SweepResults,
};

use crate::broker::Optimization;
use crate::scenario::{NetworkSpec, Scenario, UserSpec};
use crate::workload::TraceSelector;
use anyhow::{bail, Result};

/// A declarative parameter sweep over a base scenario.
///
/// Every axis left empty keeps the base scenario's value; a non-empty axis
/// overrides it for each listed value. The grid is the cartesian product of
/// all non-empty axes times `replications`.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// The scenario every cell starts from (cloned, then overridden).
    pub base: Scenario,
    /// Absolute deadline override, applied to every user in the cell.
    pub deadlines: Vec<f64>,
    /// Absolute budget override, applied to every user in the cell.
    pub budgets: Vec<f64>,
    /// User-count override: the cell gets `n` users cloned round-robin from
    /// the base scenario's user list (for a single-user base this is the
    /// paper's §5.4 "n identical competing users").
    pub user_counts: Vec<usize>,
    /// Scheduling-policy override, applied to every user in the cell.
    pub policies: Vec<Optimization>,
    /// Resource subsets by name; each entry restricts the cell to the named
    /// subset of the base resources (base order preserved).
    pub resource_subsets: Vec<Vec<String>>,
    /// Mean inter-arrival override (Poisson mean / fixed interval), applied
    /// to every user with an online-arrivals workload. Requires at least one
    /// such user in the base.
    pub mean_interarrivals: Vec<f64>,
    /// Heavy-tail fraction override, applied to every user with a
    /// heavy-tailed workload (possibly inside online arrivals). Requires at
    /// least one such user in the base.
    pub heavy_fractions: Vec<f64>,
    /// Trace-selector override, applied to every trace workload in the cell
    /// (e.g. replaying one SWF log as different per-user slices across
    /// cells). Requires at least one trace user in the base, and every
    /// selector must keep at least one job of every trace it retargets.
    pub trace_selectors: Vec<TraceSelector>,
    /// Mix-interleave weight override: each entry is one weight vector,
    /// applied to every [`crate::workload::WorkloadSpec::Mix`] whose part
    /// count matches the vector's length. Requires at least one matching
    /// mix in the base.
    pub mix_weights: Vec<Vec<f64>>,
    /// Default link-capacity override (bits per time unit), applied to the
    /// cell's [`NetworkSpec::Flow`] network (named per-entity capacity
    /// overrides are preserved). Requires a flow network in the base.
    pub link_capacities: Vec<f64>,
    /// MTBF-scaling override (fault severity), applied to the cell's
    /// [`crate::faults::FaultsSpec`]: every stochastic uptime mean (and
    /// every trace failure onset) is multiplied by the factor, repair times
    /// untouched. Values below 1 make failures more frequent. Requires a
    /// `faults` spec in the base scenario.
    pub mtbf_scalings: Vec<f64>,
    /// Spot-discount override, applied to every spot tier in the cell's
    /// [`crate::market::MarketSpec`]: each listed factor in (0, 1] replaces
    /// the discount of *every* `spot` entry (per-resource discounts collapse
    /// to one swept value). Requires a market spec with at least one spot
    /// entry in the base scenario.
    pub spot_discounts: Vec<f64>,
    /// Independent replications per grid point (≥ 1). Replication `r` runs
    /// with [`replication_seed`]`(base.seed, r)`.
    pub replications: usize,
}

impl SweepSpec {
    /// A sweep with no axes: exactly one cell, the base scenario itself.
    pub fn over(base: Scenario) -> SweepSpec {
        SweepSpec {
            base,
            deadlines: Vec::new(),
            budgets: Vec::new(),
            user_counts: Vec::new(),
            policies: Vec::new(),
            resource_subsets: Vec::new(),
            mean_interarrivals: Vec::new(),
            heavy_fractions: Vec::new(),
            trace_selectors: Vec::new(),
            mix_weights: Vec::new(),
            link_capacities: Vec::new(),
            mtbf_scalings: Vec::new(),
            spot_discounts: Vec::new(),
            replications: 1,
        }
    }

    /// Axis builder: deadline values.
    pub fn deadlines(mut self, values: Vec<f64>) -> SweepSpec {
        self.deadlines = values;
        self
    }

    /// Axis builder: budget values.
    pub fn budgets(mut self, values: Vec<f64>) -> SweepSpec {
        self.budgets = values;
        self
    }

    /// Axis builder: user counts.
    pub fn user_counts(mut self, values: Vec<usize>) -> SweepSpec {
        self.user_counts = values;
        self
    }

    /// Axis builder: scheduling policies.
    pub fn policies(mut self, values: Vec<Optimization>) -> SweepSpec {
        self.policies = values;
        self
    }

    /// Axis builder: resource subsets (by resource name).
    pub fn resource_subsets(mut self, subsets: Vec<Vec<String>>) -> SweepSpec {
        self.resource_subsets = subsets;
        self
    }

    /// Axis builder: mean inter-arrival values (online-arrivals workloads).
    pub fn mean_interarrivals(mut self, values: Vec<f64>) -> SweepSpec {
        self.mean_interarrivals = values;
        self
    }

    /// Axis builder: heavy-tail fractions (heavy-tailed workloads).
    pub fn heavy_fractions(mut self, values: Vec<f64>) -> SweepSpec {
        self.heavy_fractions = values;
        self
    }

    /// Axis builder: trace selectors (trace workloads).
    pub fn trace_selectors(mut self, selectors: Vec<TraceSelector>) -> SweepSpec {
        self.trace_selectors = selectors;
        self
    }

    /// Axis builder: mix interleave weight vectors (mix workloads).
    pub fn mix_weights(mut self, weight_sets: Vec<Vec<f64>>) -> SweepSpec {
        self.mix_weights = weight_sets;
        self
    }

    /// Axis builder: default link capacities (flow networks).
    pub fn link_capacities(mut self, values: Vec<f64>) -> SweepSpec {
        self.link_capacities = values;
        self
    }

    /// Axis builder: MTBF scaling factors (faulted scenarios).
    pub fn mtbf_scalings(mut self, values: Vec<f64>) -> SweepSpec {
        self.mtbf_scalings = values;
        self
    }

    /// Axis builder: spot-tier discount factors (market scenarios).
    pub fn spot_discounts(mut self, values: Vec<f64>) -> SweepSpec {
        self.spot_discounts = values;
        self
    }

    /// Axis builder: replications per grid point.
    pub fn replications(mut self, n: usize) -> SweepSpec {
        self.replications = n;
        self
    }

    /// Number of cells the spec expands to.
    pub fn cell_count(&self) -> usize {
        fn axis_len<T>(v: &[T]) -> usize {
            v.len().max(1)
        }
        axis_len(&self.resource_subsets)
            * axis_len(&self.policies)
            * axis_len(&self.user_counts)
            * axis_len(&self.deadlines)
            * axis_len(&self.budgets)
            * axis_len(&self.mean_interarrivals)
            * axis_len(&self.heavy_fractions)
            * axis_len(&self.trace_selectors)
            * axis_len(&self.mix_weights)
            * axis_len(&self.link_capacities)
            * axis_len(&self.mtbf_scalings)
            * axis_len(&self.spot_discounts)
            * self.replications.max(1)
    }

    /// Reject impossible specs with a did-I-mean-that error instead of a
    /// mid-sweep panic: unknown resource names, empty subsets, zero user
    /// counts, zero replications.
    pub fn validate(&self) -> Result<()> {
        // The scenario builder already asserts these, but `Scenario` fields
        // are public — a hand-built base must not panic mid-sweep instead
        // (`scenario_for` indexes `base.users` cyclically).
        if self.base.users.is_empty() {
            bail!("sweep: base scenario has no users");
        }
        if self.base.resources.is_empty() {
            bail!("sweep: base scenario has no resources");
        }
        if self.replications == 0 {
            bail!("sweep: \"replications\" must be >= 1");
        }
        if let Some(n) = self.user_counts.iter().find(|&&n| n == 0) {
            bail!("sweep: user count must be >= 1, got {n}");
        }
        for (i, subset) in self.resource_subsets.iter().enumerate() {
            if subset.is_empty() {
                bail!("sweep: resource subset #{i} is empty");
            }
            for name in subset {
                if !self.base.resources.iter().any(|r| &r.name == name) {
                    let known: Vec<&str> =
                        self.base.resources.iter().map(|r| r.name.as_str()).collect();
                    bail!(
                        "sweep: resource subset #{i} names unknown resource {name:?} \
                         (scenario has: {})",
                        known.join(", ")
                    );
                }
            }
        }
        if !self.mean_interarrivals.is_empty() {
            if let Some(m) = self.mean_interarrivals.iter().find(|&&m| m <= 0.0 || m.is_nan()) {
                bail!("sweep: mean inter-arrival must be > 0, got {m}");
            }
            if !self
                .base
                .users
                .iter()
                .any(|u| u.experiment.workload.has_arrival_process())
            {
                bail!(
                    "sweep: \"mean_interarrivals\" needs at least one user with an \
                     online_arrivals workload in the base scenario"
                );
            }
        }
        if !self.heavy_fractions.is_empty() {
            if let Some(f) = self.heavy_fractions.iter().find(|&&f| !(0.0..=1.0).contains(&f)) {
                bail!("sweep: heavy-tail fraction must be in [0, 1], got {f}");
            }
            if !self.base.users.iter().any(|u| u.experiment.workload.has_heavy_tail()) {
                bail!(
                    "sweep: \"heavy_fractions\" needs at least one user with a \
                     heavy_tailed workload in the base scenario"
                );
            }
        }
        if !self.trace_selectors.is_empty() {
            if !self.base.users.iter().any(|u| u.experiment.workload.has_trace()) {
                bail!(
                    "sweep: \"trace_selectors\" needs at least one user with a trace \
                     workload in the base scenario"
                );
            }
            // Every cell must still declare >= 1 job per retargeted trace:
            // check each selector against the base users' (borrowed) traces.
            for (i, selector) in self.trace_selectors.iter().enumerate() {
                for (u, user) in self.base.users.iter().enumerate() {
                    user.experiment.workload.check_trace_selector(selector).map_err(|e| {
                        e.context(format!(
                            "sweep: trace selector #{i} ({:?}) against user #{u}",
                            selector.label()
                        ))
                    })?;
                }
            }
        }
        if !self.mix_weights.is_empty() {
            for (i, weights) in self.mix_weights.iter().enumerate() {
                if weights.is_empty() {
                    bail!("sweep: mix_weights entry #{i} is empty");
                }
                if let Some(w) = weights.iter().find(|w| !w.is_finite() || **w <= 0.0) {
                    bail!("sweep: mix weights must be finite and > 0, got {w}");
                }
                if !self
                    .base
                    .users
                    .iter()
                    .any(|u| u.experiment.workload.has_mix_of(weights.len()))
                {
                    bail!(
                        "sweep: mix_weights entry #{i} has {} weights, but no user in \
                         the base scenario has a mix workload with {} parts",
                        weights.len(),
                        weights.len()
                    );
                }
            }
        }
        if !self.link_capacities.is_empty() {
            if let Some(c) = self.link_capacities.iter().find(|&&c| !c.is_finite() || c <= 0.0) {
                bail!("sweep: link capacity must be finite and > 0, got {c}");
            }
            if !matches!(self.base.network, NetworkSpec::Flow { .. }) {
                bail!(
                    "sweep: \"link_capacities\" needs \"network\": {{\"model\": \"flow\"}} \
                     in the base scenario (only flow networks have link capacities)"
                );
            }
        }
        if !self.mtbf_scalings.is_empty() {
            if let Some(s) = self.mtbf_scalings.iter().find(|&&s| !s.is_finite() || s <= 0.0) {
                bail!("sweep: mtbf scaling must be finite and > 0, got {s}");
            }
            if self.base.faults.is_none() {
                bail!(
                    "sweep: \"mtbf_scalings\" needs a \"faults\" block in the base \
                     scenario (there is nothing to scale otherwise)"
                );
            }
        }
        if !self.spot_discounts.is_empty() {
            if let Some(d) =
                self.spot_discounts.iter().find(|&&d| !d.is_finite() || d <= 0.0 || d > 1.0)
            {
                bail!("sweep: spot discount must be in (0, 1], got {d}");
            }
            if !self.base.market.as_ref().is_some_and(|m| !m.spot.is_empty()) {
                bail!(
                    "sweep: \"spot_discounts\" needs a \"spot\" block in the base \
                     scenario (there is no spot tier to discount otherwise)"
                );
            }
        }
        Ok(())
    }

    /// Expand the grid into cells, row-major over the axes in the fixed
    /// order *subset → policy → users → deadline → budget → arrival mean →
    /// heavy fraction → trace selector → mix weights → link capacity →
    /// MTBF scaling → spot discount → replication* (replication varies
    /// fastest). The order is part of the
    /// output contract: cell index == CSV row block, independent of
    /// execution.
    pub fn cells(&self) -> Vec<SweepCell> {
        fn axis<T: Copy>(values: &[T]) -> Vec<Option<T>> {
            if values.is_empty() {
                vec![None]
            } else {
                values.iter().copied().map(Some).collect()
            }
        }
        /// Index-valued axis for non-`Copy` axis payloads (subsets,
        /// selectors, weight vectors live on the spec; cells carry indices).
        fn index_axis<T>(values: &[T]) -> Vec<Option<usize>> {
            if values.is_empty() {
                vec![None]
            } else {
                (0..values.len()).map(Some).collect()
            }
        }
        let mut cells = Vec::with_capacity(self.cell_count());
        for &subset in &index_axis(&self.resource_subsets) {
            for &policy in &axis(&self.policies) {
                for &users in &axis(&self.user_counts) {
                    for &deadline in &axis(&self.deadlines) {
                        for &budget in &axis(&self.budgets) {
                            for &mean_interarrival in &axis(&self.mean_interarrivals) {
                                for &heavy_fraction in &axis(&self.heavy_fractions) {
                                    for &trace_selector in &index_axis(&self.trace_selectors) {
                                        for &mix_weights in &index_axis(&self.mix_weights) {
                                            for &link_capacity in &axis(&self.link_capacities) {
                                                for &mtbf_scaling in &axis(&self.mtbf_scalings) {
                                                    for &spot_discount in
                                                        &axis(&self.spot_discounts)
                                                    {
                                                        for replication in
                                                            0..self.replications.max(1)
                                                        {
                                                            cells.push(SweepCell {
                                                                index: cells.len(),
                                                                subset,
                                                                policy,
                                                                users,
                                                                deadline,
                                                                budget,
                                                                mean_interarrival,
                                                                heavy_fraction,
                                                                trace_selector,
                                                                mix_weights,
                                                                link_capacity,
                                                                mtbf_scaling,
                                                                spot_discount,
                                                                replication,
                                                                seed: replication_seed(
                                                                    self.base.seed,
                                                                    replication,
                                                                ),
                                                            });
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    /// Materialize the scenario for one cell: clone the base, then apply the
    /// cell's overrides. Pure — no global state, so cells can materialize on
    /// any worker thread in any order.
    ///
    /// Panics on a cell that names an out-of-range subset; run
    /// [`validate`](Self::validate) first (the engine does).
    pub fn scenario_for(&self, cell: &SweepCell) -> Scenario {
        let mut scenario = self.base.clone();
        scenario.seed = cell.seed;
        if let Some(i) = cell.subset {
            let subset = &self.resource_subsets[i];
            scenario.resources = self
                .base
                .resources
                .iter()
                .filter(|r| subset.iter().any(|n| n == &r.name))
                .cloned()
                .collect();
        }
        if let Some(n) = cell.users {
            scenario.users = (0..n)
                .map(|i| self.base.users[i % self.base.users.len()].clone())
                .collect();
        }
        if let Some(c) = cell.link_capacity {
            match &mut scenario.network {
                NetworkSpec::Flow { default_capacity, .. } => *default_capacity = c,
                _ => unreachable!("validate() requires a flow network for link_capacities"),
            }
        }
        if let Some(s) = cell.mtbf_scaling {
            match &mut scenario.faults {
                Some(faults) => faults.mtbf_scaling = s,
                None => unreachable!("validate() requires a faults block for mtbf_scalings"),
            }
        }
        if let Some(d) = cell.spot_discount {
            match &mut scenario.market {
                Some(market) => {
                    for (_, discount) in &mut market.spot {
                        *discount = d;
                    }
                }
                None => unreachable!("validate() requires a spot tier for spot_discounts"),
            }
        }
        for user in &mut scenario.users {
            self.apply_user_overrides(user, cell);
        }
        scenario
    }

    /// Label for a cell's resource-subset axis (`"all"` when unswept).
    pub fn subset_label(&self, cell: &SweepCell) -> String {
        match cell.subset {
            None => "all".to_string(),
            Some(i) => self.resource_subsets[i].join("+"),
        }
    }

    /// Label for a cell's trace-selector axis (`"base"` when unswept).
    pub fn selector_label(&self, cell: &SweepCell) -> String {
        match cell.trace_selector {
            None => "base".to_string(),
            Some(i) => self.trace_selectors[i].label(),
        }
    }

    /// Label for a cell's mix-weights axis (`"base"` when unswept,
    /// `+`-joined weights otherwise).
    pub fn mix_weights_label(&self, cell: &SweepCell) -> String {
        match cell.mix_weights {
            None => "base".to_string(),
            Some(i) => self.mix_weights[i]
                .iter()
                .map(|w| crate::output::csv::trim_float(*w))
                .collect::<Vec<_>>()
                .join("+"),
        }
    }

    fn apply_user_overrides(&self, user: &mut UserSpec, cell: &SweepCell) {
        if let Some(d) = cell.deadline {
            user.experiment = user.experiment.clone().deadline(d);
        }
        if let Some(b) = cell.budget {
            user.experiment = user.experiment.clone().budget(b);
        }
        if let Some(p) = cell.policy {
            user.experiment = user.experiment.clone().optimization(p);
        }
        // Workload-shape axes only touch users whose workload has the knob
        // (validate() guarantees at least one does).
        if let Some(m) = cell.mean_interarrival {
            user.experiment.workload.set_arrival_mean(m);
        }
        if let Some(f) = cell.heavy_fraction {
            user.experiment.workload.set_heavy_fraction(f);
        }
        if let Some(i) = cell.trace_selector {
            user.experiment.workload.set_trace_selector(&self.trace_selectors[i]);
        }
        if let Some(i) = cell.mix_weights {
            user.experiment.workload.set_mix_weights(&self.mix_weights[i]);
        }
    }
}

/// One point of the expanded grid. `None` axis values mean "keep the base
/// scenario's value". Cells are plain values: `Send + Clone`, safe to hand
/// to any worker.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Position in the fixed expansion order (CSV row block).
    pub index: usize,
    /// Index into [`SweepSpec::resource_subsets`].
    pub subset: Option<usize>,
    /// Scheduling-policy override.
    pub policy: Option<Optimization>,
    /// User-count override.
    pub users: Option<usize>,
    /// Absolute deadline override.
    pub deadline: Option<f64>,
    /// Absolute budget override.
    pub budget: Option<f64>,
    /// Mean inter-arrival override (online-arrivals workloads).
    pub mean_interarrival: Option<f64>,
    /// Heavy-tail fraction override (heavy-tailed workloads).
    pub heavy_fraction: Option<f64>,
    /// Index into [`SweepSpec::trace_selectors`] (trace workloads).
    pub trace_selector: Option<usize>,
    /// Index into [`SweepSpec::mix_weights`] (mix workloads).
    pub mix_weights: Option<usize>,
    /// Default link-capacity override (flow networks).
    pub link_capacity: Option<f64>,
    /// MTBF-scaling override (faulted scenarios).
    pub mtbf_scaling: Option<f64>,
    /// Spot-discount override (market scenarios with a spot tier).
    pub spot_discount: Option<f64>,
    /// Replication number, `0..replications`.
    pub replication: usize,
    /// The RNG seed this cell runs with (a pure function of the base seed
    /// and `replication` — never of execution order).
    pub seed: u64,
}

/// Seed for replication `r` of a grid point: the `r`-th output of the
/// SplitMix64 stream seeded at `base` (`r = 0` is the base seed itself).
///
/// Replication 0 keeping the base seed means a 1-replication sweep
/// reproduces the corresponding single runs bit-for-bit. Within one base
/// seed, replications can never collide (SplitMix64 is a bijection over
/// distinct states). Distinct base seeds yield distinct whole streams
/// except for the standard SplitMix64 caveat (bases differing by an exact
/// multiple of the golden-ratio increment share a shifted stream) — in
/// particular there is no cheap cross-base collision for adjacent seeds.
/// Cells that differ only in parameter axes share a seed on purpose:
/// common random numbers make cross-cell comparisons lower-variance.
pub fn replication_seed(base: u64, replication: usize) -> u64 {
    let mut state = base;
    let mut seed = base;
    for _ in 0..replication {
        seed = crate::util::rng::splitmix64(&mut state);
    }
    seed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::ExperimentSpec;
    use crate::gridsim::AllocPolicy;
    use crate::scenario::ResourceSpec;

    fn small_resource(name: &str) -> ResourceSpec {
        ResourceSpec {
            name: name.into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: 2,
            mips_per_pe: 100.0,
            policy: AllocPolicy::TimeShared,
            price: 1.0,
            time_zone: 0.0,
            calendar: None,
        }
    }

    fn base() -> Scenario {
        Scenario::builder()
            .resource(small_resource("R0"))
            .resource(small_resource("R1"))
            .user(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
            .seed(9)
            .build()
    }

    #[test]
    fn empty_axes_is_one_cell() {
        let spec = SweepSpec::over(base());
        assert_eq!(spec.cell_count(), 1);
        let cells = spec.cells();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].seed, 9, "replication 0 keeps the base seed");
        let scenario = spec.scenario_for(&cells[0]);
        assert_eq!(scenario.users.len(), 1);
        assert_eq!(scenario.resources.len(), 2);
    }

    #[test]
    fn expansion_is_row_major_and_indexed() {
        let spec = SweepSpec::over(base())
            .deadlines(vec![100.0, 200.0])
            .budgets(vec![10.0, 20.0, 30.0])
            .replications(2);
        assert_eq!(spec.cell_count(), 12);
        let cells = spec.cells();
        assert_eq!(cells.len(), 12);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // Replication varies fastest, then budget, then deadline.
        assert_eq!(cells[0].deadline, Some(100.0));
        assert_eq!(cells[0].budget, Some(10.0));
        assert_eq!(cells[0].replication, 0);
        assert_eq!(cells[1].replication, 1);
        assert_eq!(cells[2].budget, Some(20.0));
        assert_eq!(cells[6].deadline, Some(200.0));
        assert_eq!(cells[6].budget, Some(10.0));
    }

    #[test]
    fn replication_seeds_differ_but_are_stable() {
        assert_eq!(replication_seed(9, 0), 9);
        let s1 = replication_seed(9, 1);
        let s2 = replication_seed(9, 2);
        assert_ne!(s1, 9);
        assert_ne!(s1, s2);
        assert_eq!(s1, replication_seed(9, 1), "pure function of (base, r)");
    }

    #[test]
    fn overrides_apply_to_every_user() {
        let spec = SweepSpec::over(base())
            .deadlines(vec![123.0])
            .budgets(vec![456.0])
            .user_counts(vec![3])
            .policies(vec![Optimization::Time]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 1);
        let scenario = spec.scenario_for(&cells[0]);
        assert_eq!(scenario.users.len(), 3);
        for u in &scenario.users {
            assert_eq!(u.experiment.deadline, crate::broker::DeadlineSpec::Absolute(123.0));
            assert_eq!(u.experiment.budget, crate::broker::BudgetSpec::Absolute(456.0));
            assert_eq!(u.experiment.optimization, Optimization::Time);
        }
    }

    #[test]
    fn resource_subsets_filter_in_base_order() {
        let spec = SweepSpec::over(base())
            .resource_subsets(vec![vec!["R1".into(), "R0".into()], vec!["R1".into()]]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        let full = spec.scenario_for(&cells[0]);
        // Subset listed R1 before R0, but base order wins.
        assert_eq!(full.resources[0].name, "R0");
        assert_eq!(full.resources[1].name, "R1");
        let only_r1 = spec.scenario_for(&cells[1]);
        assert_eq!(only_r1.resources.len(), 1);
        assert_eq!(only_r1.resources[0].name, "R1");
        assert_eq!(spec.subset_label(&cells[1]), "R1");
    }

    #[test]
    fn workload_axes_override_and_validate() {
        use crate::workload::{ArrivalProcess, WorkloadSpec};
        let mut base = base();
        base.users[0].experiment = base.users[0].experiment.clone().workload(
            WorkloadSpec::online(
                WorkloadSpec::heavy_tailed(6, 500.0, 0.1, 10.0),
                ArrivalProcess::Poisson { mean_interarrival: 9.0 },
            ),
        );
        let spec = SweepSpec::over(base)
            .mean_interarrivals(vec![2.0, 4.0])
            .heavy_fractions(vec![0.0, 0.5, 1.0]);
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 6);
        let cells = spec.cells();
        // Heavy fraction varies fastest (before replication).
        assert_eq!(cells[0].mean_interarrival, Some(2.0));
        assert_eq!(cells[0].heavy_fraction, Some(0.0));
        assert_eq!(cells[1].heavy_fraction, Some(0.5));
        assert_eq!(cells[3].mean_interarrival, Some(4.0));
        let scenario = spec.scenario_for(&cells[4]);
        let WorkloadSpec::OnlineArrivals { workload, arrivals } =
            &scenario.users[0].experiment.workload
        else {
            panic!("online workload expected")
        };
        assert_eq!(*arrivals, ArrivalProcess::Poisson { mean_interarrival: 4.0 });
        let WorkloadSpec::HeavyTailed { heavy_fraction, .. } = **workload else {
            panic!("heavy tail expected")
        };
        assert_eq!(heavy_fraction, 0.5);

        // A base without the knobs rejects the axes.
        let err = SweepSpec::over(base()).mean_interarrivals(vec![1.0]).validate().unwrap_err();
        assert!(err.to_string().contains("online_arrivals"), "{err}");
        let err = SweepSpec::over(base()).heavy_fractions(vec![0.5]).validate().unwrap_err();
        assert!(err.to_string().contains("heavy_tailed"), "{err}");
        let err = SweepSpec::over(base()).mean_interarrivals(vec![0.0]).validate().unwrap_err();
        assert!(err.to_string().contains("> 0"), "{err}");
    }

    #[test]
    fn trace_selector_and_mix_weight_axes() {
        use crate::workload::{TraceJob, TraceSelector, WorkloadSpec};
        let mut jobs = vec![
            TraceJob::new(0.0, 500.0, 0, 0),
            TraceJob::new(1.0, 600.0, 0, 0),
            TraceJob::new(2.0, 700.0, 0, 0),
        ];
        jobs[0].user = Some(3);
        jobs[1].user = Some(7);
        jobs[2].user = Some(3);
        let mut traced = base();
        traced.users[0].experiment = traced.users[0].experiment.clone().workload(
            WorkloadSpec::mix(vec![
                WorkloadSpec::trace(jobs),
                WorkloadSpec::task_farm(4, 500.0, 0.0),
            ]),
        );
        let spec = SweepSpec::over(traced.clone())
            .trace_selectors(vec![TraceSelector::user(3), TraceSelector::user(7)])
            .mix_weights(vec![vec![1.0, 1.0], vec![9.0, 1.0]]);
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 4);
        let cells = spec.cells();
        // Mix weights vary faster than trace selectors.
        assert_eq!(cells[0].trace_selector, Some(0));
        assert_eq!(cells[0].mix_weights, Some(0));
        assert_eq!(cells[1].mix_weights, Some(1));
        assert_eq!(cells[2].trace_selector, Some(1));
        let scenario = spec.scenario_for(&cells[3]);
        let workload = &scenario.users[0].experiment.workload;
        assert_eq!(workload.declared_jobs(), 1 + 4, "user 7 has one trace job");
        let WorkloadSpec::Mix { weights, .. } = workload else { panic!("mix expected") };
        assert_eq!(weights, &vec![9.0, 1.0]);
        assert_eq!(spec.selector_label(&cells[3]), "u7");
        assert_eq!(spec.mix_weights_label(&cells[3]), "9+1");
        // Unswept axes label as "base".
        let unswept = SweepSpec::over(traced.clone());
        let plain = unswept.cells();
        assert_eq!(unswept.selector_label(&plain[0]), "base");
        assert_eq!(unswept.mix_weights_label(&plain[0]), "base");

        // A selector that would empty a cell's trace fails validation.
        let err = SweepSpec::over(traced.clone())
            .trace_selectors(vec![TraceSelector::user(99)])
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("keeps none"), "{err:#}");
        // Axes against a base without the matching workload fail.
        let err = SweepSpec::over(base())
            .trace_selectors(vec![TraceSelector::all()])
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("trace"), "{err}");
        let err =
            SweepSpec::over(traced.clone()).mix_weights(vec![vec![1.0; 3]]).validate();
        assert!(err.unwrap_err().to_string().contains("3 parts"), "arity mismatch");
        let err = SweepSpec::over(traced).mix_weights(vec![vec![]]).validate().unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn link_capacity_axis_overrides_flow_network() {
        let mut flow_base = base();
        flow_base.network = NetworkSpec::Flow {
            default_capacity: 9600.0,
            latency: 0.0,
            capacities: vec![("R0".into(), 1200.0)],
        };
        let spec = SweepSpec::over(flow_base).link_capacities(vec![4800.0, 19200.0]);
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 2);
        let cells = spec.cells();
        assert_eq!(cells[0].link_capacity, Some(4800.0));
        let s = spec.scenario_for(&cells[1]);
        let NetworkSpec::Flow { default_capacity, capacities, .. } = &s.network else {
            panic!("flow network expected")
        };
        assert_eq!(*default_capacity, 19200.0);
        assert_eq!(capacities.len(), 1, "named per-entity overrides preserved");

        // A non-flow base rejects the axis; so do non-positive capacities.
        let err = SweepSpec::over(base()).link_capacities(vec![100.0]).validate().unwrap_err();
        assert!(err.to_string().contains("flow"), "{err}");
        let err = SweepSpec::over(base()).link_capacities(vec![0.0]).validate().unwrap_err();
        assert!(err.to_string().contains("> 0"), "{err}");
    }

    #[test]
    fn mtbf_scaling_axis_overrides_faults_spec() {
        use crate::faults::{FaultProcess, FaultsSpec};
        let mut faulted = base();
        faulted.faults =
            Some(FaultsSpec::all(FaultProcess::Exponential { mtbf: 500.0, mttr: 50.0 }));
        let spec = SweepSpec::over(faulted).mtbf_scalings(vec![0.25, 1.0, 4.0]);
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 3);
        let cells = spec.cells();
        assert_eq!(cells[0].mtbf_scaling, Some(0.25));
        let s = spec.scenario_for(&cells[2]);
        assert_eq!(s.faults.as_ref().unwrap().mtbf_scaling, 4.0);
        // The process parameters themselves are untouched — scaling is
        // applied at sampling time so per-resource overrides stay intact.
        assert_eq!(
            s.faults.unwrap().process_for("R0"),
            Some(&FaultProcess::Exponential { mtbf: 500.0, mttr: 50.0 })
        );

        // An unfaulted base rejects the axis; so do non-positive factors.
        let err = SweepSpec::over(base()).mtbf_scalings(vec![0.5]).validate().unwrap_err();
        assert!(err.to_string().contains("faults"), "{err}");
        let err = SweepSpec::over(base()).mtbf_scalings(vec![0.0]).validate().unwrap_err();
        assert!(err.to_string().contains("> 0"), "{err}");
    }

    #[test]
    fn spot_discount_axis_overrides_every_spot_entry() {
        use crate::market::MarketSpec;
        let mut market_base = base();
        market_base.market =
            Some(MarketSpec::new().spot_for("R0", 0.4).spot_for("R1", 0.6));
        let spec = SweepSpec::over(market_base).spot_discounts(vec![0.25, 0.5, 1.0]);
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 3);
        let cells = spec.cells();
        assert_eq!(cells[0].spot_discount, Some(0.25));
        assert_eq!(cells[2].spot_discount, Some(1.0));
        let s = spec.scenario_for(&cells[1]);
        let spot = &s.market.as_ref().unwrap().spot;
        assert_eq!(spot.len(), 2, "the spot roster itself is untouched");
        assert!(
            spot.iter().all(|(_, d)| *d == 0.5),
            "one swept value replaces every per-resource discount"
        );

        // A base without a spot tier rejects the axis; so do discounts
        // outside (0, 1].
        let err = SweepSpec::over(base()).spot_discounts(vec![0.5]).validate().unwrap_err();
        assert!(err.to_string().contains("spot"), "{err}");
        let mut priced_only = base();
        priced_only.market = Some(MarketSpec::new());
        let err =
            SweepSpec::over(priced_only).spot_discounts(vec![0.5]).validate().unwrap_err();
        assert!(err.to_string().contains("spot"), "{err}");
        let err = SweepSpec::over(base()).spot_discounts(vec![0.0]).validate().unwrap_err();
        assert!(err.to_string().contains("(0, 1]"), "{err}");
        let err = SweepSpec::over(base()).spot_discounts(vec![1.5]).validate().unwrap_err();
        assert!(err.to_string().contains("(0, 1]"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let err = SweepSpec::over(base()).replications(0).validate().unwrap_err();
        assert!(err.to_string().contains("replications"), "{err}");

        let err = SweepSpec::over(base()).user_counts(vec![0]).validate().unwrap_err();
        assert!(err.to_string().contains("user count"), "{err}");

        let err = SweepSpec::over(base())
            .resource_subsets(vec![vec!["R9".into()]])
            .validate()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("R9") && msg.contains("R0"), "{msg}");

        let err =
            SweepSpec::over(base()).resource_subsets(vec![vec![]]).validate().unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }
}
