//! Small CSV writer for figure and sweep series.
//!
//! Deliberately minimal: fields are written verbatim with `,` separators
//! and no quoting (every producer in this crate emits numbers and
//! identifier-shaped labels), and floats go through [`trim_float`] so the
//! bytes are a pure function of the values — the substrate of the sweep
//! engine's byte-identical-output contract.

use std::fmt::Write as _;
use std::path::Path;

/// Row-oriented CSV builder: fixed header, then one arity-checked row at a
/// time; render with [`to_string`](CsvWriter::to_string) or persist with
/// [`write_to`](CsvWriter::write_to).
#[derive(Debug, Clone)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// A writer with the given column names and no rows.
    pub fn new(header: &[&str]) -> CsvWriter {
        CsvWriter { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row. Panics when the field count does not match the
    /// header — a mis-shaped row is always a bug in the producer.
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len(), "row arity mismatch");
        self.rows.push(fields.to_vec());
    }

    /// Convenience: numeric row (each field through [`trim_float`]).
    pub fn row_f64(&mut self, fields: &[f64]) {
        self.row(&fields.iter().map(|x| trim_float(*x)).collect::<Vec<_>>());
    }

    /// Number of data rows (header excluded).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data row has been appended yet.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the full CSV: header line, then rows, `\n`-terminated.
    #[allow(clippy::inherent_to_string)] // established API; not a Display
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.header.join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.join(",")).unwrap();
        }
        out
    }

    /// Write the rendered CSV to `path`, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())
    }
}

/// Format a float compactly and deterministically: integral values (below
/// 10^15) without a decimal point, everything else with four decimals.
pub fn trim_float(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows() {
        let mut w = CsvWriter::new(&["deadline", "budget", "done"]);
        w.row_f64(&[100.0, 5000.0, 42.0]);
        w.row_f64(&[100.0, 6000.0, 57.5]);
        let s = w.to_string();
        assert_eq!(s, "deadline,budget,done\n100,5000,42\n100,6000,57.5000\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }

    #[test]
    fn writes_file() {
        let mut w = CsvWriter::new(&["x"]);
        w.row_f64(&[1.0]);
        let path = std::env::temp_dir().join("gridsim_csv_test/out.csv");
        w.write_to(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert!(read.starts_with("x\n1"));
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
