//! Textual report writer — the paper's optional `ReportWriter` entity: at
//! the end of a simulation it queries `GridStatistics` and renders a
//! summary per category.

use crate::broker::ExperimentResult;
use crate::gridsim::statistics::GridStatistics;
use std::fmt::Write as _;

/// Render the paper's three report categories (Fig 15) from recorded stats.
pub fn user_summary(stats: &GridStatistics) -> String {
    let mut out = String::new();
    for cat in ["USER.TimeUtilization", "USER.GridletCompletionFactor", "USER.BudgetUtilization"] {
        let acc = stats.accumulator_for(&format!("*.{cat}"));
        writeln!(
            out,
            "{cat}: n={} mean={:.4} min={:.4} max={:.4} sd={:.4}",
            acc.count(),
            acc.mean(),
            acc.min(),
            acc.max(),
            acc.std_dev()
        )
        .unwrap();
    }
    out
}

/// Per-experiment one-line summary.
pub fn experiment_line(user: &str, r: &ExperimentResult) -> String {
    format!(
        "{user}: {}/{} gridlets, spent {:.1}/{:.1} G$, time {:.1}/{:.1} ({} resources used)",
        r.gridlets_completed,
        r.gridlets_total,
        r.budget_spent,
        r.budget,
        r.finish_time - r.start_time,
        r.deadline,
        r.per_resource.iter().filter(|p| p.gridlets_completed > 0).count(),
    )
}

/// Per-resource breakdown table.
pub fn resource_table(r: &ExperimentResult) -> String {
    let mut out = String::from("resource  gridlets  spent(G$)\n");
    for p in &r.per_resource {
        writeln!(out, "{:<9} {:>8}  {:>9.1}", p.name, p.gridlets_completed, p.budget_spent)
            .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::experiment::ResourceOutcome;

    fn result() -> ExperimentResult {
        ExperimentResult {
            gridlets_completed: 10,
            gridlets_total: 20,
            budget_spent: 500.0,
            finish_time: 90.0,
            start_time: 0.0,
            deadline: 100.0,
            budget: 1000.0,
            gridlets_lost: 0,
            gridlets_resubmitted: 0,
            gridlets_abandoned: 0,
            gridlets_preempted: 0,
            per_resource: vec![
                ResourceOutcome { name: "R0".into(), gridlets_completed: 10, budget_spent: 500.0 },
                ResourceOutcome { name: "R1".into(), gridlets_completed: 0, budget_spent: 0.0 },
            ],
            trace: vec![],
        }
    }

    #[test]
    fn experiment_line_contents() {
        let line = experiment_line("U0", &result());
        assert!(line.contains("10/20"));
        assert!(line.contains("(1 resources used)"));
    }

    #[test]
    fn resource_table_lists_all() {
        let table = resource_table(&result());
        assert!(table.contains("R0"));
        assert!(table.contains("R1"));
    }

    #[test]
    fn user_summary_over_stats() {
        let mut stats = GridStatistics::new("s");
        use crate::gridsim::statistics::StatRecord;
        use crate::des::{Entity, Event};
        // Feed records directly through the event interface.
        let mut sim: crate::des::Simulation<crate::gridsim::Msg> = crate::des::Simulation::new();
        let _ = &mut sim; // stats consumed via records below
        for v in [0.5, 0.7] {
            let rec = StatRecord {
                time: 0.0,
                category: "U0.USER.TimeUtilization".into(),
                label: "U0".into(),
                value: v,
            };
            // Call on_event directly with a synthetic context-free shim:
            // simpler to push through the public records path.
            let ev: Event<crate::gridsim::Msg> = Event {
                time: 0.0,
                seq: 0,
                src: 0,
                dst: 0,
                tag: crate::gridsim::tags::RECORD_STATISTICS,
                kind: crate::des::EventKind::External,
                data: Some(crate::gridsim::Msg::Stat(rec)),
            };
            // Minimal ctx plumbing via a throwaway simulation.
            let mut queue = crate::des::EventQueue::new();
            let mut flows = crate::network::FlowTable::new();
            let mut stop = false;
            let names: Vec<std::sync::Arc<str>> = vec!["s".into()];
            let mut ctx = test_ctx(&mut queue, &mut flows, &mut stop, &names);
            stats.on_event(&mut ctx, ev);
        }
        let summary = user_summary(&stats);
        assert!(summary.contains("TimeUtilization: n=2 mean=0.6000"));
    }

    fn test_ctx<'a>(
        queue: &'a mut crate::des::EventQueue<crate::gridsim::Msg>,
        flows: &'a mut crate::network::FlowTable<crate::gridsim::Msg>,
        stop: &'a mut bool,
        names: &'a [std::sync::Arc<str>],
    ) -> crate::des::Ctx<'a, crate::gridsim::Msg> {
        crate::des::entity::test_ctx(0.0, 0, queue, flows, stop, names)
    }
}
