//! Long-format and aggregate CSV writers for sweep results, plus the
//! per-cell checkpoint format that makes long sweeps resumable.
//!
//! Two CSV shapes, both in cell-index order and free of wall-clock data, so
//! the bytes depend only on the spec (the determinism contract of
//! [`crate::sweep::engine::run_sweep`]):
//!
//! * **long** — one row per (cell, user): the tidy-data shape plotting
//!   tools ingest directly. Effective per-user deadline/budget come from the
//!   broker's [`crate::broker::ExperimentResult`] (absolute, after Eq 1–2),
//!   so factor-specified constraints show their resolved values.
//! * **aggregate** — one row per *grid point* (replications collapsed) with
//!   cross-replication statistics: per-user means plus the standard error
//!   of the mean over replications (`mean ± 1.96·stderr` is the usual 95%
//!   confidence interval; stderr is 0 for a single replication).
//!
//! # The checkpoint file (`sweep_cells.jsonl`)
//!
//! A checkpointed sweep ([`crate::sweep::run_sweep_checkpointed`]) appends
//! one fsync'd JSON line per *completed* cell to
//! [`CHECKPOINT_FILE`] in the output directory:
//!
//! ```text
//! {"digest":"9f2a…16 hex…","cell":17,"end_time":2143.5,"events":80211,
//!  "unfinished":[],"users":[{"completed":50,"total":50,"spent":8123.25,
//!  "finish":2143.5,"start":0,"deadline":3100,"budget":22000,
//!  "lost":2,"resubmitted":2,"abandoned":0,"preempted":0,
//!  "resources":[{"name":"R0","completed":50,"spent":8123.25}]}]}
//! ```
//!
//! * `digest` — [`cell_digest`] of the whole sweep ([`sweep_digest`] covers
//!   the base scenario and every axis) plus the cell's index and seed, as 16
//!   lower-case hex digits. Resume refuses a line whose digest does not
//!   match the spec being resumed, so a checkpoint can never leak results
//!   into a different sweep.
//! * `cell` — the cell's index in the fixed expansion order.
//! * the remaining fields — the cell's [`ScenarioReport`]: engine counters,
//!   indices of unfinished users, and per-user results (every float in
//!   Rust's shortest-roundtrip form, so a resumed report is
//!   **bit-identical** to the original and the final CSVs are byte-identical
//!   to an uninterrupted run). The per-user time-series `trace` is *not*
//!   checkpointed (no CSV consumes it); resumed reports carry it empty.
//!
//! The file is append-only and each line is fsync'd before the cell counts
//! as done, so a killed sweep loses at most the in-flight cells. A torn
//! final line (the kill landed mid-write) is detected and ignored on
//! resume; corruption anywhere else is a hard error.

use crate::broker::experiment::ResourceOutcome;
use crate::broker::{ExperimentResult, Optimization};
use crate::output::csv::{trim_float, CsvWriter};
use crate::scenario::ScenarioReport;
use crate::sweep::{SweepCell, SweepResults, SweepSpec};
use crate::util::json::{self, Value};
use crate::util::stats::Summary;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::fmt::Write as _;

/// Axis-coordinate columns shared by both writers (minus the replication
/// column, which the writers append in their own shape).
const AXIS_COLS: [&str; 13] = [
    "cell",
    "resources",
    "policy",
    "users",
    "deadline",
    "budget",
    "arrival_mean",
    "heavy_fraction",
    "trace_select",
    "mix_weights",
    "link_capacity",
    "mtbf_scaling",
    "spot_discount",
];

fn axis_fields(spec: &SweepSpec, cell: &SweepCell, users: usize) -> Vec<String> {
    vec![
        cell.index.to_string(),
        spec.subset_label(cell),
        match cell.policy {
            Some(p) => p.label().to_string(),
            None => base_policy_label(spec),
        },
        users.to_string(),
        cell.deadline.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.budget.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.mean_interarrival.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.heavy_fraction.map(trim_float).unwrap_or_else(|| "base".into()),
        spec.selector_label(cell),
        spec.mix_weights_label(cell),
        cell.link_capacity.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.mtbf_scaling.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.spot_discount.map(trim_float).unwrap_or_else(|| "base".into()),
    ]
}

/// Label for the policy axis when unswept: the base users' shared policy,
/// or `"mixed"` for heterogeneous bases.
fn base_policy_label(spec: &SweepSpec) -> String {
    let mut labels = spec.base.users.iter().map(|u| u.experiment.optimization);
    let first: Optimization = match labels.next() {
        Some(p) => p,
        None => return "mixed".into(),
    };
    if labels.all(|p| p == first) {
        first.label().to_string()
    } else {
        "mixed".into()
    }
}

/// One row per (cell, user).
pub fn long_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "replication",
        "seed",
        "user",
        "gridlets_completed",
        "gridlets_total",
        "user_deadline",
        "user_budget",
        "time_used",
        "budget_spent",
        "gridlets_lost",
        "gridlets_resubmitted",
        "gridlets_abandoned",
        "gridlets_preempted",
        "finished",
    ]);
    let mut csv = CsvWriter::new(&header);
    for outcome in &results.outcomes {
        let axes = axis_fields(spec, &outcome.cell, outcome.report.users.len());
        for (u, result) in outcome.report.users.iter().enumerate() {
            let mut row = axes.clone();
            let finished = !outcome.report.unfinished.contains(&u);
            row.extend([
                outcome.cell.replication.to_string(),
                outcome.cell.seed.to_string(),
                u.to_string(),
                result.gridlets_completed.to_string(),
                result.gridlets_total.to_string(),
                trim_float(result.deadline),
                trim_float(result.budget),
                trim_float(result.finish_time - result.start_time),
                trim_float(result.budget_spent),
                result.gridlets_lost.to_string(),
                result.gridlets_resubmitted.to_string(),
                result.gridlets_abandoned.to_string(),
                result.gridlets_preempted.to_string(),
                if finished { "1".into() } else { "0".into() },
            ]);
            csv.row(&row);
        }
    }
    csv
}

/// One row per grid point (the paper's Figures 33–38 shape), aggregating
/// the point's replications: per-user means of completions / time used /
/// budget spent, each with the standard error over replications, plus
/// summed engine counters. The `cell` column carries the grid point's first
/// cell index (its replication-0 cell).
pub fn aggregate_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "replications",
        "mean_gridlets_completed",
        "stderr_gridlets_completed",
        "mean_time_used",
        "stderr_time_used",
        "mean_budget_spent",
        "stderr_budget_spent",
        "unfinished_users",
        "events",
    ]);
    let mut csv = CsvWriter::new(&header);
    // Replication varies fastest in the expansion order, so one grid point
    // is one contiguous chunk of `replications` cells.
    let reps = spec.replications.max(1);
    assert_eq!(results.outcomes.len() % reps, 0, "outcomes not a whole grid");
    for group in results.outcomes.chunks(reps) {
        let first = &group[0];
        let mut completed = Summary::new();
        let mut time_used = Summary::new();
        let mut spent = Summary::new();
        let mut unfinished = 0usize;
        let mut events = 0u64;
        for outcome in group {
            completed.add(outcome.report.mean_completed());
            time_used.add(outcome.report.mean_finish_time());
            spent.add(outcome.report.mean_spent());
            unfinished += outcome.report.unfinished.len();
            events += outcome.report.events;
        }
        let mut row = axis_fields(spec, &first.cell, first.report.users.len());
        row.extend([
            reps.to_string(),
            trim_float(completed.mean()),
            trim_float(completed.std_err()),
            trim_float(time_used.mean()),
            trim_float(time_used.std_err()),
            trim_float(spent.mean()),
            trim_float(spent.std_err()),
            unfinished.to_string(),
            events.to_string(),
        ]);
        csv.row(&row);
    }
    csv
}

// ---------------------------------------------------------------------------
// Checkpoint format (sweep_cells.jsonl)
// ---------------------------------------------------------------------------

/// File name of the per-cell checkpoint a checkpointed sweep writes into its
/// output directory (see the module docs for the line format).
pub const CHECKPOINT_FILE: &str = "sweep_cells.jsonl";

/// FNV-1a 64-bit accumulator usable as a `fmt::Write` sink, so digests of
/// large values (a sweep spec holding a 10^5-record shared trace) stream
/// through `Debug` formatting without materializing the string.
struct FnvWriter {
    hash: u64,
}

impl FnvWriter {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> FnvWriter {
        FnvWriter { hash: Self::OFFSET }
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.hash = (self.hash ^ u64::from(b)).wrapping_mul(Self::PRIME);
        }
    }
}

impl std::fmt::Write for FnvWriter {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        self.update(s.as_bytes());
        Ok(())
    }
}

/// Digest of a whole [`SweepSpec`] — the base scenario (resources, users,
/// workloads including shared trace contents, seed, network, advisor,
/// broker tuning, kernel limits) and every axis. Two specs that could
/// produce different cells digest differently; the digest is a pure
/// function of the spec value, never of execution.
///
/// Computed by streaming the spec's `Debug` representation through FNV-1a
/// (Rust formats floats in shortest-roundtrip form, so the text — and hence
/// the digest — is deterministic). The representation can change across
/// crate versions; that only *invalidates* old checkpoints (resume refuses
/// them), it can never mis-match a foreign cell to this spec's.
pub fn sweep_digest(spec: &SweepSpec) -> u64 {
    let mut w = FnvWriter::new();
    let _ = write!(w, "{spec:?}");
    w.hash
}

/// Digest keying one checkpoint line: the sweep digest plus the cell's
/// index and seed. A line only resumes into the cell it was written for.
pub fn cell_digest(sweep_digest: u64, index: usize, seed: u64) -> u64 {
    let mut w = FnvWriter::new();
    w.update(&sweep_digest.to_le_bytes());
    w.update(&(index as u64).to_le_bytes());
    w.update(&seed.to_le_bytes());
    w.hash
}

/// Serialize one completed cell into its checkpoint line (no trailing
/// newline). Floats are written in shortest-roundtrip form, so
/// [`parse_checkpoint`] reconstructs a bit-identical [`ScenarioReport`].
pub fn checkpoint_line(cell_digest: u64, cell_index: usize, report: &ScenarioReport) -> String {
    let users: Vec<Value> = report
        .users
        .iter()
        .map(|u| {
            Value::obj(vec![
                ("completed", u.gridlets_completed.into()),
                ("total", u.gridlets_total.into()),
                ("spent", u.budget_spent.into()),
                ("finish", u.finish_time.into()),
                ("start", u.start_time.into()),
                ("deadline", u.deadline.into()),
                ("budget", u.budget.into()),
                ("lost", u.gridlets_lost.into()),
                ("resubmitted", u.gridlets_resubmitted.into()),
                ("abandoned", u.gridlets_abandoned.into()),
                ("preempted", u.gridlets_preempted.into()),
                (
                    "resources",
                    Value::Arr(
                        u.per_resource
                            .iter()
                            .map(|r| {
                                Value::obj(vec![
                                    ("name", Value::str(r.name.clone())),
                                    ("completed", r.gridlets_completed.into()),
                                    ("spent", r.budget_spent.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let record = Value::obj(vec![
        ("digest", Value::str(format!("{cell_digest:016x}"))),
        ("cell", cell_index.into()),
        ("end_time", report.end_time.into()),
        ("events", (report.events as usize).into()),
        (
            "unfinished",
            Value::Arr(report.unfinished.iter().map(|&i| i.into()).collect()),
        ),
        ("users", Value::Arr(users)),
    ]);
    json::to_string(&record)
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    let n = v.req_f64(key)?;
    if n >= 0.0 && n.fract() == 0.0 && n < 9_007_199_254_740_992.0 {
        Ok(n as usize)
    } else {
        bail!("field {key:?} must be a non-negative integer, got {n}")
    }
}

/// Like [`req_usize`] but an absent key reads as 0 (used for the fault
/// counters, which a line from before the reliability layer simply lacks —
/// such a line is refused by the digest check anyway, but parsing must not
/// be the thing that trips first).
fn opt_usize(v: &Value, key: &str) -> Result<usize> {
    if v.get(key).is_none() {
        return Ok(0);
    }
    req_usize(v, key)
}

/// Parse one checkpoint line back into its cell index and report.
fn parse_checkpoint_line(line: &str) -> Result<(u64, usize, ScenarioReport)> {
    let v = json::parse(line).map_err(|e| anyhow!("{e}"))?;
    let digest = u64::from_str_radix(v.req_str("digest")?, 16)
        .map_err(|e| anyhow!("bad digest: {e}"))?;
    let cell = req_usize(&v, "cell")?;
    let unfinished = v
        .get("unfinished")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing \"unfinished\" array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as usize)
                .ok_or_else(|| anyhow!("\"unfinished\" must hold non-negative integers"))
        })
        .collect::<Result<Vec<_>>>()?;
    let users = v
        .get("users")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing \"users\" array"))?
        .iter()
        .map(|u| -> Result<ExperimentResult> {
            let per_resource = u
                .get("resources")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("missing \"resources\" array"))?
                .iter()
                .map(|r| -> Result<ResourceOutcome> {
                    Ok(ResourceOutcome {
                        name: r.req_str("name")?.to_string(),
                        gridlets_completed: req_usize(r, "completed")?,
                        budget_spent: r.req_f64("spent")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(ExperimentResult {
                gridlets_completed: req_usize(u, "completed")?,
                gridlets_total: req_usize(u, "total")?,
                budget_spent: u.req_f64("spent")?,
                finish_time: u.req_f64("finish")?,
                start_time: u.req_f64("start")?,
                deadline: u.req_f64("deadline")?,
                budget: u.req_f64("budget")?,
                gridlets_lost: opt_usize(u, "lost")?,
                gridlets_resubmitted: opt_usize(u, "resubmitted")?,
                gridlets_abandoned: opt_usize(u, "abandoned")?,
                gridlets_preempted: opt_usize(u, "preempted")?,
                per_resource,
                // The time-series trace is not checkpointed (no CSV
                // consumes it); resumed reports carry it empty.
                trace: vec![],
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let report = ScenarioReport {
        users,
        unfinished,
        end_time: v.req_f64("end_time")?,
        events: req_usize(&v, "events")? as u64,
    };
    Ok((digest, cell, report))
}

/// Parse a `sweep_cells.jsonl` file written for the sweep whose
/// [`sweep_digest`] is `digest`, returning the completed cells by index.
/// (Taking the digest rather than the spec lets callers that already
/// computed it — the engine does — skip a second full Debug-format pass
/// over a spec that may hold a 10^5-record shared trace.)
///
/// Strictness rules:
/// * a line whose digest does not match [`cell_digest`] for its cell (or
///   whose cell index is out of range) is a hard error — the checkpoint
///   belongs to a different sweep (changed base, axes, seed, or crate
///   version) — even when it is the final line, since such a line parsed
///   cleanly and therefore is not torn damage;
/// * an *unparseable* final line is ignored (the writing process was
///   killed mid-append — exactly the scenario checkpoints exist for);
/// * an unparseable earlier line — including a blank one; the writer never
///   emits those, so one is always foreign damage — is a hard error, and
///   errors report the raw 1-based line number in the file.
pub fn parse_checkpoint(
    text: &str,
    digest: u64,
    cells: &[SweepCell],
) -> Result<HashMap<usize, ScenarioReport>> {
    // Raw lines, nothing filtered: blank lines never come from the writer,
    // so they fall through parse_checkpoint_line as corruption (tolerated
    // only in final position, like any torn tail), and reported line
    // numbers match the file.
    let lines: Vec<&str> = text.lines().collect();
    let mut completed = HashMap::new();
    for (i, line) in lines.iter().enumerate() {
        let (d, cell, report) = match parse_checkpoint_line(line) {
            Ok(parsed) => parsed,
            // A torn final line means the writer was killed mid-append;
            // that cell simply reruns. (A line from a different sweep is
            // not torn damage — it parses, and fails the digest check
            // below, which is fatal even on the last line.)
            Err(_) if i + 1 == lines.len() => break,
            Err(e) => {
                return Err(e.context(format!("{CHECKPOINT_FILE} line {}", i + 1)));
            }
        };
        if cell >= cells.len()
            || d != cell_digest(digest, cell, cells.get(cell).map_or(0, |c| c.seed))
        {
            bail!(
                "{CHECKPOINT_FILE} line {}: digest mismatch at cell {cell}: this \
                 checkpoint was written by a different sweep (changed scenario, axes, \
                 seed, or version); delete it or rerun without --resume",
                i + 1
            );
        }
        completed.insert(cell, report);
    }
    Ok(completed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::ExperimentSpec;
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, Scenario};
    use crate::sweep::run_sweep;

    fn spec() -> SweepSpec {
        let base = Scenario::builder()
            .resource(ResourceSpec {
                name: "R0".into(),
                arch: "test".into(),
                os: "linux".into(),
                machines: 1,
                pes_per_machine: 2,
                mips_per_pe: 100.0,
                policy: AllocPolicy::TimeShared,
                price: 1.0,
                time_zone: 0.0,
                calendar: None,
            })
            .user(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
            .seed(3)
            .build();
        SweepSpec::over(base).budgets(vec![1e6, 5.0]).user_counts(vec![1, 2])
    }

    #[test]
    fn long_rows_are_cell_times_users() {
        let s = spec();
        let results = run_sweep(&s, 2).unwrap();
        let csv = long_csv(&s, &results);
        // Cells: users {1,2} × budgets {1e6, 5}; rows = 1+1+2+2.
        assert_eq!(csv.len(), 6);
        let text = csv.to_string();
        assert!(text.starts_with(
            "cell,resources,policy,users,deadline,budget,arrival_mean,heavy_fraction,\
             trace_select,mix_weights,link_capacity,mtbf_scaling,spot_discount,"
        ));
        assert!(
            text.contains(
                "gridlets_lost,gridlets_resubmitted,gridlets_abandoned,\
                 gridlets_preempted,finished"
            ),
            "fault and market counters in the long header: {text}"
        );
        assert!(text.contains(",all,cost,"), "unswept axes echo base values: {text}");
        assert!(
            text.contains(",base,base,base,base,"),
            "unswept workload axes print base: {text}"
        );
    }

    #[test]
    fn aggregate_rows_are_one_per_grid_point() {
        let s = spec();
        let results = run_sweep(&s, 1).unwrap();
        let csv = aggregate_csv(&s, &results);
        // No replications axis: every grid point is one cell.
        assert_eq!(csv.len(), 4);
        let text = csv.to_string();
        assert!(text.contains("mean_gridlets_completed"));
        assert!(text.contains("stderr_gridlets_completed"));
        assert!(text.lines().count() == 5);
        // With one replication every stderr is exactly 0.
        for line in text.lines().skip(1) {
            let fields: Vec<&str> = line.split(',').collect();
            assert_eq!(fields[13], "1", "replications column");
            assert_eq!(fields[15], "0", "stderr with 1 rep");
            assert_eq!(fields[17], "0", "stderr with 1 rep");
            assert_eq!(fields[19], "0", "stderr with 1 rep");
        }
    }

    #[test]
    fn checkpoint_lines_round_trip_bit_exact() {
        let s = spec();
        let results = run_sweep(&s, 2).unwrap();
        let digest = sweep_digest(&s);
        let cells = s.cells();
        let mut text = String::new();
        for o in &results.outcomes {
            text.push_str(&checkpoint_line(
                cell_digest(digest, o.cell.index, o.cell.seed),
                o.cell.index,
                &o.report,
            ));
            text.push('\n');
        }
        let completed = parse_checkpoint(&text, digest, &cells).unwrap();
        assert_eq!(completed.len(), results.outcomes.len());
        for o in &results.outcomes {
            let r = &completed[&o.cell.index];
            assert_eq!(r.events, o.report.events);
            assert_eq!(r.end_time.to_bits(), o.report.end_time.to_bits());
            assert_eq!(r.unfinished, o.report.unfinished);
            assert_eq!(r.users.len(), o.report.users.len());
            for (a, b) in r.users.iter().zip(&o.report.users) {
                assert_eq!(a.gridlets_completed, b.gridlets_completed);
                assert_eq!(a.gridlets_total, b.gridlets_total);
                assert_eq!(a.budget_spent.to_bits(), b.budget_spent.to_bits());
                assert_eq!(a.finish_time.to_bits(), b.finish_time.to_bits());
                assert_eq!(a.start_time.to_bits(), b.start_time.to_bits());
                assert_eq!(a.deadline.to_bits(), b.deadline.to_bits());
                assert_eq!(a.budget.to_bits(), b.budget.to_bits());
                assert_eq!(a.gridlets_lost, b.gridlets_lost);
                assert_eq!(a.gridlets_resubmitted, b.gridlets_resubmitted);
                assert_eq!(a.gridlets_abandoned, b.gridlets_abandoned);
                assert_eq!(a.gridlets_preempted, b.gridlets_preempted);
                assert_eq!(a.per_resource.len(), b.per_resource.len());
                for (x, y) in a.per_resource.iter().zip(&b.per_resource) {
                    assert_eq!(x.name, y.name);
                    assert_eq!(x.gridlets_completed, y.gridlets_completed);
                    assert_eq!(x.budget_spent.to_bits(), y.budget_spent.to_bits());
                }
            }
        }
    }

    #[test]
    fn checkpoint_tolerates_torn_tail_but_not_corruption_or_foreign_specs() {
        let s = spec();
        let results = run_sweep(&s, 1).unwrap();
        let digest = sweep_digest(&s);
        let cells = s.cells();
        let lines: Vec<String> = results
            .outcomes
            .iter()
            .map(|o| {
                checkpoint_line(
                    cell_digest(digest, o.cell.index, o.cell.seed),
                    o.cell.index,
                    &o.report,
                )
            })
            .collect();
        let text = lines.join("\n") + "\n";

        // A torn final line (killed mid-append) is ignored.
        let torn = format!("{text}{{\"digest\":\"00ab");
        let completed = parse_checkpoint(&torn, digest, &cells).unwrap();
        assert_eq!(completed.len(), lines.len());

        // The same garbage anywhere else is a hard error.
        let corrupt = format!("{{\"digest\":\"00ab\n{text}");
        let err = format!("{:#}", parse_checkpoint(&corrupt, digest, &cells).unwrap_err());
        assert!(err.contains("line 1"), "{err}");

        // A checkpoint from a different sweep (changed axis) is refused —
        // even when the mismatching line is the last one.
        let other = spec().deadlines(vec![77.0]);
        assert_ne!(digest, sweep_digest(&other), "axis change changes digest");
        let one_line = format!("{}\n", lines[0]);
        let err =
            parse_checkpoint(&one_line, sweep_digest(&other), &other.cells()).unwrap_err();
        assert!(err.to_string().contains("different sweep"), "{err}");

        // The digest itself is a pure function of the spec value.
        assert_eq!(sweep_digest(&s), sweep_digest(&spec()));
    }

    #[test]
    fn aggregate_collapses_replications_with_stderr() {
        // Variation > 0 makes replications draw different workloads, so the
        // cross-replication spread is real.
        let mut s = spec();
        s.base.users[0].experiment =
            ExperimentSpec::task_farm(4, 500.0, 0.10).deadline(1e4).budget(1e6);
        let s = SweepSpec::over(s.base).replications(3);
        let results = run_sweep(&s, 2).unwrap();
        assert_eq!(results.outcomes.len(), 3);
        let csv = aggregate_csv(&s, &results);
        assert_eq!(csv.len(), 1, "3 replications collapse into one row");
        let text = csv.to_string();
        let fields: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(fields[13], "3", "replications column");
        // Mean time used must match the hand-computed mean of the cells.
        let mut expect = Summary::new();
        for o in &results.outcomes {
            expect.add(o.report.mean_finish_time());
        }
        assert_eq!(fields[16], trim_float(expect.mean()), "mean_time_used");
        assert_eq!(fields[17], trim_float(expect.std_err()), "stderr_time_used");
        // Engine events are summed across replications.
        let events: u64 = results.outcomes.iter().map(|o| o.report.events).sum();
        assert_eq!(fields[21], events.to_string());
    }
}
