//! Long-format and aggregate CSV writers for sweep results.
//!
//! Two shapes, both in cell-index order and free of wall-clock data, so the
//! bytes depend only on the spec (the determinism contract of
//! [`crate::sweep::engine::run_sweep`]):
//!
//! * **long** — one row per (cell, user): the tidy-data shape plotting
//!   tools ingest directly. Effective per-user deadline/budget come from the
//!   broker's [`crate::broker::ExperimentResult`] (absolute, after Eq 1–2),
//!   so factor-specified constraints show their resolved values.
//! * **aggregate** — one row per cell with per-user means: the shape of the
//!   paper's multi-user figures (33–38).

use crate::broker::Optimization;
use crate::output::csv::{trim_float, CsvWriter};
use crate::sweep::{SweepResults, SweepSpec};

/// Axis-coordinate columns shared by both writers.
const AXIS_COLS: [&str; 7] =
    ["cell", "resources", "policy", "users", "deadline", "budget", "replication"];

fn axis_fields(spec: &SweepSpec, results: &SweepResults, i: usize) -> Vec<String> {
    let outcome = &results.outcomes[i];
    let cell = &outcome.cell;
    vec![
        cell.index.to_string(),
        spec.subset_label(cell),
        match cell.policy {
            Some(p) => p.label().to_string(),
            None => base_policy_label(spec),
        },
        outcome.report.users.len().to_string(),
        cell.deadline.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.budget.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.replication.to_string(),
    ]
}

/// Label for the policy axis when unswept: the base users' shared policy,
/// or `"mixed"` for heterogeneous bases.
fn base_policy_label(spec: &SweepSpec) -> String {
    let mut labels = spec.base.users.iter().map(|u| u.experiment.optimization);
    let first: Optimization = match labels.next() {
        Some(p) => p,
        None => return "mixed".into(),
    };
    if labels.all(|p| p == first) {
        first.label().to_string()
    } else {
        "mixed".into()
    }
}

/// One row per (cell, user).
pub fn long_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "seed",
        "user",
        "gridlets_completed",
        "gridlets_total",
        "user_deadline",
        "user_budget",
        "time_used",
        "budget_spent",
        "finished",
    ]);
    let mut csv = CsvWriter::new(&header);
    for (i, outcome) in results.outcomes.iter().enumerate() {
        let axes = axis_fields(spec, results, i);
        for (u, result) in outcome.report.users.iter().enumerate() {
            let mut row = axes.clone();
            let finished = !outcome.report.unfinished.contains(&u);
            row.extend([
                outcome.cell.seed.to_string(),
                u.to_string(),
                result.gridlets_completed.to_string(),
                result.gridlets_total.to_string(),
                trim_float(result.deadline),
                trim_float(result.budget),
                trim_float(result.finish_time - result.start_time),
                trim_float(result.budget_spent),
                if finished { "1".into() } else { "0".into() },
            ]);
            csv.row(&row);
        }
    }
    csv
}

/// One row per cell with per-user means (the paper's Figures 33–38 shape).
pub fn aggregate_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "seed",
        "mean_gridlets_completed",
        "mean_time_used",
        "mean_budget_spent",
        "unfinished_users",
        "events",
        "end_time",
    ]);
    let mut csv = CsvWriter::new(&header);
    for (i, outcome) in results.outcomes.iter().enumerate() {
        let mut row = axis_fields(spec, results, i);
        let report = &outcome.report;
        row.extend([
            outcome.cell.seed.to_string(),
            trim_float(report.mean_completed()),
            trim_float(report.mean_finish_time()),
            trim_float(report.mean_spent()),
            report.unfinished.len().to_string(),
            report.events.to_string(),
            trim_float(report.end_time),
        ]);
        csv.row(&row);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::ExperimentSpec;
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, Scenario};
    use crate::sweep::run_sweep;

    fn spec() -> SweepSpec {
        let base = Scenario::builder()
            .resource(ResourceSpec {
                name: "R0".into(),
                arch: "test".into(),
                os: "linux".into(),
                machines: 1,
                pes_per_machine: 2,
                mips_per_pe: 100.0,
                policy: AllocPolicy::TimeShared,
                price: 1.0,
                time_zone: 0.0,
                calendar: None,
            })
            .user(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
            .seed(3)
            .build();
        SweepSpec::over(base).budgets(vec![1e6, 5.0]).user_counts(vec![1, 2])
    }

    #[test]
    fn long_rows_are_cell_times_users() {
        let s = spec();
        let results = run_sweep(&s, 2).unwrap();
        let csv = long_csv(&s, &results);
        // Cells: users {1,2} × budgets {1e6, 5}; rows = 1+1+2+2.
        assert_eq!(csv.len(), 6);
        let text = csv.to_string();
        assert!(text.starts_with("cell,resources,policy,users,deadline,budget,replication,"));
        assert!(text.contains(",all,cost,"), "unswept axes echo base values: {text}");
    }

    #[test]
    fn aggregate_rows_are_one_per_cell() {
        let s = spec();
        let results = run_sweep(&s, 1).unwrap();
        let csv = aggregate_csv(&s, &results);
        assert_eq!(csv.len(), 4);
        let text = csv.to_string();
        assert!(text.contains("mean_gridlets_completed"));
        // The starved-budget cells complete fewer gridlets than the funded
        // ones; both appear.
        assert!(text.lines().count() == 5);
    }
}
