//! Long-format and aggregate CSV writers for sweep results.
//!
//! Two shapes, both in cell-index order and free of wall-clock data, so the
//! bytes depend only on the spec (the determinism contract of
//! [`crate::sweep::engine::run_sweep`]):
//!
//! * **long** — one row per (cell, user): the tidy-data shape plotting
//!   tools ingest directly. Effective per-user deadline/budget come from the
//!   broker's [`crate::broker::ExperimentResult`] (absolute, after Eq 1–2),
//!   so factor-specified constraints show their resolved values.
//! * **aggregate** — one row per *grid point* (replications collapsed) with
//!   cross-replication statistics: per-user means plus the standard error
//!   of the mean over replications (`mean ± 1.96·stderr` is the usual 95%
//!   confidence interval; stderr is 0 for a single replication).

use crate::broker::Optimization;
use crate::output::csv::{trim_float, CsvWriter};
use crate::sweep::{SweepCell, SweepResults, SweepSpec};
use crate::util::stats::Summary;

/// Axis-coordinate columns shared by both writers (minus the replication
/// column, which the writers append in their own shape).
const AXIS_COLS: [&str; 10] = [
    "cell",
    "resources",
    "policy",
    "users",
    "deadline",
    "budget",
    "arrival_mean",
    "heavy_fraction",
    "trace_select",
    "mix_weights",
];

fn axis_fields(spec: &SweepSpec, cell: &SweepCell, users: usize) -> Vec<String> {
    vec![
        cell.index.to_string(),
        spec.subset_label(cell),
        match cell.policy {
            Some(p) => p.label().to_string(),
            None => base_policy_label(spec),
        },
        users.to_string(),
        cell.deadline.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.budget.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.mean_interarrival.map(trim_float).unwrap_or_else(|| "base".into()),
        cell.heavy_fraction.map(trim_float).unwrap_or_else(|| "base".into()),
        spec.selector_label(cell),
        spec.mix_weights_label(cell),
    ]
}

/// Label for the policy axis when unswept: the base users' shared policy,
/// or `"mixed"` for heterogeneous bases.
fn base_policy_label(spec: &SweepSpec) -> String {
    let mut labels = spec.base.users.iter().map(|u| u.experiment.optimization);
    let first: Optimization = match labels.next() {
        Some(p) => p,
        None => return "mixed".into(),
    };
    if labels.all(|p| p == first) {
        first.label().to_string()
    } else {
        "mixed".into()
    }
}

/// One row per (cell, user).
pub fn long_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "replication",
        "seed",
        "user",
        "gridlets_completed",
        "gridlets_total",
        "user_deadline",
        "user_budget",
        "time_used",
        "budget_spent",
        "finished",
    ]);
    let mut csv = CsvWriter::new(&header);
    for outcome in &results.outcomes {
        let axes = axis_fields(spec, &outcome.cell, outcome.report.users.len());
        for (u, result) in outcome.report.users.iter().enumerate() {
            let mut row = axes.clone();
            let finished = !outcome.report.unfinished.contains(&u);
            row.extend([
                outcome.cell.replication.to_string(),
                outcome.cell.seed.to_string(),
                u.to_string(),
                result.gridlets_completed.to_string(),
                result.gridlets_total.to_string(),
                trim_float(result.deadline),
                trim_float(result.budget),
                trim_float(result.finish_time - result.start_time),
                trim_float(result.budget_spent),
                if finished { "1".into() } else { "0".into() },
            ]);
            csv.row(&row);
        }
    }
    csv
}

/// One row per grid point (the paper's Figures 33–38 shape), aggregating
/// the point's replications: per-user means of completions / time used /
/// budget spent, each with the standard error over replications, plus
/// summed engine counters. The `cell` column carries the grid point's first
/// cell index (its replication-0 cell).
pub fn aggregate_csv(spec: &SweepSpec, results: &SweepResults) -> CsvWriter {
    let mut header: Vec<&str> = AXIS_COLS.to_vec();
    header.extend([
        "replications",
        "mean_gridlets_completed",
        "stderr_gridlets_completed",
        "mean_time_used",
        "stderr_time_used",
        "mean_budget_spent",
        "stderr_budget_spent",
        "unfinished_users",
        "events",
    ]);
    let mut csv = CsvWriter::new(&header);
    // Replication varies fastest in the expansion order, so one grid point
    // is one contiguous chunk of `replications` cells.
    let reps = spec.replications.max(1);
    assert_eq!(results.outcomes.len() % reps, 0, "outcomes not a whole grid");
    for group in results.outcomes.chunks(reps) {
        let first = &group[0];
        let mut completed = Summary::new();
        let mut time_used = Summary::new();
        let mut spent = Summary::new();
        let mut unfinished = 0usize;
        let mut events = 0u64;
        for outcome in group {
            completed.add(outcome.report.mean_completed());
            time_used.add(outcome.report.mean_finish_time());
            spent.add(outcome.report.mean_spent());
            unfinished += outcome.report.unfinished.len();
            events += outcome.report.events;
        }
        let mut row = axis_fields(spec, &first.cell, first.report.users.len());
        row.extend([
            reps.to_string(),
            trim_float(completed.mean()),
            trim_float(completed.std_err()),
            trim_float(time_used.mean()),
            trim_float(time_used.std_err()),
            trim_float(spent.mean()),
            trim_float(spent.std_err()),
            unfinished.to_string(),
            events.to_string(),
        ]);
        csv.row(&row);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::ExperimentSpec;
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, Scenario};
    use crate::sweep::run_sweep;

    fn spec() -> SweepSpec {
        let base = Scenario::builder()
            .resource(ResourceSpec {
                name: "R0".into(),
                arch: "test".into(),
                os: "linux".into(),
                machines: 1,
                pes_per_machine: 2,
                mips_per_pe: 100.0,
                policy: AllocPolicy::TimeShared,
                price: 1.0,
                time_zone: 0.0,
                calendar: None,
            })
            .user(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
            .seed(3)
            .build();
        SweepSpec::over(base).budgets(vec![1e6, 5.0]).user_counts(vec![1, 2])
    }

    #[test]
    fn long_rows_are_cell_times_users() {
        let s = spec();
        let results = run_sweep(&s, 2).unwrap();
        let csv = long_csv(&s, &results);
        // Cells: users {1,2} × budgets {1e6, 5}; rows = 1+1+2+2.
        assert_eq!(csv.len(), 6);
        let text = csv.to_string();
        assert!(text.starts_with(
            "cell,resources,policy,users,deadline,budget,arrival_mean,heavy_fraction,\
             trace_select,mix_weights,"
        ));
        assert!(text.contains(",all,cost,"), "unswept axes echo base values: {text}");
        assert!(
            text.contains(",base,base,base,base,"),
            "unswept workload axes print base: {text}"
        );
    }

    #[test]
    fn aggregate_rows_are_one_per_grid_point() {
        let s = spec();
        let results = run_sweep(&s, 1).unwrap();
        let csv = aggregate_csv(&s, &results);
        // No replications axis: every grid point is one cell.
        assert_eq!(csv.len(), 4);
        let text = csv.to_string();
        assert!(text.contains("mean_gridlets_completed"));
        assert!(text.contains("stderr_gridlets_completed"));
        assert!(text.lines().count() == 5);
        // With one replication every stderr is exactly 0.
        for line in text.lines().skip(1) {
            let fields: Vec<&str> = line.split(',').collect();
            assert_eq!(fields[10], "1", "replications column");
            assert_eq!(fields[12], "0", "stderr with 1 rep");
            assert_eq!(fields[14], "0", "stderr with 1 rep");
            assert_eq!(fields[16], "0", "stderr with 1 rep");
        }
    }

    #[test]
    fn aggregate_collapses_replications_with_stderr() {
        // Variation > 0 makes replications draw different workloads, so the
        // cross-replication spread is real.
        let mut s = spec();
        s.base.users[0].experiment =
            ExperimentSpec::task_farm(4, 500.0, 0.10).deadline(1e4).budget(1e6);
        let s = SweepSpec::over(s.base).replications(3);
        let results = run_sweep(&s, 2).unwrap();
        assert_eq!(results.outcomes.len(), 3);
        let csv = aggregate_csv(&s, &results);
        assert_eq!(csv.len(), 1, "3 replications collapse into one row");
        let text = csv.to_string();
        let fields: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(fields[10], "3", "replications column");
        // Mean time used must match the hand-computed mean of the cells.
        let mut expect = Summary::new();
        for o in &results.outcomes {
            expect.add(o.report.mean_finish_time());
        }
        assert_eq!(fields[13], trim_float(expect.mean()), "mean_time_used");
        assert_eq!(fields[14], trim_float(expect.std_err()), "stderr_time_used");
        // Engine events are summed across replications.
        let events: u64 = results.outcomes.iter().map(|o| o.report.events).sum();
        assert_eq!(fields[18], events.to_string());
    }
}
