//! Result output: CSV series writers, the textual report writer (the
//! paper's user-defined `ReportWriter` entity, realized post-run), and the
//! long-format/aggregate sweep writers.

pub mod csv;
pub mod report;
pub mod sweep;

pub use csv::CsvWriter;
