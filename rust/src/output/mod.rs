//! Result output: CSV series writers ([`csv`]), the textual report writer
//! ([`report`] — the paper's user-defined `ReportWriter` entity, realized
//! post-run), and the sweep writers ([`sweep`]: long-format + aggregate
//! CSVs and the `sweep_cells.jsonl` checkpoint format behind
//! `repro sweep --resume`).

pub mod csv;
pub mod report;
pub mod sweep;

pub use csv::CsvWriter;
