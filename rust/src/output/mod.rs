//! Result output: CSV series writers and the textual report writer
//! (the paper's user-defined `ReportWriter` entity, realized post-run).

pub mod csv;
pub mod report;

pub use csv::CsvWriter;
