//! `GridSession` — the composable execution API around a scenario.
//!
//! Evaluating brokers "under different scenarios" the way Nimrod/G-style
//! adaptive experimentation does requires pausing a run, probing broker
//! state, and resuming — so the session splits the lifecycle into explicit
//! stages (instead of a fire-and-forget build/run/harvest monolith):
//!
//! 1. **build** — [`GridSession::new`] assembles the entity graph (GIS,
//!    statistics, shutdown, resources, user+broker pairs) with per-user
//!    heterogeneity: each [`UserSpec`](crate::scenario::UserSpec) may
//!    override the scheduling policy (via its experiment), advisor kind and
//!    [`crate::broker::BrokerConfig`] while scenario-level values remain the
//!    defaults;
//! 2. **step/observe** — [`step`](GridSession::step) dispatches one event,
//!    [`run_until`](GridSession::run_until) dispatches everything due by a
//!    time; [`snapshot`](GridSession::snapshot) pulls per-broker progress,
//!    budget spent and per-resource load at any point, and
//!    [`set_observer`](GridSession::set_observer) streams every dispatched
//!    event to a callback;
//! 3. **report** — [`report`](GridSession::report) runs the end phase and
//!    harvests per-user [`UserOutcome`]s, distinguishing finished
//!    experiments from did-not-finish partial accounting (no fabricated
//!    all-zero results).
//!
//! Stepping is free: an incremental `run_until` sweep produces results
//! bit-identical to one [`run_to_completion`](GridSession::run_to_completion)
//! (proven by `rust/tests/session_stepping.rs`).

use crate::broker::policy::make_policy;
use crate::broker::{Broker, BrokerProgress, ExperimentResult, UserEntity};
use crate::des::{EntityId, Event, SimConfig, Simulation};
use crate::faults::FaultInjector;
use crate::gridsim::{
    BaudLink, GridInformationService, GridResource, GridSimShutdown, GridStatistics, Msg,
    ResourceCalendar,
};
use crate::network::FlowLink;
use crate::runtime::{Advisor, AdvisorInput, NativeAdvisor, XlaAdvisor};
use crate::scenario::{AdvisorKind, NetworkSpec, Scenario, ScenarioReport};
use std::sync::{Arc, Mutex};

/// Shared advisor handle: brokers with the same advisor kind reuse one
/// engine instance (one compiled XLA executable compiles once, executes on
/// each scheduling tick). `Arc<Mutex<_>>` rather than `Rc<RefCell<_>>` so a
/// whole session stays `Send` — the sharing is *within* one session, so the
/// lock is never contended.
struct SharedAdvisor {
    inner: Arc<Mutex<dyn Advisor>>,
    label: &'static str,
}

impl Advisor for SharedAdvisor {
    fn advise(&mut self, input: &AdvisorInput) -> Vec<usize> {
        self.inner.lock().expect("advisor lock").advise(input)
    }
    fn name(&self) -> &'static str {
        self.label
    }
}

fn make_shared_advisor(kind: &AdvisorKind) -> anyhow::Result<Arc<Mutex<dyn Advisor>>> {
    Ok(match kind {
        AdvisorKind::Native => Arc::new(Mutex::new(NativeAdvisor::new())),
        AdvisorKind::Xla => Arc::new(Mutex::new(XlaAdvisor::load_default().map_err(|e| {
            e.context(
                "cannot initialize the XLA advisor (run `make artifacts` and build with \
                 `--features xla`)",
            )
        })?)),
    })
}

/// A reusable pool of advisor engines, one per [`AdvisorKind`], for callers
/// that build many sessions in a row (the sweep engine keeps one cache per
/// worker thread). Initializing an engine can be expensive — the XLA advisor
/// loads and compiles a PJRT artifact — so rebuilding it per session turns
/// an `advisor: xla` sweep into one compilation *per cell* instead of one
/// per worker.
///
/// Sharing an engine across sessions is sound because [`Advisor::advise`]
/// is a pure function of its input: engines carry no per-experiment state
/// (the native advisor is a unit struct; the XLA advisor holds only the
/// compiled executable), so cached and fresh engines produce bit-identical
/// schedules — the sweep determinism contract is unaffected.
#[derive(Default)]
pub struct AdvisorCache {
    native: Option<Arc<Mutex<dyn Advisor>>>,
    xla: Option<Arc<Mutex<dyn Advisor>>>,
}

impl AdvisorCache {
    /// An empty cache; engines are created on first use.
    pub fn new() -> AdvisorCache {
        AdvisorCache::default()
    }

    /// Number of engine instances currently cached (observability/tests).
    pub fn len(&self) -> usize {
        usize::from(self.native.is_some()) + usize::from(self.xla.is_some())
    }

    /// True when no engine has been initialized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The cached engine for `kind`, initializing it on first request.
    fn get_or_init(&mut self, kind: &AdvisorKind) -> anyhow::Result<Arc<Mutex<dyn Advisor>>> {
        let slot = match kind {
            AdvisorKind::Native => &mut self.native,
            AdvisorKind::Xla => &mut self.xla,
        };
        if slot.is_none() {
            *slot = Some(make_shared_advisor(kind)?);
        }
        Ok(slot.as_ref().expect("just initialized").clone())
    }
}

/// How one user's experiment ended.
#[derive(Debug, Clone)]
pub enum UserOutcome {
    /// The broker terminated the experiment and reported a result.
    Finished(ExperimentResult),
    /// The run ended (kernel time/event limit) before the experiment
    /// terminated; the payload is the broker's real partial accounting.
    DidNotFinish(ExperimentResult),
}

impl UserOutcome {
    /// Did the broker terminate the experiment itself (as opposed to the
    /// kernel's time/event limit cutting the run short)?
    pub fn is_finished(&self) -> bool {
        matches!(self, UserOutcome::Finished(_))
    }

    /// The result either way — complete or partial.
    pub fn result(&self) -> &ExperimentResult {
        match self {
            UserOutcome::Finished(r) | UserOutcome::DidNotFinish(r) => r,
        }
    }

    /// Consume the outcome into its result — complete or partial.
    pub fn into_result(self) -> ExperimentResult {
        match self {
            UserOutcome::Finished(r) | UserOutcome::DidNotFinish(r) => r,
        }
    }
}

/// Per-user outcomes plus engine-level metrics.
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// One outcome per user, in user order.
    pub outcomes: Vec<UserOutcome>,
    /// Simulation end time.
    pub end_time: f64,
    /// Events dispatched by the kernel.
    pub events: u64,
}

impl SessionReport {
    /// Flatten into the legacy [`ScenarioReport`] shape (did-not-finish
    /// users keep their partial results and are listed in `unfinished`).
    pub fn into_scenario_report(self) -> ScenarioReport {
        let mut unfinished = Vec::new();
        let users = self
            .outcomes
            .into_iter()
            .enumerate()
            .map(|(i, outcome)| {
                if !outcome.is_finished() {
                    unfinished.push(i);
                }
                outcome.into_result()
            })
            .collect();
        ScenarioReport { users, unfinished, end_time: self.end_time, events: self.events }
    }
}

/// Pull-based view of the whole session at one instant.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    /// Simulation clock at snapshot time.
    pub time: f64,
    /// Events dispatched so far.
    pub events: u64,
    /// Per-user broker progress, in user order.
    pub users: Vec<BrokerProgress>,
}

/// A live simulation of one [`Scenario`]: build once, then step, observe
/// and finally report. See the module docs for the lifecycle.
///
/// Sessions are `Send` (asserted below): the sweep engine hands whole
/// sessions to worker threads, and embedders can run sessions on background
/// threads.
pub struct GridSession {
    sim: Simulation<Msg>,
    user_ids: Vec<EntityId>,
    broker_ids: Vec<EntityId>,
}

// Compile-time proof that the full session stack (kernel, entities, broker
// policies, advisors, link model) is `Send`.
#[allow(dead_code)]
fn _assert_session_send(session: GridSession) -> impl Send {
    session
}

impl GridSession {
    /// Assemble the entity graph for `scenario`. Entity ids, names and
    /// per-user seeds match the historical layout, so sessions reproduce
    /// pre-session runs bit-for-bit.
    ///
    /// Panics when an advisor engine cannot be initialized (e.g. the XLA
    /// artifact is missing); use [`try_new`](Self::try_new) to surface that
    /// as an error instead.
    pub fn new(scenario: &Scenario) -> GridSession {
        Self::try_new(scenario).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible variant of [`new`](Self::new): advisor initialization
    /// failures become an `Err` rather than a panic.
    pub fn try_new(scenario: &Scenario) -> anyhow::Result<GridSession> {
        Self::try_new_cached(scenario, &mut AdvisorCache::new())
    }

    /// [`try_new`](Self::try_new) drawing advisor engines from `advisors`
    /// instead of building fresh ones: engines already in the cache are
    /// reused, missing ones are initialized and left in the cache for the
    /// next session. The sweep engine holds one cache per worker thread, so
    /// cells sharing an advisor config share one engine instance per worker
    /// (see [`AdvisorCache`] for why this cannot change results).
    pub fn try_new_cached(
        scenario: &Scenario,
        advisors: &mut AdvisorCache,
    ) -> anyhow::Result<GridSession> {
        let mut sim: Simulation<Msg> = Simulation::with_config(SimConfig {
            max_time: scenario.max_time,
            max_events: u64::MAX,
        });

        let gis = sim.add(Box::new(GridInformationService::new("GIS")));
        let stats = sim.add(Box::new(GridStatistics::new("GridStatistics")));
        let shutdown =
            sim.add(Box::new(GridSimShutdown::new("GridSimShutdown", scenario.users.len())));

        // Market layer: validated up front; resources without a pricing or
        // spot entry are constructed exactly as before (no market state, no
        // PRICE_UPDATE traffic), so no-market scenarios stay bit-identical.
        if let Some(market) = &scenario.market {
            if let Err(e) = market.validate() {
                anyhow::bail!("invalid market spec: {e}");
            }
        }

        let mut resource_ids = Vec::with_capacity(scenario.resources.len());
        for spec in &scenario.resources {
            let calendar = spec.calendar.clone().unwrap_or_else(ResourceCalendar::no_load);
            let mut resource =
                GridResource::new(spec.name.clone(), spec.characteristics(), calendar, gis)
                    .with_stats(stats);
            if let Some((model, discount)) = scenario
                .market
                .as_ref()
                .and_then(|m| m.config_for(&spec.name, spec.price))
            {
                resource = resource.with_market(model, discount);
            }
            resource_ids.push(sim.add(Box::new(resource)));
        }

        // One shared engine instance per advisor kind actually in use,
        // drawn from (and left in) the caller's cache.
        let mut user_ids = Vec::with_capacity(scenario.users.len());
        let mut broker_ids = Vec::with_capacity(scenario.users.len());
        for (i, user) in scenario.users.iter().enumerate() {
            let kind = user.advisor.as_ref().unwrap_or(&scenario.advisor);
            let label = match kind {
                AdvisorKind::Native => "native",
                AdvisorKind::Xla => "xla",
            };
            let advisor = Box::new(SharedAdvisor { inner: advisors.get_or_init(kind)?, label });
            let policy = make_policy(user.experiment.optimization, advisor);
            let config = user.broker.clone().unwrap_or_else(|| scenario.broker_config.clone());
            let mut broker = Broker::new(format!("Broker_{i}"), gis, policy, config);
            if let Some(market) = &scenario.market {
                broker = broker.with_market(market.spot.clone(), user.max_spot_price);
            }
            let broker_id = sim.add(Box::new(broker));
            broker_ids.push(broker_id);
            // Paper Fig 15 per-user seed derivation: seed·997·(1+i)+1.
            let user_seed = scenario
                .seed
                .wrapping_mul(997)
                .wrapping_mul(1 + i as u64)
                .wrapping_add(1);
            let mut entity = UserEntity::new(
                format!("U{i}"),
                broker_id,
                shutdown,
                user.experiment.clone(),
                user_seed,
            )
            .with_stats(stats);
            if user.submit_delay > 0.0 {
                entity = entity.with_submit_delay(user.submit_delay);
            }
            user_ids.push(sim.add(Box::new(entity)));
        }

        // The fault injector is appended *after* the historical entity
        // layout (and only when the scenario asks for faults), so scenarios
        // without a faults spec keep bit-identical entity ids and event
        // streams.
        if let Some(faults) = &scenario.faults {
            if let Err(e) = faults.validate() {
                anyhow::bail!("invalid faults spec: {e}");
            }
            let resources: Vec<(EntityId, String)> = resource_ids
                .iter()
                .zip(&scenario.resources)
                .map(|(id, spec)| (*id, spec.name.clone()))
                .collect();
            sim.add(Box::new(FaultInjector::new(faults, &resources, scenario.seed)));
        }

        // The link model is installed after entity assembly so per-entity
        // overrides (named flow capacities, per-user link rates) resolve
        // against the final entity table; nothing consults the model before
        // the first dispatch, so late installation cannot change results.
        Self::install_link_model(&mut sim, scenario, &user_ids, &broker_ids)?;

        Ok(GridSession { sim, user_ids, broker_ids })
    }

    /// Build the scenario's link model and install it: `BaudLink` for the
    /// scalar specs, [`FlowLink`] for [`NetworkSpec::Flow`]. Per-user
    /// [`link_rate`](crate::scenario::UserSpec::link_rate) overrides apply
    /// to both the user entity and its broker (the user's "site"); flow
    /// capacity overrides are resolved from entity names here.
    fn install_link_model(
        sim: &mut Simulation<Msg>,
        scenario: &Scenario,
        user_ids: &[EntityId],
        broker_ids: &[EntityId],
    ) -> anyhow::Result<()> {
        let site_rates = |users: &[crate::scenario::UserSpec]| {
            users
                .iter()
                .enumerate()
                .filter_map(|(i, u)| u.link_rate.map(|r| (user_ids[i], broker_ids[i], r)))
                .collect::<Vec<_>>()
        };
        match &scenario.network {
            NetworkSpec::Instantaneous => {
                // Per-user rates still apply: that user's site link is
                // finite while the rest of the grid stays zero-delay.
                let mut link = BaudLink::instantaneous();
                for (user, broker, rate) in site_rates(&scenario.users) {
                    link.set_rate(user, rate);
                    link.set_rate(broker, rate);
                }
                sim.set_link_model(Box::new(link));
            }
            NetworkSpec::Baud { default_rate, latency } => {
                let mut link = BaudLink::new()
                    .with_default_rate(*default_rate)
                    .with_default_latency(*latency);
                for (user, broker, rate) in site_rates(&scenario.users) {
                    link.set_rate(user, rate);
                    link.set_rate(broker, rate);
                }
                sim.set_link_model(Box::new(link));
            }
            NetworkSpec::Flow { default_capacity, latency, capacities } => {
                let mut link = FlowLink::new(*default_capacity, *latency);
                for (name, cap) in capacities {
                    let id = sim.lookup(name).ok_or_else(|| {
                        let known = (0..sim.entity_count())
                            .map(|e| sim.name_of(e))
                            .collect::<Vec<_>>()
                            .join(", ");
                        anyhow::anyhow!(
                            "network capacities: unknown entity {name:?} (known entities: {known})"
                        )
                    })?;
                    link.set_capacity(id, *cap);
                }
                for (user, broker, rate) in site_rates(&scenario.users) {
                    link.set_capacity(user, rate);
                    link.set_capacity(broker, rate);
                }
                sim.set_link_model(Box::new(link));
            }
        }
        Ok(())
    }

    /// Run the start phase (idempotent; stepping calls it implicitly).
    pub fn init(&mut self) {
        self.sim.init();
    }

    /// Dispatch exactly one event; `None` when the session is idle.
    pub fn step(&mut self) -> Option<f64> {
        self.sim.step()
    }

    /// Dispatch every event due at or before `t`; returns the clock.
    pub fn run_until(&mut self, t: f64) -> f64 {
        self.sim.run_until(t)
    }

    /// True when no further event can be dispatched. A session whose start
    /// phase has not run yet is not idle, so `while !is_idle()` loops work
    /// without an explicit [`init`](Self::init).
    pub fn is_idle(&self) -> bool {
        self.sim.is_idle()
    }

    /// Current simulation clock.
    pub fn clock(&self) -> f64 {
        self.sim.clock()
    }

    /// Events dispatched so far.
    pub fn events_processed(&self) -> u64 {
        self.sim.events_processed()
    }

    /// Timestamp of the next pending event, if any.
    pub fn next_event_time(&self) -> Option<f64> {
        self.sim.next_event_time()
    }

    /// Entity name lookup (for interpreting observer events).
    pub fn entity_name(&self, id: EntityId) -> &str {
        self.sim.name_of(id)
    }

    /// Stream every dispatched event to `observer` (called after the clock
    /// advances, before the destination entity handles the event). The
    /// observer is `Send` so an observing session remains movable across
    /// threads.
    pub fn set_observer(&mut self, observer: Box<dyn FnMut(&Event<Msg>) + Send>) {
        self.sim.set_observer(observer);
    }

    /// Remove the installed observer.
    pub fn clear_observer(&mut self) {
        self.sim.take_observer();
    }

    /// Pull-based progress snapshot: per-broker state, completion counts,
    /// budget spent and per-resource load — valid at any point of the run.
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            time: self.sim.clock(),
            events: self.sim.events_processed(),
            users: self
                .broker_ids
                .iter()
                .map(|&id| self.sim.get::<Broker>(id).expect("broker entity").progress())
                .collect(),
        }
    }

    /// Run the end phase (idempotent) and harvest per-user outcomes.
    ///
    /// A user whose experiment terminated yields
    /// [`UserOutcome::Finished`] — taken from the user entity, or from the
    /// broker when the final report message was still in flight. Otherwise
    /// the outcome is [`UserOutcome::DidNotFinish`] carrying the broker's
    /// real partial accounting.
    pub fn report(&mut self) -> SessionReport {
        let end_time = self.sim.finalize();
        let outcomes = self
            .user_ids
            .iter()
            .zip(&self.broker_ids)
            .map(|(&uid, &bid)| {
                if let Some(r) =
                    self.sim.get::<UserEntity>(uid).and_then(|u| u.result.clone())
                {
                    return UserOutcome::Finished(r);
                }
                let broker = self.sim.get::<Broker>(bid).expect("broker entity");
                match &broker.result {
                    Some(r) => UserOutcome::Finished(r.clone()),
                    None => UserOutcome::DidNotFinish(broker.partial_result(end_time)),
                }
            })
            .collect();
        SessionReport { outcomes, end_time, events: self.sim.events_processed() }
    }

    /// Drive the session until idle and return the legacy-shaped report.
    pub fn run_to_completion(&mut self) -> ScenarioReport {
        self.sim.run();
        self.report().into_scenario_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, ExperimentSpec, Optimization};
    use crate::gridsim::AllocPolicy;
    use crate::scenario::{ResourceSpec, UserSpec};

    fn small_resource(name: &str, pes: usize, mips: f64, price: f64) -> ResourceSpec {
        ResourceSpec {
            name: name.into(),
            arch: "test".into(),
            os: "linux".into(),
            machines: 1,
            pes_per_machine: pes,
            mips_per_pe: mips,
            policy: AllocPolicy::TimeShared,
            price,
            time_zone: 0.0,
            calendar: None,
        }
    }

    fn two_user_scenario() -> Scenario {
        Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .resource(small_resource("R1", 2, 100.0, 2.0))
            .user(
                ExperimentSpec::task_farm(12, 1_000.0, 0.10)
                    .deadline(2_000.0)
                    .budget(1e6)
                    .optimization(Optimization::Cost),
            )
            .user(
                UserSpec::new(
                    ExperimentSpec::task_farm(8, 1_000.0, 0.10)
                        .deadline(2_000.0)
                        .budget(1e6)
                        .optimization(Optimization::Time),
                )
                .broker(BrokerConfig { max_gridlets_per_pe: 1, ..BrokerConfig::default() }),
            )
            .seed(11)
            .build()
    }

    #[test]
    fn stepped_run_until_is_bit_identical() {
        let baseline = GridSession::new(&two_user_scenario()).run_to_completion();

        let mut session = GridSession::new(&two_user_scenario());
        session.init();
        let mut t = 0.0;
        while !session.is_idle() {
            t += 13.7;
            session.run_until(t);
        }
        let stepped = session.report().into_scenario_report();

        assert_eq!(baseline.end_time.to_bits(), stepped.end_time.to_bits());
        assert_eq!(baseline.events, stepped.events);
        assert_eq!(baseline.users.len(), stepped.users.len());
        for (a, b) in baseline.users.iter().zip(&stepped.users) {
            assert_eq!(a.gridlets_completed, b.gridlets_completed);
            assert_eq!(a.budget_spent.to_bits(), b.budget_spent.to_bits());
            assert_eq!(a.finish_time.to_bits(), b.finish_time.to_bits());
        }
    }

    #[test]
    fn snapshot_observes_progress_mid_run() {
        let mut session = GridSession::new(&two_user_scenario());
        session.init();
        let before = session.snapshot();
        assert_eq!(before.users.len(), 2);

        // Drive halfway and probe.
        let mut saw_active = false;
        while !session.is_idle() && session.clock() < 100.0 {
            session.step();
            let snap = session.snapshot();
            if snap.users.iter().any(|u| u.state == "scheduling") {
                saw_active = true;
            }
        }
        assert!(saw_active, "brokers visible mid-lifecycle");

        let report = session.run_to_completion();
        assert!(report.all_finished());
        let final_snap = session.snapshot();
        assert!(final_snap.users.iter().all(|u| u.state == "done"));
        assert_eq!(final_snap.users[0].gridlets_completed, 12);
        assert_eq!(final_snap.users[1].gridlets_completed, 8);
    }

    #[test]
    fn observer_counts_every_event() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let count = Arc::new(AtomicU64::new(0));
        let sink = count.clone();
        let mut session = GridSession::new(&two_user_scenario());
        session.set_observer(Box::new(move |_ev| {
            sink.fetch_add(1, Ordering::Relaxed);
        }));
        let report = session.run_to_completion();
        assert_eq!(count.load(Ordering::Relaxed), report.events);
    }

    #[test]
    fn truncated_run_reports_did_not_finish_with_real_accounting() {
        let mut scenario = two_user_scenario();
        scenario.max_time = 15.0; // far too short to finish
        let mut session = GridSession::new(&scenario);
        while session.step().is_some() {}
        let report = session.report();
        assert!(report.outcomes.iter().any(|o| !o.is_finished()), "run was truncated");
        for outcome in &report.outcomes {
            let r = outcome.result();
            // The partial result carries the real experiment size, not the
            // old fabricated all-zero placeholder.
            assert!(r.gridlets_total > 0, "partial keeps real totals");
            assert!(r.gridlets_completed <= r.gridlets_total);
        }
        let legacy = report.clone().into_scenario_report();
        assert!(!legacy.all_finished());
        assert!(!legacy.unfinished.is_empty());
    }

    #[test]
    fn fresh_session_is_not_idle() {
        // Without an explicit init(), an is_idle-driven loop still runs:
        // the pending start phase means the session is not idle yet.
        let mut session = GridSession::new(&two_user_scenario());
        assert!(!session.is_idle());
        let mut horizon = 0.0;
        while !session.is_idle() {
            horizon += 50.0;
            session.run_until(horizon);
        }
        let report = session.report().into_scenario_report();
        assert!(report.all_finished());
    }

    #[test]
    fn advisor_cache_reuses_engines_without_changing_results() {
        let scenario = two_user_scenario();
        let baseline = GridSession::new(&scenario).run_to_completion();
        let mut cache = AdvisorCache::new();
        assert!(cache.is_empty());
        let first =
            GridSession::try_new_cached(&scenario, &mut cache).unwrap().run_to_completion();
        assert_eq!(cache.len(), 1, "one native engine initialized on first use");
        let second =
            GridSession::try_new_cached(&scenario, &mut cache).unwrap().run_to_completion();
        assert_eq!(cache.len(), 1, "the second session reused it");
        for r in [&first, &second] {
            assert_eq!(r.events, baseline.events);
            assert_eq!(r.end_time.to_bits(), baseline.end_time.to_bits());
            for (a, b) in r.users.iter().zip(&baseline.users) {
                assert_eq!(a.gridlets_completed, b.gridlets_completed);
                assert_eq!(a.budget_spent.to_bits(), b.budget_spent.to_bits());
            }
        }
    }

    #[test]
    fn per_user_advisor_override_builds() {
        // Both users explicitly request the native advisor; the scenario
        // default is also native — exercise the override plumbing.
        let scenario = Scenario::builder()
            .resource(small_resource("R0", 2, 100.0, 1.0))
            .user(
                UserSpec::new(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
                    .advisor(AdvisorKind::Native),
            )
            .user(ExperimentSpec::task_farm(4, 500.0, 0.0).deadline(1e4).budget(1e6))
            .seed(3)
            .build();
        let report = GridSession::new(&scenario).run_to_completion();
        assert!(report.all_finished());
        assert_eq!(report.users[0].gridlets_completed, 4);
        assert_eq!(report.users[1].gridlets_completed, 4);
    }
}
