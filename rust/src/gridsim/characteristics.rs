//! `gridsim.ResourceCharacteristics` — static resource properties
//! (paper §3.6): architecture, OS, machine list, allocation policy, cost and
//! time zone.

use super::machine::MachineList;

/// Queue ordering policy for space-shared resources (paper §3.5: "FCFS,
/// back filling, shortest-job-first served (SJFS), and so on").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpacePolicy {
    /// First-come first-served.
    Fcfs,
    /// Shortest job (smallest MI) first.
    Sjf,
    /// FCFS with EASY backfilling: the head job reserves PEs at the earliest
    /// time enough become free; later jobs may jump ahead if they would not
    /// delay the reservation.
    BackfillEasy,
}

/// Internal process scheduling policy of the resource manager.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Round-robin multitasking: all Gridlets run at once and share PEs
    /// (single machine / SMP under a time-shared OS).
    TimeShared,
    /// Queueing system: each Gridlet gets dedicated PEs (clusters).
    SpaceShared(SpacePolicy),
}

impl AllocPolicy {
    /// `true` for [`AllocPolicy::TimeShared`] (Table 2's "manager" column).
    pub fn is_time_shared(&self) -> bool {
        matches!(self, AllocPolicy::TimeShared)
    }
}

/// Static properties of a grid resource.
#[derive(Debug, Clone)]
pub struct ResourceCharacteristics {
    /// Architecture label, e.g. "Sun Ultra" (informational).
    pub arch: String,
    /// OS label (informational).
    pub os: String,
    /// The machines making up this resource.
    pub machines: MachineList,
    /// Allocation policy.
    pub policy: AllocPolicy,
    /// Price in G$ per PE per simulation time unit (Table 2 "Price").
    pub cost_per_pe_time: f64,
    /// Time zone offset in hours (paper: resources can be located in any
    /// time zone; drives the local-load calendar).
    pub time_zone: f64,
}

impl ResourceCharacteristics {
    /// Build the characteristics record; panics on an empty machine list or
    /// a negative price.
    pub fn new(
        arch: impl Into<String>,
        os: impl Into<String>,
        machines: MachineList,
        policy: AllocPolicy,
        cost_per_pe_time: f64,
        time_zone: f64,
    ) -> ResourceCharacteristics {
        assert!(!machines.is_empty(), "resource needs at least one machine");
        assert!(cost_per_pe_time >= 0.0);
        ResourceCharacteristics {
            arch: arch.into(),
            os: os.into(),
            machines,
            policy,
            cost_per_pe_time,
            time_zone,
        }
    }

    /// Total number of PEs.
    pub fn num_pe(&self) -> usize {
        self.machines.num_pe()
    }

    /// MIPS rating of a single PE (homogeneous within a resource).
    pub fn mips_per_pe(&self) -> f64 {
        self.machines.mips_of_one_pe()
    }

    /// Aggregate MIPS.
    pub fn total_mips(&self) -> f64 {
        self.machines.total_mips()
    }

    /// Cost of processing one MI on this resource, used by brokers to rank
    /// resources (the paper's "translate G$/PE-time into G$ per MI"):
    /// `price / MIPS`.
    pub fn cost_per_mi(&self) -> f64 {
        self.cost_per_pe_time / self.mips_per_pe()
    }

    /// MIPS bought per G$ (Table 2 last column).
    pub fn mips_per_dollar(&self) -> f64 {
        if self.cost_per_pe_time == 0.0 {
            f64::INFINITY
        } else {
            self.mips_per_pe() / self.cost_per_pe_time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn char_for(pes: usize, mips: f64, price: f64) -> ResourceCharacteristics {
        ResourceCharacteristics::new(
            "test",
            "linux",
            MachineList::cluster(1, pes, mips),
            AllocPolicy::TimeShared,
            price,
            0.0,
        )
    }

    #[test]
    fn table2_row_r0() {
        // R0: Compaq AlphaServer, 4 PEs, 515 SPEC, 8 G$/PE-time → 64.37 MIPS/G$.
        let c = char_for(4, 515.0, 8.0);
        assert_eq!(c.num_pe(), 4);
        assert!((c.mips_per_dollar() - 64.375).abs() < 1e-9);
        assert!((c.cost_per_mi() - 8.0 / 515.0).abs() < 1e-12);
    }

    #[test]
    fn table2_row_r8_cheapest_per_mi() {
        // R8: Intel VC820, 380 SPEC, 1 G$ → 380 MIPS/G$, cheapest in Table 2.
        let r8 = char_for(2, 380.0, 1.0);
        let r0 = char_for(4, 515.0, 8.0);
        assert!(r8.cost_per_mi() < r0.cost_per_mi());
        assert!((r8.mips_per_dollar() - 380.0).abs() < 1e-9);
    }

    #[test]
    fn free_resource_infinite_value() {
        let c = char_for(1, 100.0, 0.0);
        assert!(c.mips_per_dollar().is_infinite());
        assert_eq!(c.cost_per_mi(), 0.0);
    }

    #[test]
    fn policy_predicates() {
        assert!(AllocPolicy::TimeShared.is_time_shared());
        assert!(!AllocPolicy::SpaceShared(SpacePolicy::Fcfs).is_time_shared());
    }
}
