//! `gridsim.GridSimRandom` — mapping predicted values to "real-world" values
//! with bounded uncertainty (paper §3.6).
//!
//! `real(d, f_L, f_M)` maps an estimate `d` into
//! `[(1 − f_L)·d, (1 + f_M)·d)` via `d·(1 − f_L + (f_L + f_M)·rd)` where
//! `rd ~ U[0, 1)` — exactly the paper's formula.

use crate::util::rng::Rng;

/// Stateful randomizer with per-situation factor presets.
#[derive(Debug, Clone)]
pub struct GridSimRandom {
    rng: Rng,
    /// Less/more factors for network staging estimates.
    pub net_factors: (f64, f64),
    /// Less/more factors for job-length estimates.
    pub exec_factors: (f64, f64),
}

impl GridSimRandom {
    /// A randomizer seeded deterministically, with zero uncertainty factors.
    pub fn new(seed: u64) -> GridSimRandom {
        GridSimRandom { rng: Rng::new(seed), net_factors: (0.0, 0.0), exec_factors: (0.0, 0.0) }
    }

    /// The paper's `real(d, f_L, f_M)`.
    pub fn real(&mut self, d: f64, f_less: f64, f_more: f64) -> f64 {
        assert!((0.0..=1.0).contains(&f_less), "f_L must be in [0,1]");
        assert!((0.0..=1.0).contains(&f_more), "f_M must be in [0,1]");
        let rd = self.rng.next_f64();
        d * (1.0 - f_less + (f_less + f_more) * rd)
    }

    /// `real` with the execution-factor preset.
    pub fn real_exec(&mut self, d: f64) -> f64 {
        let (fl, fm) = self.exec_factors;
        self.real(d, fl, fm)
    }

    /// `real` with the network-factor preset.
    pub fn real_net(&mut self, d: f64) -> f64 {
        let (fl, fm) = self.net_factors;
        self.real(d, fl, fm)
    }

    /// Access the underlying uniform stream (for modelers needing raw draws).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_within_bounds() {
        let mut r = GridSimRandom::new(1);
        for _ in 0..10_000 {
            let x = r.real(100.0, 0.1, 0.25);
            assert!(x >= 90.0 - 1e-9, "{x}");
            assert!(x < 125.0, "{x}");
        }
    }

    #[test]
    fn zero_factors_identity() {
        let mut r = GridSimRandom::new(2);
        for _ in 0..100 {
            assert_eq!(r.real(42.0, 0.0, 0.0), 42.0);
        }
    }

    #[test]
    fn positive_only_variation_matches_paper_workload() {
        // §5.2: "at least 10,000 MI with a random variation of 0 to 10% on
        // the positive side" → real(10_000, 0, 0.10).
        let mut r = GridSimRandom::new(3);
        for _ in 0..10_000 {
            let x = r.real(10_000.0, 0.0, 0.10);
            assert!((10_000.0..11_000.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn deterministic_stream() {
        let mut a = GridSimRandom::new(7);
        let mut b = GridSimRandom::new(7);
        for _ in 0..50 {
            assert_eq!(a.real(5.0, 0.2, 0.2), b.real(5.0, 0.2, 0.2));
        }
    }

    #[test]
    #[should_panic(expected = "f_L")]
    fn rejects_bad_factor() {
        GridSimRandom::new(0).real(1.0, 1.5, 0.0);
    }
}
