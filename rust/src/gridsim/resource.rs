//! `gridsim.GridResource` — the resource entity (paper §3.5/§3.6).
//!
//! Wraps a local scheduler (time- or space-shared) in the event protocol of
//! Figs 5/6: register with the GIS at start, answer characteristics/dynamics
//! queries, accept Gridlet submissions, run the internal completion-
//! interrupt loop (with the stale-tag discard rule of Figs 7/10), and return
//! processed Gridlets to their owners.

use super::calendar::ResourceCalendar;
use super::characteristics::{AllocPolicy, ResourceCharacteristics};
use super::gridlet::{Gridlet, GridletStatus};
use super::messages::{Msg, ReservationReply, ResourceDynamics, ResourceInfo};
use super::pool;
use super::res_gridlet::ResGridlet;
use super::reservation::ReservationBook;
use super::space_shared::SpaceShared;
use super::statistics::StatRecord;
use super::tags;
use super::time_shared::TimeShared;
use crate::des::{Ctx, EntityId, Event};
use crate::market::{PriceModel, PricingModel};
use std::collections::HashMap;
use std::sync::Arc;

/// The policy-specific half of a resource: how Gridlets are multiplexed onto
/// PEs. Implemented by [`TimeShared`] (Fig 7/8) and [`SpaceShared`]
/// (Fig 10/11).
pub trait LocalScheduler: std::fmt::Debug + Send {
    /// Update the background-load availability factor (1 − local load).
    fn set_availability(&mut self, factor: f64, now: f64);
    /// Withhold PEs from grid work (active advance reservations).
    fn set_withheld_pes(&mut self, pes: usize, now: f64);
    /// A Gridlet arrived for execution.
    fn submit(&mut self, rg: ResGridlet, now: f64);
    /// Advance to `now`; return Gridlets that completed.
    fn collect(&mut self, now: f64) -> Vec<ResGridlet>;
    /// Earliest forecast completion time, if any work is in flight.
    fn next_completion(&mut self, now: f64) -> Option<f64>;
    /// Gridlets currently executing.
    fn in_exec(&self) -> usize;
    /// Gridlets waiting in the submission queue.
    fn queued(&self) -> usize;
    /// Cancel a Gridlet by id (queued or running).
    fn cancel(&mut self, gridlet_id: usize, now: f64) -> Option<ResGridlet>;
    /// Cancel a Gridlet by `(owner, id)`. Gridlet ids are user-scoped, so
    /// two users' jobs on one resource can share an id — spot preemption
    /// uses this to evict exactly the bid-carrying job.
    fn cancel_owned(&mut self, owner: EntityId, gridlet_id: usize, now: f64)
        -> Option<ResGridlet>;
    /// Status of a Gridlet currently held by the scheduler.
    fn status_of(&self, gridlet_id: usize) -> Option<GridletStatus>;
    /// Flush everything in flight as [`GridletStatus::Lost`] (the resource
    /// failed under the jobs — failure injection).
    fn drain(&mut self, now: f64) -> Vec<ResGridlet>;
}

/// Residency mark for one Gridlet under a market: where the price integral
/// stood when it arrived, and the spot bid it carried (NaN = on-demand).
#[derive(Debug, Clone, Copy)]
struct ResidencyMark {
    /// Price integral `∫ price dt` at arrival.
    acc0: f64,
    /// Arrival time.
    t0: f64,
    /// Price-change counter at arrival.
    changes0: u64,
    /// The job's spot bid (`Gridlet::max_spot_price`; NaN for on-demand).
    bid: f64,
}

/// Dynamic-pricing state of one resource (attached by
/// [`GridResource::with_market`]; absent on static-price resources, which
/// then emit no market events at all).
#[derive(Debug)]
struct MarketState {
    /// The pricing model driving the posted price.
    model: PriceModel,
    /// Spot-tier discount in `(0, 1]`, if this resource rents a spot tier.
    spot_discount: Option<f64>,
    /// Price currently in effect.
    current_price: f64,
    /// Brokers that queried characteristics — they receive `PRICE_UPDATE`.
    subscribers: Vec<EntityId>,
    /// Lazy `∫ price dt`, settled on every price change.
    acc: f64,
    /// Time `acc` was last settled.
    last_update: f64,
    /// Price-change counter. When it is unchanged across a residency the
    /// time-averaged price *is* the current price — reported exactly, with
    /// no division, so the `Static` model reproduces the pre-market
    /// `price × cpu_time` arithmetic bit for bit.
    changes: u64,
    /// Residency marks keyed by `(owner, id)` (ids are user-scoped).
    marks: HashMap<(EntityId, usize), ResidencyMark>,
}

/// The resource entity.
pub struct GridResource {
    name: Arc<str>,
    /// Precomputed `"<name>.GridletCompletion"` statistics category, shared
    /// by every completion record instead of formatted per Gridlet.
    stat_category: Arc<str>,
    characteristics: ResourceCharacteristics,
    calendar: ResourceCalendar,
    scheduler: Box<dyn LocalScheduler>,
    gis: EntityId,
    /// Optional statistics sink.
    stats: Option<EntityId>,
    /// Sequence number of the most recently scheduled internal tick; stale
    /// interrupts (Figs 7/10) are discarded by comparing against this.
    last_tick: Option<u64>,
    /// Arrival counter (rank for the time-shared share allocator).
    arrivals: u64,
    /// Failure-injection state.
    failed: bool,
    /// Advance reservations (paper §3.1 / §6).
    reservations: ReservationBook,
    /// Market layer: dynamic pricing + spot tier (None = static price).
    market: Option<MarketState>,
    /// Gridlets processed in total (metrics).
    pub completed: u64,
}

impl GridResource {
    /// Build a resource entity from its characteristics. The scheduler kind
    /// follows `characteristics.policy`.
    pub fn new(
        name: impl Into<Arc<str>>,
        characteristics: ResourceCharacteristics,
        calendar: ResourceCalendar,
        gis: EntityId,
    ) -> GridResource {
        let scheduler: Box<dyn LocalScheduler> = match characteristics.policy {
            AllocPolicy::TimeShared => Box::new(TimeShared::new(
                characteristics.num_pe(),
                characteristics.mips_per_pe(),
            )),
            AllocPolicy::SpaceShared(policy) => {
                let machine_pes: Vec<usize> =
                    characteristics.machines.iter().map(|m| m.num_pe()).collect();
                Box::new(SpaceShared::new(
                    &machine_pes,
                    characteristics.mips_per_pe(),
                    policy,
                ))
            }
        };
        let num_pe = characteristics.num_pe();
        let name = name.into();
        GridResource {
            stat_category: format!("{name}.GridletCompletion").into(),
            name,
            characteristics,
            calendar,
            scheduler,
            gis,
            stats: None,
            last_tick: None,
            arrivals: 0,
            failed: false,
            reservations: ReservationBook::new(num_pe),
            market: None,
            completed: 0,
        }
    }

    /// Send Gridlet completion records to this statistics entity.
    pub fn with_stats(mut self, stats: EntityId) -> GridResource {
        self.stats = Some(stats);
        self
    }

    /// Attach the market layer: a dynamic pricing model and, optionally, a
    /// spot-tier discount. Without this call the resource never publishes
    /// `PRICE_UPDATE` events and behaves byte-identically to the
    /// static-price toolkit.
    pub fn with_market(mut self, model: PriceModel, spot_discount: Option<f64>) -> GridResource {
        let current_price = model.price_at(0.0, 0.0);
        self.market = Some(MarketState {
            model,
            spot_discount,
            current_price,
            subscribers: Vec::new(),
            acc: 0.0,
            last_update: 0.0,
            changes: 0,
            marks: HashMap::new(),
        });
        self
    }

    /// The static characteristics record this resource registers with the
    /// GIS and returns to `RESOURCE_CHARACTERISTICS` queries.
    pub fn info(&self, id: EntityId) -> ResourceInfo {
        ResourceInfo {
            id,
            name: self.name.clone(),
            num_pe: self.characteristics.num_pe(),
            mips_per_pe: self.characteristics.mips_per_pe(),
            cost_per_pe_time: self.characteristics.cost_per_pe_time,
            time_shared: self.characteristics.policy.is_time_shared(),
            time_zone: self.characteristics.time_zone,
        }
    }

    /// The resource's static properties.
    pub fn characteristics(&self) -> &ResourceCharacteristics {
        &self.characteristics
    }

    /// Refresh calendar-driven availability and reservation withholding.
    fn refresh_environment(&mut self, now: f64) {
        self.scheduler.set_availability(self.calendar.availability(now), now);
        let reserved = self.reservations.active_pes(now);
        self.scheduler.set_withheld_pes(reserved, now);
    }

    /// (Re)schedule the internal completion interrupt at the earliest
    /// forecast finish (Fig 7 step 2d / Fig 10).
    fn reschedule_tick(&mut self, ctx: &mut Ctx<Msg>) {
        if let Some(t) = self.scheduler.next_completion(ctx.now()) {
            let delay = (t - ctx.now()).max(0.0);
            self.last_tick = Some(ctx.schedule_self(delay, tags::RESOURCE_TICK, None));
        } else {
            self.last_tick = None;
        }
    }

    /// Fraction of PEs busy or committed, in `[0, 1]` — the demand signal
    /// driving utilization-priced markets.
    fn utilization(&self) -> f64 {
        let busy = self.scheduler.in_exec() + self.scheduler.queued();
        (busy as f64 / self.characteristics.num_pe() as f64).min(1.0)
    }

    /// Record a residency mark for an arriving Gridlet (market runs only).
    fn mark_arrival(&mut self, owner: EntityId, id: usize, bid: f64, now: f64) {
        if let Some(m) = self.market.as_mut() {
            let acc0 = m.acc + m.current_price * (now - m.last_update);
            m.marks.insert((owner, id), ResidencyMark { acc0, t0: now, changes0: m.changes, bid });
        }
    }

    /// Stamp `paid_rate` on a departing Gridlet: the time-averaged price
    /// over its residency, spot-discounted for bid-carrying jobs. Consumes
    /// the residency mark (a second call is a no-op).
    fn settle_market(&mut self, g: &mut Gridlet, now: f64) {
        let Some(m) = self.market.as_mut() else { return };
        let Some(mark) = m.marks.remove(&(g.owner, g.id)) else { return };
        let avg = if m.changes == mark.changes0 {
            // The price never moved during the residency: the average *is*
            // the current price, reported exactly (no division).
            m.current_price
        } else {
            let dt = now - mark.t0;
            if dt > 0.0 {
                let acc_now = m.acc + m.current_price * (now - m.last_update);
                (acc_now - mark.acc0) / dt
            } else {
                m.current_price
            }
        };
        g.paid_rate = match m.spot_discount {
            Some(d) if mark.bid.is_finite() => d * avg,
            _ => avg,
        };
    }

    /// Recompute the utilization-driven price. On a change: settle the
    /// price integral, publish `PRICE_UPDATE` to every subscribed broker,
    /// and preempt resident spot jobs whose bid the new discounted price
    /// crossed (in sorted `(owner, id)` order, for determinism).
    fn update_market(&mut self, ctx: &mut Ctx<Msg>) {
        if self.market.is_none() {
            return;
        }
        let util = self.utilization();
        let now = ctx.now();
        let victims = {
            let m = self.market.as_mut().unwrap();
            let p = m.model.price_at(util, now);
            if p == m.current_price {
                return;
            }
            m.acc += m.current_price * (now - m.last_update);
            m.last_update = now;
            m.current_price = p;
            m.changes += 1;
            for &dst in &m.subscribers {
                ctx.send(dst, tags::PRICE_UPDATE, Some(Msg::Price(p)), 16);
            }
            match m.spot_discount {
                Some(d) => {
                    let spot_price = d * p;
                    let mut v: Vec<(EntityId, usize)> = m
                        .marks
                        .iter()
                        .filter(|(_, mark)| mark.bid.is_finite() && mark.bid < spot_price)
                        .map(|(&key, _)| key)
                        .collect();
                    v.sort_unstable();
                    v
                }
                None => Vec::new(),
            }
        };
        let mut preempted = Vec::new();
        for (owner, id) in victims {
            if let Some(mut rg) = self.scheduler.cancel_owned(owner, id, now) {
                rg.gridlet.status = GridletStatus::Preempted;
                self.settle_market(&mut rg.gridlet, now);
                preempted.push(rg);
            }
        }
        if !preempted.is_empty() {
            self.return_finished(ctx, preempted);
            // Evictions lowered the utilization, so let the price relax.
            // Bounded recursion: the evicted marks are consumed, so a
            // second pass finds no victims and a third finds a fixed point.
            self.update_market(ctx);
        }
    }

    /// Return finished Gridlets to their owners, record statistics.
    fn return_finished(&mut self, ctx: &mut Ctx<Msg>, finished: Vec<ResGridlet>) {
        for mut rg in finished {
            self.settle_market(&mut rg.gridlet, ctx.now());
            self.completed += u64::from(rg.gridlet.status == GridletStatus::Success);
            if let Some(stats) = self.stats {
                let record = StatRecord {
                    time: ctx.now(),
                    category: self.stat_category.clone(),
                    label: format!("G{}", rg.gridlet.id),
                    value: rg.gridlet.elapsed(),
                };
                ctx.send(stats, tags::RECORD_STATISTICS, Some(Msg::Stat(record)), 48);
            }
            let owner = rg.gridlet.owner;
            let msg = Msg::Gridlet(pool::boxed(rg.gridlet));
            let bytes = msg.wire_bytes(false);
            ctx.send(owner, tags::GRIDLET_RETURN, Some(msg), bytes);
        }
    }
}

impl crate::des::Entity<Msg> for GridResource {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        // Register with the information service (like GRIS -> GIIS in
        // Globus; paper §3.4).
        let info = self.info(ctx.me());
        ctx.send(self.gis, tags::REGISTER_RESOURCE, Some(Msg::Register(info)), 128);
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::GRIDLET_SUBMIT => {
                let Msg::Gridlet(mut g) = ev.take_data() else {
                    panic!("GRIDLET_SUBMIT without a gridlet payload")
                };
                if self.failed {
                    // Bounce immediately: the owner sees a failed Gridlet.
                    g.status = GridletStatus::Failed;
                    g.finish_time = ctx.now();
                    g.resource = Some(ctx.me());
                    let owner = g.owner;
                    let msg = Msg::Gridlet(g);
                    let bytes = msg.wire_bytes(false);
                    ctx.send(owner, tags::GRIDLET_RETURN, Some(msg), bytes);
                    return;
                }
                self.refresh_environment(ctx.now());
                g.arrival_time = ctx.now();
                g.resource = Some(ctx.me());
                let rank = self.arrivals;
                self.arrivals += 1;
                let (owner, id, bid) = (g.owner, g.id, g.max_spot_price);
                self.mark_arrival(owner, id, bid, ctx.now());
                self.scheduler.submit(ResGridlet::new(pool::unbox(g), ctx.now(), rank), ctx.now());
                self.update_market(ctx);
                self.reschedule_tick(ctx);
            }
            tags::RESOURCE_TICK => {
                // Stale-interrupt rule: only the most recently scheduled
                // internal event signifies a completion.
                if self.last_tick != Some(ev.seq) {
                    return;
                }
                self.refresh_environment(ctx.now());
                let finished = self.scheduler.collect(ctx.now());
                self.return_finished(ctx, finished);
                self.update_market(ctx);
                self.reschedule_tick(ctx);
            }
            tags::RESOURCE_CHARACTERISTICS => {
                let mut info = self.info(ctx.me());
                if let Some(m) = self.market.as_mut() {
                    // Report the price currently in effect (Eqs 1–2 resolve
                    // against it) and subscribe the inquirer to updates.
                    info.cost_per_pe_time = m.current_price;
                    if !m.subscribers.contains(&ev.src) {
                        m.subscribers.push(ev.src);
                    }
                }
                ctx.send(ev.src, tags::RESOURCE_CHARACTERISTICS, Some(Msg::Characteristics(info)), 128);
            }
            tags::RESOURCE_DYNAMICS => {
                let dyn_info = ResourceDynamics {
                    id: ctx.me(),
                    in_exec: self.scheduler.in_exec(),
                    queued: self.scheduler.queued(),
                    local_load: self.calendar.load(ctx.now()),
                    available: !self.failed,
                };
                ctx.send(ev.src, tags::RESOURCE_DYNAMICS, Some(Msg::Dynamics(dyn_info)), 64);
            }
            tags::GRIDLET_CANCEL => {
                let Msg::GridletId(id) = ev.take_data() else {
                    panic!("GRIDLET_CANCEL without a gridlet id")
                };
                self.refresh_environment(ctx.now());
                match self.scheduler.cancel(id, ctx.now()) {
                    Some(mut rg) => {
                        self.settle_market(&mut rg.gridlet, ctx.now());
                        let msg = Msg::Gridlet(pool::boxed(rg.gridlet));
                        let bytes = msg.wire_bytes(false);
                        ctx.send(ev.src, tags::GRIDLET_CANCEL_REPLY, Some(msg), bytes);
                    }
                    None => {
                        // Unknown (already finished / returned in flight).
                        ctx.send(ev.src, tags::GRIDLET_CANCEL_REPLY, Some(Msg::GridletId(id)), 16);
                    }
                }
                self.update_market(ctx);
                self.reschedule_tick(ctx);
            }
            tags::GRIDLET_STATUS => {
                let Msg::GridletId(id) = ev.take_data() else {
                    panic!("GRIDLET_STATUS without a gridlet id")
                };
                // Encode the status as a small control code; unknown
                // Gridlets (already returned) report u64::MAX.
                let code = match self.scheduler.status_of(id) {
                    Some(GridletStatus::Queued) => 1,
                    Some(GridletStatus::InExec) => 2,
                    Some(_) => 3,
                    None => u64::MAX,
                };
                ctx.send(ev.src, tags::GRIDLET_STATUS, Some(Msg::Control(code)), 16);
            }
            tags::RESERVATION_REQUEST => {
                let Msg::Reserve(req) = ev.take_data() else {
                    panic!("RESERVATION_REQUEST without payload")
                };
                let accepted = self.reservations.try_reserve(
                    req.reservation_id,
                    req.start,
                    req.duration,
                    req.num_pe,
                );
                let reply = ReservationReply { reservation_id: req.reservation_id, accepted };
                ctx.send(ev.src, tags::RESERVATION_REPLY, Some(Msg::ReserveReply(reply)), 64);
            }
            tags::RESOURCE_FAIL => {
                // Drained jobs come back marked `GridletStatus::Lost`, so
                // owners can distinguish a crash from a completion or a
                // bounce and apply their resubmission policy.
                self.failed = true;
                let lost = self.scheduler.drain(ctx.now());
                self.return_finished(ctx, lost);
                self.update_market(ctx);
                self.last_tick = None;
            }
            tags::RESOURCE_RECOVER => {
                self.failed = false;
            }
            tags::INSIGNIFICANT => {}
            other => panic!("resource {} got unexpected tag {other}", self.name),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridsim::machine::MachineList;

    fn chars(pes: usize, mips: f64, policy: AllocPolicy) -> ResourceCharacteristics {
        ResourceCharacteristics::new(
            "test",
            "linux",
            MachineList::cluster(1, pes, mips),
            policy,
            1.0,
            0.0,
        )
    }

    #[test]
    fn info_reflects_characteristics() {
        let r = GridResource::new(
            "R0",
            chars(4, 515.0, AllocPolicy::TimeShared),
            ResourceCalendar::no_load(),
            0,
        );
        let info = r.info(3);
        assert_eq!(info.id, 3);
        assert_eq!(info.num_pe, 4);
        assert!(info.time_shared);
        assert_eq!(info.mips_per_pe, 515.0);
    }

    #[test]
    fn scheduler_kind_follows_policy() {
        let ts = GridResource::new(
            "a",
            chars(2, 100.0, AllocPolicy::TimeShared),
            ResourceCalendar::no_load(),
            0,
        );
        assert_eq!(ts.scheduler.queued(), 0);
        let ss = GridResource::new(
            "b",
            chars(2, 100.0, AllocPolicy::SpaceShared(super::super::characteristics::SpacePolicy::Fcfs)),
            ResourceCalendar::no_load(),
            0,
        );
        assert_eq!(ss.scheduler.in_exec(), 0);
    }
}
