//! Advance reservations (paper §3.1: "resources can be booked for advance
//! reservation"; §6 lists its scheduling simulation as future work).
//!
//! A [`ReservationBook`] tracks accepted PE bookings over time windows and
//! admits a new reservation only if, at every instant of its window, the
//! total reserved PEs stay within the resource's capacity. Active
//! reservations withhold PEs from the local scheduler (grid work slows
//! down / queues while a window is active).

/// One accepted reservation.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    /// Caller-chosen id (used to cancel).
    pub id: usize,
    /// Window start (inclusive).
    pub start: f64,
    /// Window end (exclusive).
    pub end: f64,
    /// PEs withheld from the local scheduler during the window.
    pub num_pe: usize,
}

/// Capacity-checked reservation calendar for one resource.
#[derive(Debug, Clone)]
pub struct ReservationBook {
    capacity: usize,
    accepted: Vec<Reservation>,
}

impl ReservationBook {
    /// An empty book for a resource with `capacity` PEs.
    pub fn new(capacity: usize) -> ReservationBook {
        ReservationBook { capacity, accepted: Vec::new() }
    }

    /// The resource's total PE count (the admission ceiling).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// All currently accepted reservations, in acceptance order.
    pub fn accepted(&self) -> &[Reservation] {
        &self.accepted
    }

    /// PEs reserved at instant `t`.
    pub fn active_pes(&self, t: f64) -> usize {
        self.accepted
            .iter()
            .filter(|r| r.start <= t && t < r.end)
            .map(|r| r.num_pe)
            .sum()
    }

    /// Peak PEs reserved over `[start, end)` if `extra` more were added.
    fn peak_with(&self, start: f64, end: f64, extra: usize) -> usize {
        // Check at every boundary point inside the window: reservations are
        // piecewise constant so the max occurs at a start point.
        let mut points = vec![start];
        for r in &self.accepted {
            if r.start > start && r.start < end {
                points.push(r.start);
            }
        }
        points
            .into_iter()
            .map(|t| self.active_pes(t) + extra)
            .max()
            .unwrap_or(extra)
    }

    /// Try to book `num_pe` PEs over `[start, start+duration)`. Returns
    /// whether the reservation was accepted.
    pub fn try_reserve(&mut self, id: usize, start: f64, duration: f64, num_pe: usize) -> bool {
        if duration <= 0.0 || num_pe == 0 || num_pe > self.capacity || start < 0.0 {
            return false;
        }
        if self.accepted.iter().any(|r| r.id == id) {
            return false; // duplicate id
        }
        let end = start + duration;
        if self.peak_with(start, end, num_pe) > self.capacity {
            return false;
        }
        self.accepted.push(Reservation { id, start, end, num_pe });
        true
    }

    /// Cancel a reservation by id.
    pub fn cancel(&mut self, id: usize) -> bool {
        let before = self.accepted.len();
        self.accepted.retain(|r| r.id != id);
        self.accepted.len() != before
    }

    /// Drop reservations that ended before `t` (housekeeping).
    pub fn expire(&mut self, t: f64) {
        self.accepted.retain(|r| r.end > t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_within_capacity() {
        let mut book = ReservationBook::new(4);
        assert!(book.try_reserve(1, 10.0, 5.0, 2));
        assert!(book.try_reserve(2, 10.0, 5.0, 2));
        assert_eq!(book.active_pes(12.0), 4);
        assert_eq!(book.active_pes(9.9), 0);
        assert_eq!(book.active_pes(15.0), 0); // end is exclusive
    }

    #[test]
    fn rejects_overlap_beyond_capacity() {
        let mut book = ReservationBook::new(4);
        assert!(book.try_reserve(1, 10.0, 10.0, 3));
        assert!(!book.try_reserve(2, 15.0, 10.0, 2), "peak would be 5 > 4");
        // Non-overlapping is fine.
        assert!(book.try_reserve(3, 20.0, 10.0, 2));
    }

    #[test]
    fn staggered_windows_checked_at_boundaries() {
        let mut book = ReservationBook::new(4);
        assert!(book.try_reserve(1, 0.0, 10.0, 2));
        assert!(book.try_reserve(2, 5.0, 10.0, 2));
        // [7,12) overlaps both at t∈[7,10) → 2+2+1 > 4.
        assert!(!book.try_reserve(3, 7.0, 5.0, 1));
        // But after 10, only id=2 is active → 2+2 ≤ 4 fits in [10,12).
        assert!(book.try_reserve(4, 10.0, 2.0, 2));
    }

    #[test]
    fn rejects_nonsense() {
        let mut book = ReservationBook::new(2);
        assert!(!book.try_reserve(1, 0.0, 0.0, 1), "zero duration");
        assert!(!book.try_reserve(2, 0.0, 1.0, 0), "zero PEs");
        assert!(!book.try_reserve(3, 0.0, 1.0, 3), "beyond capacity");
        assert!(!book.try_reserve(4, -1.0, 1.0, 1), "negative start");
        assert!(book.try_reserve(5, 0.0, 1.0, 1));
        assert!(!book.try_reserve(5, 5.0, 1.0, 1), "duplicate id");
    }

    #[test]
    fn cancel_frees_capacity() {
        let mut book = ReservationBook::new(2);
        assert!(book.try_reserve(1, 0.0, 10.0, 2));
        assert!(!book.try_reserve(2, 5.0, 1.0, 1));
        assert!(book.cancel(1));
        assert!(!book.cancel(1));
        assert!(book.try_reserve(2, 5.0, 1.0, 1));
    }

    #[test]
    fn expire_drops_past() {
        let mut book = ReservationBook::new(2);
        book.try_reserve(1, 0.0, 5.0, 1);
        book.try_reserve(2, 10.0, 5.0, 1);
        book.expire(7.0);
        assert_eq!(book.accepted().len(), 1);
        assert_eq!(book.accepted()[0].id, 2);
    }
}
