//! `gridsim.GridInformationService` — resource registration and discovery
//! (paper §3.2.2): resources register at simulation start; brokers query for
//! the list of registered resources.

use super::messages::{Msg, ResourceInfo};
use super::tags;
use crate::des::{Ctx, Entity, EntityId, Event};

/// The GIS entity.
pub struct GridInformationService {
    name: String,
    resources: Vec<ResourceInfo>,
}

impl GridInformationService {
    /// A GIS with the given entity name and no registered resources yet.
    pub fn new(name: impl Into<String>) -> GridInformationService {
        GridInformationService { name: name.into(), resources: Vec::new() }
    }

    /// Registered resource records (post-run inspection / direct queries in
    /// tests).
    pub fn resources(&self) -> &[ResourceInfo] {
        &self.resources
    }
}

impl Entity<Msg> for GridInformationService {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::REGISTER_RESOURCE => {
                let Msg::Register(info) = ev.take_data() else {
                    panic!("REGISTER_RESOURCE without payload")
                };
                self.resources.push(info);
            }
            tags::RESOURCE_LIST => {
                let ids: Vec<EntityId> = self.resources.iter().map(|r| r.id).collect();
                let msg = Msg::ResourceIds(ids);
                let bytes = msg.wire_bytes(true);
                ctx.send(ev.src, tags::RESOURCE_LIST, Some(msg), bytes);
            }
            tags::INSIGNIFICANT => {}
            other => panic!("GIS got unexpected tag {other}"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Simulation;

    struct Probe {
        gis: EntityId,
        got: Vec<EntityId>,
    }

    impl Entity<Msg> for Probe {
        fn name(&self) -> &str {
            "probe"
        }
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            // Query after registrations have been delivered.
            ctx.send_delayed(self.gis, 1.0, tags::RESOURCE_LIST, None);
        }
        fn on_event(&mut self, _ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
            if let Msg::ResourceIds(ids) = ev.take_data() {
                self.got = ids;
            }
        }
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    struct FakeResource {
        name: String,
        gis: EntityId,
    }

    impl Entity<Msg> for FakeResource {
        fn name(&self) -> &str {
            &self.name
        }
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            let info = ResourceInfo {
                id: ctx.me(),
                name: self.name.as_str().into(),
                num_pe: 1,
                mips_per_pe: 100.0,
                cost_per_pe_time: 1.0,
                time_shared: true,
                time_zone: 0.0,
            };
            ctx.send(self.gis, tags::REGISTER_RESOURCE, Some(Msg::Register(info)), 128);
        }
        fn on_event(&mut self, _ctx: &mut Ctx<Msg>, _ev: Event<Msg>) {}
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn register_and_discover() {
        let mut sim: Simulation<Msg> = Simulation::new();
        let gis = sim.add(Box::new(GridInformationService::new("GIS")));
        let r1 = sim.add(Box::new(FakeResource { name: "R1".into(), gis }));
        let r2 = sim.add(Box::new(FakeResource { name: "R2".into(), gis }));
        let probe = sim.add(Box::new(Probe { gis, got: vec![] }));
        sim.run();
        let p = sim.get::<Probe>(probe).unwrap();
        assert_eq!(p.got, vec![r1, r2]);
        let g = sim.get::<GridInformationService>(gis).unwrap();
        assert_eq!(g.resources().len(), 2);
        assert_eq!(&*g.resources()[0].name, "R1");
    }
}
