//! `gridsim.Machine` / `gridsim.MachineList` — a machine is one or more PEs
//! sharing memory; a resource is one or more machines (paper §3.5).

use super::pe::PeList;

/// A uniprocessor or shared-memory multiprocessor node.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Machine id, unique within its resource.
    pub id: usize,
    /// The machine's processing elements.
    pub pes: PeList,
}

impl Machine {
    /// A machine from its PEs; panics on an empty PE list.
    pub fn new(id: usize, pes: PeList) -> Machine {
        assert!(!pes.is_empty(), "a machine needs at least one PE");
        Machine { id, pes }
    }

    /// Number of PEs in this machine.
    pub fn num_pe(&self) -> usize {
        self.pes.len()
    }

    /// Sum of this machine's PE ratings.
    pub fn total_mips(&self) -> f64 {
        self.pes.total_mips()
    }
}

/// The collection of machines forming a grid resource. A single machine
/// models a PC/workstation/SMP; multiple machines model a cluster.
#[derive(Debug, Clone, Default)]
pub struct MachineList {
    machines: Vec<Machine>,
}

impl MachineList {
    /// An empty machine list.
    pub fn new() -> MachineList {
        MachineList { machines: Vec::new() }
    }

    /// `n_machines` × `pes_per_machine` PEs at `mips`.
    pub fn cluster(n_machines: usize, pes_per_machine: usize, mips: f64) -> MachineList {
        let mut list = MachineList::new();
        for m in 0..n_machines {
            list.add(Machine::new(m, PeList::uniform(pes_per_machine, mips)));
        }
        list
    }

    /// Append a machine.
    pub fn add(&mut self, machine: Machine) {
        self.machines.push(machine);
    }

    /// Number of machines.
    pub fn len(&self) -> usize {
        self.machines.len()
    }

    /// `true` when the list holds no machines.
    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    /// Iterate over the machines in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = &Machine> {
        self.machines.iter()
    }

    /// The `i`-th machine; panics when out of range.
    pub fn get(&self, i: usize) -> &Machine {
        &self.machines[i]
    }

    /// Mutable access to the `i`-th machine; panics when out of range.
    pub fn get_mut(&mut self, i: usize) -> &mut Machine {
        &mut self.machines[i]
    }

    /// Total PEs across all machines.
    pub fn num_pe(&self) -> usize {
        self.machines.iter().map(|m| m.num_pe()).sum()
    }

    /// Sum of the PE ratings across all machines.
    pub fn total_mips(&self) -> f64 {
        self.machines.iter().map(|m| m.total_mips()).sum()
    }

    /// MIPS of one PE (homogeneous assumption, as in the paper).
    pub fn mips_of_one_pe(&self) -> f64 {
        self.machines.first().map(|m| m.pes.mips_of_one()).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_construction() {
        let ml = MachineList::cluster(3, 4, 410.0);
        assert_eq!(ml.len(), 3);
        assert_eq!(ml.num_pe(), 12);
        assert_eq!(ml.total_mips(), 12.0 * 410.0);
        assert_eq!(ml.mips_of_one_pe(), 410.0);
    }

    #[test]
    fn single_machine_smp() {
        let ml = MachineList::cluster(1, 8, 377.0);
        assert_eq!(ml.len(), 1);
        assert_eq!(ml.num_pe(), 8);
    }

    #[test]
    #[should_panic(expected = "at least one PE")]
    fn empty_machine_rejected() {
        Machine::new(0, PeList::new());
    }

    #[test]
    fn empty_list() {
        let ml = MachineList::new();
        assert_eq!(ml.num_pe(), 0);
        assert_eq!(ml.mips_of_one_pe(), 0.0);
    }
}
