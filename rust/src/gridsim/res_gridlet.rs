//! `gridsim.ResGridlet` — a Gridlet as held inside a resource (paper §3.6):
//! the job plus its arrival time, remaining work, and PE/machine assignment.

use super::gridlet::Gridlet;

/// Resource-side execution record for one Gridlet.
#[derive(Debug, Clone)]
pub struct ResGridlet {
    /// The job being executed.
    pub gridlet: Gridlet,
    /// Arrival time at the resource.
    pub arrival: f64,
    /// Time execution started (first allocation of a PE share).
    pub start: f64,
    /// Remaining processing requirement in MI.
    pub remaining_mi: f64,
    /// Machine index assigned (space-shared).
    pub machine: Option<usize>,
    /// First PE index assigned (space-shared).
    pub pe: Option<usize>,
    /// Arrival rank within the resource — the time-shared PE-share allocator
    /// (Fig 8) gives the max share to the lowest-ranked Gridlets.
    pub rank: u64,
}

impl ResGridlet {
    /// Wrap an arriving Gridlet: stamps the arrival time and sets the full
    /// job length as remaining work, unassigned to any machine/PE yet.
    pub fn new(mut gridlet: Gridlet, now: f64, rank: u64) -> ResGridlet {
        let remaining = gridlet.length_mi;
        gridlet.arrival_time = now;
        ResGridlet {
            gridlet,
            arrival: now,
            start: now,
            remaining_mi: remaining,
            machine: None,
            pe: None,
            rank,
        }
    }

    /// Deduct processed work; clamps at zero.
    pub fn consume(&mut self, mi: f64) {
        self.remaining_mi = (self.remaining_mi - mi).max(0.0);
    }

    /// Finished (within float tolerance scaled to job size)?
    pub fn is_done(&self) -> bool {
        self.remaining_mi <= 1e-9 * self.gridlet.length_mi.max(1.0)
    }

    /// Fraction of work completed.
    pub fn progress(&self) -> f64 {
        1.0 - self.remaining_mi / self.gridlet.length_mi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consume_and_done() {
        let g = Gridlet::new(0, 10.0, 0, 0);
        let mut rg = ResGridlet::new(g, 5.0, 0);
        assert_eq!(rg.arrival, 5.0);
        assert!(!rg.is_done());
        rg.consume(4.0);
        assert_eq!(rg.remaining_mi, 6.0);
        assert!((rg.progress() - 0.4).abs() < 1e-12);
        rg.consume(100.0);
        assert_eq!(rg.remaining_mi, 0.0);
        assert!(rg.is_done());
    }

    #[test]
    fn float_tolerance_done() {
        let g = Gridlet::new(0, 1e9, 0, 0);
        let mut rg = ResGridlet::new(g, 0.0, 0);
        rg.consume(1e9 - 1e-3); // within 1e-9 relative tolerance of 1e9
        assert!(rg.is_done());
    }
}
