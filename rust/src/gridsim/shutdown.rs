//! `gridsim.GridSimShutdown` — waits for every user entity to report
//! completion, then ends the simulation (paper §3.6).

use super::messages::Msg;
use super::tags;
use crate::des::{Ctx, Entity, Event};

/// The shutdown coordinator entity.
pub struct GridSimShutdown {
    name: String,
    users_expected: usize,
    users_done: usize,
}

impl GridSimShutdown {
    /// A coordinator that waits for `users_expected` completion reports.
    pub fn new(name: impl Into<String>, users_expected: usize) -> GridSimShutdown {
        GridSimShutdown { name: name.into(), users_expected, users_done: 0 }
    }

    /// How many users have reported completion so far.
    pub fn users_done(&self) -> usize {
        self.users_done
    }
}

impl Entity<Msg> for GridSimShutdown {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, ev: Event<Msg>) {
        match ev.tag {
            tags::END_OF_SIMULATION => {
                self.users_done += 1;
                if self.users_done >= self.users_expected {
                    // All users finished: stop the event loop. Entities get
                    // their `on_end` hooks for report generation.
                    ctx.stop();
                }
            }
            tags::INSIGNIFICANT => {}
            other => panic!("shutdown entity got unexpected tag {other}"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{EntityId, Simulation};

    struct FinishingUser {
        name: String,
        shutdown: EntityId,
        at: f64,
    }

    impl Entity<Msg> for FinishingUser {
        fn name(&self) -> &str {
            &self.name
        }
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            ctx.send_delayed(self.shutdown, self.at, tags::END_OF_SIMULATION, None);
            // Noise events that should never be delivered after stop.
            ctx.schedule_self(1e9, tags::INSIGNIFICANT, None);
        }
        fn on_event(&mut self, _ctx: &mut Ctx<Msg>, _ev: Event<Msg>) {}
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn stops_after_all_users() {
        let mut sim: Simulation<Msg> = Simulation::new();
        let shutdown = sim.add(Box::new(GridSimShutdown::new("shutdown", 2)));
        sim.add(Box::new(FinishingUser { name: "u1".into(), shutdown, at: 5.0 }));
        sim.add(Box::new(FinishingUser { name: "u2".into(), shutdown, at: 9.0 }));
        let end = sim.run();
        assert_eq!(end, 9.0, "simulation must stop at the second END event, not at 1e9");
        assert_eq!(sim.get::<GridSimShutdown>(shutdown).unwrap().users_done(), 2);
    }

    #[test]
    fn waits_for_stragglers() {
        let mut sim: Simulation<Msg> = Simulation::new();
        let shutdown = sim.add(Box::new(GridSimShutdown::new("shutdown", 3)));
        sim.add(Box::new(FinishingUser { name: "u1".into(), shutdown, at: 5.0 }));
        sim.add(Box::new(FinishingUser { name: "u2".into(), shutdown, at: 9.0 }));
        // Third user never reports: simulation runs to the noise events.
        let end = sim.run();
        assert_eq!(end, 1e9);
    }
}
